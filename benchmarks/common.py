"""Shared benchmark scaffolding, rebased on the experiment layer: ``Scale``
maps onto ``repro.fl.experiment.ScenarioConfig`` (one switch (--full)
stepping toward the paper's full 100-client / G=30 / L=10 setting), and the
simulator/session builders delegate to ``repro.fl.experiment.scenario``.

Emits ``name,us_per_call,derived`` CSV rows (harness contract).  Suites can
additionally ``collect_report(name, obj)`` to contribute machine-readable
session/unlearn trajectories that ``benchmarks/run.py --json-dir`` writes to
``BENCH_<suite>.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

from repro.fl.experiment import ScenarioConfig
from repro.fl.experiment import scenario as _scenario

ROWS = []
REPORTS: Dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def collect_report(name: str, report) -> None:
    """Stash a machine-readable report (anything with ``to_dict`` or a plain
    dict) for ``run.py --json-dir`` export."""
    REPORTS[name] = report.to_dict() if hasattr(report, "to_dict") else report


@dataclasses.dataclass
class Scale:
    num_clients: int = 20
    clients_per_round: int = 12
    num_shards: int = 4
    local_epochs: int = 4
    global_rounds: int = 6
    samples_per_client: int = 80
    image_size: int = 14
    seq_len: int = 48
    test_n: int = 400

    @classmethod
    def full(cls):
        return cls(num_clients=100, clients_per_round=20, num_shards=4,
                   local_epochs=10, global_rounds=30, samples_per_client=100,
                   image_size=28, seq_len=64, test_n=1000)


def scenario_config(sc: Scale, task: str = "image", iid: bool = True,
                    seed: int = 0, **overrides) -> ScenarioConfig:
    """Map a benchmark Scale to an experiment ScenarioConfig."""
    return ScenarioConfig(task=task, iid=iid, seed=seed,
                          num_clients=sc.num_clients,
                          clients_per_round=sc.clients_per_round,
                          num_shards=sc.num_shards,
                          local_epochs=sc.local_epochs,
                          global_rounds=sc.global_rounds,
                          retrain_ratio=2.0,
                          samples_per_client=sc.samples_per_client,
                          image_size=sc.image_size, seq_len=sc.seq_len,
                          test_n=sc.test_n, **overrides)


def build_image_sim(sc: Scale, iid: bool, seed: int = 0,
                    store: str = "coded"):
    return _scenario.build_simulator(
        scenario_config(sc, task="image", iid=iid, seed=seed, store=store))


def build_lm_sim(sc: Scale, iid: bool, seed: int = 0):
    return _scenario.build_simulator(
        scenario_config(sc, task="lm", iid=iid, seed=seed))


def build_image_session(sc: Scale, iid: bool, seed: int = 0,
                        store: str = "coded", **overrides):
    return _scenario.build_session(
        scenario_config(sc, task="image", iid=iid, seed=seed, store=store,
                        **overrides))


def build_lm_session(sc: Scale, iid: bool, seed: int = 0):
    return _scenario.build_session(
        scenario_config(sc, task="lm", iid=iid, seed=seed))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
