"""Shared benchmark scaffolding: builds paper-protocol simulators at a scale
that runs on this CPU container, with one switch (--full) stepping toward the
paper's full 100-client / G=30 / L=10 setting.

Emits ``name,us_per_call,derived`` CSV rows (harness contract).
"""
from __future__ import annotations

import dataclasses
import sys
import time
from typing import Dict, Optional

import numpy as np

from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import (client_datasets_images, client_datasets_lm,
                        lm_examples, make_char_data, make_image_data)
from repro.fl import FLSimulator

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@dataclasses.dataclass
class Scale:
    num_clients: int = 20
    clients_per_round: int = 12
    num_shards: int = 4
    local_epochs: int = 4
    global_rounds: int = 6
    samples_per_client: int = 80
    image_size: int = 14
    seq_len: int = 48
    test_n: int = 400

    @classmethod
    def full(cls):
        return cls(num_clients=100, clients_per_round=20, num_shards=4,
                   local_epochs=10, global_rounds=30, samples_per_client=100,
                   image_size=28, seq_len=64, test_n=1000)


def fl_config(sc: Scale) -> FLConfig:
    return FLConfig(num_clients=sc.num_clients,
                    clients_per_round=sc.clients_per_round,
                    num_shards=sc.num_shards,
                    local_epochs=sc.local_epochs,
                    global_rounds=sc.global_rounds,
                    retrain_ratio=2.0)


def build_image_sim(sc: Scale, iid: bool, seed: int = 0,
                    store: str = "coded"):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=sc.image_size,
                              d_model=48, cnn_channels=(8, 16))
    data = make_image_data(sc.num_clients * sc.samples_per_client,
                           image_size=sc.image_size, seed=seed, noise=0.25)
    clients = client_datasets_images(data, sc.num_clients, iid=iid, seed=seed)
    sim = FLSimulator(cfg, fl_config(sc), clients, task="image",
                      opt_cfg=OptimizerConfig(name="sgd", lr=0.05, grad_clip=0.0),
                      local_batch=20, seed=seed)
    test = make_image_data(sc.test_n, image_size=sc.image_size, seed=seed + 999,
                           noise=0.25)
    return sim, (test.images, test.labels)


def build_lm_sim(sc: Scale, iid: bool, seed: int = 0):
    cfg = get_config("nanogpt-paper")
    stream = make_char_data(sc.num_clients * sc.samples_per_client * sc.seq_len
                            + sc.seq_len + 1, vocab_size=cfg.vocab_size,
                            seed=seed)
    toks, labs = lm_examples(stream, sc.seq_len)
    clients = client_datasets_lm(toks, labs, sc.num_clients, iid=iid, seed=seed)
    sim = FLSimulator(cfg, fl_config(sc), clients, task="lm",
                      opt_cfg=OptimizerConfig(name="sgd", lr=0.3, grad_clip=0.0),
                      local_batch=10, seed=seed)
    test_stream = make_char_data(sc.test_n * sc.seq_len + 1,
                                 vocab_size=cfg.vocab_size, seed=seed + 999)
    tt, tl = lm_examples(test_stream, sc.seq_len)
    return sim, (tt, tl)


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
