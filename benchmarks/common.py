"""Shared benchmark scaffolding, rebased on the experiment layer: ``Scale``
maps onto ``repro.fl.experiment.ScenarioConfig`` (one switch (--full)
stepping toward the paper's full 100-client / G=30 / L=10 setting), and the
simulator/session builders delegate to ``repro.fl.experiment.scenario``.

``Scale``'s defaults are DERIVED from the ``ScenarioConfig`` dataclass (and
``Scale.full()`` from ``ScenarioConfig.paper_full()``), so a new scenario
field can never silently drift between the two.

Emits ``name,us_per_call,derived`` CSV rows (harness contract).  Suites can
additionally ``collect_report(name, obj)`` to contribute machine-readable
session/unlearn trajectories that ``benchmarks/run.py --json-dir`` writes to
``BENCH_<suite>.json``.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict

from repro.fl.experiment import ScenarioConfig
from repro.fl.experiment import scenario as _scenario

ROWS = []
REPORTS: Dict[str, dict] = {}


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def collect_report(name: str, report) -> None:
    """Stash a machine-readable report (anything with ``to_dict`` or a plain
    dict) for ``run.py --json-dir`` export."""
    REPORTS[name] = report.to_dict() if hasattr(report, "to_dict") else report


_SCENARIO_DEFAULTS = {f.name: f.default
                      for f in dataclasses.fields(ScenarioConfig)}
_SCALE_FIELDS = ("num_clients", "clients_per_round", "num_shards",
                 "local_epochs", "global_rounds", "samples_per_client",
                 "image_size", "seq_len", "test_n")


def _scale_full(cls):
    pf = ScenarioConfig.paper_full()
    return cls(**{name: getattr(pf, name) for name in _SCALE_FIELDS})


Scale = dataclasses.make_dataclass(
    "Scale",
    [(name, int, dataclasses.field(default=_SCENARIO_DEFAULTS[name]))
     for name in _SCALE_FIELDS],
    namespace={"full": classmethod(_scale_full)})
Scale.__doc__ = ("Benchmark scale knobs — defaults derived from "
                 "``ScenarioConfig``; ``Scale.full()`` is the paper's full "
                 "setting (``ScenarioConfig.paper_full``).")


def scenario_config(sc, task: str = "classification",
                    partitioner: str = "iid", seed: int = 0,
                    **overrides) -> ScenarioConfig:
    """Map a benchmark Scale to an experiment ScenarioConfig.  ``overrides``
    win over the Scale's fields (e.g. a suite pushing ``local_epochs`` into
    the memorization regime)."""
    kw = {name: getattr(sc, name) for name in _SCALE_FIELDS}
    kw.update(overrides)
    return ScenarioConfig(task=task, partitioner=partitioner, seed=seed, **kw)


def _partitioner(iid: bool, task: str) -> str:
    """The paper's two data distributions, by registry name."""
    if iid:
        return "iid"
    return "primary-class" if task == "classification" else "buckets"


def build_image_sim(sc, iid: bool, seed: int = 0, store: str = "coded"):
    return _scenario.build_simulator(
        scenario_config(sc, task="classification",
                        partitioner=_partitioner(iid, "classification"),
                        seed=seed, store=store))


def build_lm_sim(sc, iid: bool, seed: int = 0):
    return _scenario.build_simulator(
        scenario_config(sc, task="generation",
                        partitioner=_partitioner(iid, "generation"),
                        seed=seed))


def build_image_session(sc, iid: bool, seed: int = 0, store: str = "coded",
                        **overrides):
    return _scenario.build_session(
        scenario_config(sc, task="classification",
                        partitioner=_partitioner(iid, "classification"),
                        seed=seed, store=store, **overrides))


def build_lm_session(sc, iid: bool, seed: int = 0):
    return _scenario.build_session(
        scenario_config(sc, task="generation",
                        partitioner=_partitioner(iid, "generation"),
                        seed=seed))


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, (time.perf_counter() - t0) * 1e6
