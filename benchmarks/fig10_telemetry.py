"""Tracer overhead A/B/C on the fig6 stage-engine scenario.

Three configurations of the SAME workload (one coded-store ``stage``-engine
training stage at scale ``sc``, the fig6 steady-state protocol):

* ``off``     — the default ``NULL_TRACER``: every instrumentation site costs
  one ``get_tracer()`` call plus a no-op context manager.  The acceptance
  budget is < 2% over an untraced run; fig10 reports the measured wall so the
  dispatch-budget table (ROADMAP) can carry the real number.
* ``on``      — full span recording (wall + virtual clocks, labels, the
  metrics registry absorbing per-stage StoreStats).
* ``export``  — recording plus a Chrome/Perfetto ``trace.json`` export and
  validation after the timed stages (export cost amortized per stage).

Emits the per-stage median wall for each mode, the relative overheads, the
span count and export size for the traced modes, and restores the disabled
tracer afterwards so later suites see the default.
"""
from __future__ import annotations

import json
import os
import statistics
import tempfile

from benchmarks.common import Scale, build_image_sim, emit, timed

ITERS = 3


def _stage_wall(sc: Scale) -> float:
    """Median wall (us) of a steady-state stage-engine training stage."""
    from repro.fl.experiment import train_stage

    sim, _ = build_image_sim(sc, iid=True)
    train_stage(sim, store_kind="coded", engine="stage")   # warm the jit cache
    walls = []
    for _ in range(ITERS):
        _, us = timed(train_stage, sim, store_kind="coded", engine="stage")
        walls.append(us)
    return statistics.median(walls)


def run(sc: Scale):
    from repro.telemetry import (configure, get_tracer, set_tracer,
                                 to_chrome_trace, validate_chrome_trace,
                                 NULL_TRACER)

    set_tracer(NULL_TRACER)
    off_us = _stage_wall(sc)
    emit("fig10_tracer_off", off_us,
         f"stage engine;coded;G={sc.global_rounds};median_of={ITERS}")

    configure(enabled=True)
    on_us = _stage_wall(sc)
    tr = get_tracer()
    spans = len(tr.all_spans())
    emit("fig10_tracer_on", on_us,
         f"spans={spans};overhead_vs_off={(on_us / off_us - 1) * 100:.2f}pct")

    configure(enabled=True, annotate_costs=True)
    export_us = _stage_wall(sc)
    tr = get_tracer()
    trace = to_chrome_trace(tr)
    errors = validate_chrome_trace(trace)
    payload = json.dumps(trace)
    path = os.path.join(tempfile.gettempdir(), "fig10_trace.json")
    with open(path, "w") as f:
        f.write(payload)
    emit("fig10_tracer_export", export_us,
         f"spans={len(tr.all_spans())};trace_bytes={len(payload)};"
         f"schema_errors={len(errors)};"
         f"overhead_vs_off={(export_us / off_us - 1) * 100:.2f}pct")

    set_tracer(NULL_TRACER)                 # leave later suites untraced


if __name__ == "__main__":  # PYTHONPATH=src python -m benchmarks.fig10_telemetry
    run(Scale())
