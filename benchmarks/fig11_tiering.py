"""Fig. 11 (repo extension): the tiered store's storage-vs-decode-error
frontier.

Sweeps ``MemoryBudget`` points from all-hot (exact, max bytes) down to
all-cold (int8 on disk, min RAM bytes) on identically seeded sessions, and
measures at each point: resident bytes per tier, the SE-unlearn decode error
against the exact ``CodedStore`` twin (global relative model distance), and
the SE unlearn wall — the three axes of the frontier.  A second sweep holds
the budget fixed at half-hot and swaps the eviction policy (LRU /
stage-age / Zipf-aware heat).

Every point's full ``SessionReport`` (with the per-tier ``StoreStats``
counters) lands in ``BENCH_fig11.json`` via ``--json-dir``.
"""
from __future__ import annotations

import tempfile

import jax
import numpy as np

from benchmarks.common import (Scale, build_image_session, collect_report,
                               emit)
from repro.fl.experiment import UnlearnRequest


def _victim(plan):
    return [plan.shard_clients[0][0]]


def _rel_err(ref_models, got_models) -> float:
    """Global relative model distance over the impacted shards."""
    diff, ref = [], []
    for s in ref_models:
        for x, y in zip(jax.tree.leaves(ref_models[s]),
                        jax.tree.leaves(got_models[s])):
            x = np.asarray(x, np.float64)
            diff.append((x - np.asarray(y, np.float64)).ravel())
            ref.append(x.ravel())
    d, r = np.concatenate(diff), np.concatenate(ref)
    return float(np.linalg.norm(d) / (np.linalg.norm(r) + 1e-12))


def _run_point(sc, store: str, store_options=None):
    session, _test = build_image_session(sc, iid=True, store=store,
                                         store_options=store_options or {})
    session.run_stage()
    res = session.unlearn(UnlearnRequest(_victim, framework="SE"))[0]
    return session, res


def run(sc: Scale):
    offload = tempfile.mkdtemp(prefix="fig11-")
    # exact reference: the plain coded store ---------------------------------
    ref_session, ref_res = _run_point(sc, "coded")
    emit("fig11_coded_ref", 0.0,
         f"server_bytes={ref_session.report.store_stats.server_bytes};"
         f"unlearn_s={ref_res.wall_time:.3f}")

    # budget frontier: all-hot → all-cold ------------------------------------
    hot_total = None
    points = [("unlimited", dict()),
              ("hot_half", None),                    # resolved after unlimited
              ("warm_only", dict(hot_bytes=0)),
              ("cold_only", dict(hot_bytes=0, warm_bytes=0))]
    for name, opts in points:
        if opts is None:                             # hot_half needs hot_total
            opts = dict(hot_bytes=hot_total // 2)
        opts = dict(opts, offload_dir=offload)
        session, res = _run_point(sc, "tiered", opts)
        stats = session.report.store_stats
        tb = stats.tier_bytes
        if name == "unlimited":
            hot_total = tb.get("hot", 0)
        err = _rel_err(ref_res.models, res.models)
        emit(f"fig11_{name}", 0.0,
             f"hot_bytes={tb.get('hot', 0)};warm_bytes={tb.get('warm', 0)};"
             f"cold_bytes={tb.get('cold', 0)};"
             f"ram_bytes={tb.get('hot', 0) + tb.get('warm', 0)};"
             f"decode_rel_err={err:.2e};unlearn_s={res.wall_time:.3f};"
             f"hits={dict(stats.tier_hits)};"
             f"evictions={dict(stats.tier_evictions)}")
        collect_report(f"fig11_{name}", session.report)

    # eviction-policy sweep at the half-hot pressure point -------------------
    for policy in ("lru", "stage_age", "heat"):
        opts = dict(hot_bytes=hot_total // 2, eviction=policy,
                    offload_dir=offload)
        session, res = _run_point(sc, "tiered", opts)
        stats = session.report.store_stats
        err = _rel_err(ref_res.models, res.models)
        emit(f"fig11_evict_{policy}", 0.0,
             f"decode_rel_err={err:.2e};unlearn_s={res.wall_time:.3f};"
             f"evictions={dict(stats.tier_evictions)};"
             f"promotions={dict(stats.tier_promotions)}")


if __name__ == "__main__":
    run(Scale())
