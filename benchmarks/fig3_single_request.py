"""Paper Fig. 3: performance with a SINGLE unlearning request.

For each framework (FR / FE / RR / SE) x task (image, lm) x distribution
(IID, non-IID): unlearned-model quality (accuracy / loss) and retraining time.
SE's claim: comparable accuracy to FR at a fraction of the retraining time.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Scale, build_image_sim, build_lm_sim, emit

FRAMEWORKS = ("FR", "FE", "RR", "SE")


def run(sc: Scale, tasks=("image", "lm"), iids=(True, False)):
    for task in tasks:
        for iid in iids:
            tag = f"fig3_{task}_{'iid' if iid else 'noniid'}"
            sim, test = (build_image_sim if task == "image" else build_lm_sim)(
                sc, iid=iid)
            record = sim.train_stage(store_kind="coded")
            base = sim.evaluate(record.shard_models, *test)
            emit(f"{tag}_trained", 0.0,
                 f"acc={base['acc']:.4f};loss={base['loss']:.4f}")
            victim = record.plan.shard_clients[0][0]
            for fw in FRAMEWORKS:
                res = sim.unlearn(fw, record, [victim])
                m = sim.evaluate(res.models, *test)
                emit(f"{tag}_{fw}", res.wall_time * 1e6,
                     f"acc={m['acc']:.4f};loss={m['loss']:.4f};"
                     f"cost_units={res.cost_units:.0f};"
                     f"retrain_s={res.wall_time:.2f}")
            fr = sim.unlearn("FR", record, [victim])
            se = sim.unlearn("SE", record, [victim])
            gain = 1.0 - se.cost_units / max(fr.cost_units, 1e-9)
            emit(f"{tag}_SE_vs_FR_cost_reduction", 0.0, f"gain={gain:.2%}")


if __name__ == "__main__":
    run(Scale())
