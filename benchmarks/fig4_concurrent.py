"""Paper Fig. 4: performance with CONCURRENT unlearning requests, in the
'Even' (spread across shards) and 'Adapt' (all in one shard) patterns.

SE's claim: the retraining cost follows eq. (10) — only distinct impacted
shards retrain — so Adapt is much cheaper than Even, and both beat FR/FE/RR
which always retrain the full federation.
"""
from __future__ import annotations

from benchmarks.common import Scale, build_image_sim, build_lm_sim, emit
from repro.core.sharding import adaptive_requests, even_requests

FRAMEWORKS = ("FR", "FE", "RR", "SE")


def run(sc: Scale, k: int = 4, tasks=("image", "lm")):
    for task in tasks:
        sim, test = (build_image_sim if task == "image" else build_lm_sim)(
            sc, iid=True)
        record = sim.train_stage(store_kind="coded")
        for pattern, reqfn in (("even", even_requests),
                               ("adapt", adaptive_requests)):
            requests = reqfn(record.plan, k)
            tag = f"fig4_{task}_{pattern}"
            for fw in FRAMEWORKS:
                res = sim.unlearn(fw, record, requests)
                m = sim.evaluate(res.models, *test)
                emit(f"{tag}_{fw}", res.wall_time * 1e6,
                     f"acc={m['acc']:.4f};loss={m['loss']:.4f};"
                     f"cost_units={res.cost_units:.0f};"
                     f"impacted={len(res.impacted_shards)}")


if __name__ == "__main__":
    run(Scale())
