"""Paper Fig. 5: communication time and storage overhead with concurrent
adaptive requests — FE (full central storage) vs Uncoded SE (isolated
sharding) vs Coded SE (isolated + coded), driven through ``FederatedSession``.

(a/b): comm time + storage for the base setting.
(c/d): storage/comm as the number of clients / global rounds grows (modelled
byte-accounting via core.theory.storage_bytes + measured encode/decode).

Communication model (paper Sec 5.2): base delay 0.1 s per transfer + bytes /
network rate (1 Gbit/s).
"""
from __future__ import annotations

from benchmarks.common import (Scale, build_image_session, collect_report,
                               emit)
from repro.stores.store import tree_bytes
from repro.core import theory
from repro.core.sharding import adaptive_requests
from repro.fl.experiment import UnlearnRequest

BASE_DELAY_S = 0.1
NET_RATE = 1e9 / 8            # bytes/s (1 Gbit/s)


def comm_time(n_transfers: int, total_bytes: int) -> float:
    return n_transfers * BASE_DELAY_S + total_bytes / NET_RATE


def run(sc: Scale):
    # measured stores on the real trained stage -----------------------------
    for store_kind, name in (("full", "FE"), ("uncoded", "SE-uncoded"),
                             ("coded", "SE-coded")):
        session, _test = build_image_session(sc, iid=True, store=store_kind)
        session.run_stage()
        fw = "FE" if store_kind == "full" else "SE"
        res = session.unlearn(UnlearnRequest(
            lambda plan: adaptive_requests(plan, 3), framework=fw))[0]
        stage = session.report.stages[0]
        st = stage.store_stats
        ct = comm_time(sc.clients_per_round * sc.global_rounds,
                       st.comm_bytes_store + st.comm_bytes_retrieve)
        emit(f"fig5_{name}_storage", 0.0,
             f"server_bytes={st.server_bytes};client_bytes={st.client_bytes};"
             f"comm_time_s={ct:.2f};retrain_s={res.wall_time:.2f};"
             f"train_s={stage.train_wall:.2f}")
        collect_report(f"fig5_{name}", session.report)

    # modelled scaling curves (paper Fig. 5c/d) ------------------------------
    session, _ = build_image_session(sc, iid=True, store="full")
    record = session.run_stage()
    c0 = record.store.clients_at(0)[0]
    mb = tree_bytes(record.store.get(0, c0))
    for c in (20, 40, 60, 80, 100):
        for mech in ("full", "uncoded", "coded"):
            b = theory.storage_bytes(mb, c, sc.num_shards, sc.global_rounds,
                                     mech)
            ct = comm_time(c * sc.global_rounds,
                           b["total_bytes"] if mech == "coded" else
                           b["server_bytes"] * (1 if mech == "full"
                                                else sc.num_shards))
            emit(f"fig5c_clients{c}_{mech}", 0.0,
                 f"server_bytes={b['server_bytes']};"
                 f"client_bytes={b['client_bytes']};comm_time_s={ct:.2f}")
    for g in (5, 10, 20, 30):
        for mech in ("full", "uncoded", "coded"):
            b = theory.storage_bytes(mb, sc.num_clients, sc.num_shards, g, mech)
            emit(f"fig5d_rounds{g}_{mech}", 0.0,
                 f"server_bytes={b['server_bytes']};"
                 f"client_bytes={b['client_bytes']}")
    # headline: coded vs full server-storage reduction
    bf = theory.storage_bytes(mb, sc.num_clients, sc.num_shards,
                              sc.global_rounds, "full")
    bc = theory.storage_bytes(mb, sc.num_clients, sc.num_shards,
                              sc.global_rounds, "coded")
    emit("fig5_server_storage_reduction", 0.0,
         f"reduction={1 - bc['server_bytes'] / bf['server_bytes']:.2%}")


if __name__ == "__main__":
    run(Scale())
