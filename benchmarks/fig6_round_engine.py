"""Round-engine A/B/C: stage-training throughput across the three engines
(same model, data, store kind, and RNG protocol) plus batched-vs-sequential
session unlearning.

Engines, by per-stage dispatch count (see ``repro.fl.experiment.stage``):

* ``legacy`` — the seed per-client loop: per-client unstack,
  ``float(tree_norm(...))`` per (shard, round, client), per-round
  re-flatten + encode (≫ G·S·M host/device round-trips).
* ``fused``  — one jitted ``shard_round`` per (shard, round) + one deferred
  batched encode: G·S + 1 dispatches.
* ``stage``  — the whole-stage superfusion: vmap over shards × scan over
  rounds with the Lagrange encode fused into the same XLA program — ONE
  dispatch per stage.

Emits per-engine stage wall time (median of ``ITERS`` timed stages),
rounds/s, the pairwise speedups, the SE unlearning wall, and a batched
session-unlearning A/B: four SE requests overlapping on two shards of one
stage, served sequentially (each request retrains its whole shard — four
calibrated retrains) vs merged (``batch_requests=True``: each shard retrains
once, both shards in one vmapped ``calib_stage`` dispatch).  Two regimes
are measured: the paper-protocol scale ``sc`` (local-SGD compute-bound — the
engine win is bounded by the training floor) and a large-C
bookkeeping-bound variant (4x the clients per round, half the local epochs)
where the per-client history handling the engines eliminate is a
first-order cost — the ROADMAP's large-fleet regime.
"""
from __future__ import annotations

import dataclasses
import statistics

from benchmarks.common import (Scale, build_image_sim, collect_report, emit,
                               timed)

ITERS = 3
ENGINES = ("legacy", "fused", "stage")


def _dispatches(engine: str, sc: Scale) -> str:
    g, s, m = sc.global_rounds, sc.num_shards, sc.clients_per_round
    return {"legacy": f"~{g * s}xtrain+{g * s * m}xnorm+{g}xencode",
            "fused": f"{g * s}xtrain+1xencode",
            "stage": "1"}[engine]


def _ab(sc: Scale, tag: str):
    from repro.fl.experiment import run_unlearn, train_stage

    stage_us = {}
    for engine in ENGINES:
        sim, _ = build_image_sim(sc, iid=True)
        # warm the jit caches so the A/B measures steady-state round time —
        # at the SAME round count as the timed stages (the stage engine's
        # program cache is keyed on g_rounds; a rounds=1 warm-up would leave
        # the G-round program to compile inside the first timed iteration)
        train_stage(sim, store_kind="coded", engine=engine)
        walls, record = [], None
        for _ in range(ITERS):
            record, us = timed(train_stage, sim, store_kind="coded",
                               engine=engine)
            walls.append(us)
        us = statistics.median(walls)
        stage_us[engine] = us
        rounds_per_s = sc.global_rounds / (us / 1e6)
        emit(f"fig6_stage_train_{engine}{tag}", us,
             f"G={sc.global_rounds};S={sc.num_shards};"
             f"M={sc.clients_per_round};L={sc.local_epochs};"
             f"rounds_per_s={rounds_per_s:.2f};"
             f"dispatches={_dispatches(engine, sc)};median_of={ITERS}")
        victim = record.plan.shard_clients[0][0]
        res = run_unlearn(sim, "SE", record, [victim])
        emit(f"fig6_unlearn_SE_{engine}_record{tag}", res.wall_time * 1e6,
             f"calibrated retraining wall;cost={res.cost_units:.0f}")
    emit(f"fig6_round_engine_speedup{tag}", 0.0,
         f"fused_vs_legacy={stage_us['legacy'] / stage_us['fused']:.2f}x;"
         f"stage_vs_fused={stage_us['fused'] / stage_us['stage']:.2f}x;"
         f"stage_vs_legacy={stage_us['legacy'] / stage_us['stage']:.2f}x")


def _batched_unlearn(sc: Scale, tag: str):
    """N=4 overlapping SE requests (two per shard on two shards of one
    stage): served sequentially (each request triggers a full calibrated
    retraining of its shard — overlapping shards retrain once PER REQUEST)
    vs merged into one batch (each impacted shard retrains ONCE with the
    union of its requested clients removed, the two shards vmapped into a
    single calib_stage dispatch)."""
    from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                     UnlearnRequest)

    def schedule():
        return RequestSchedule([
            UnlearnRequest(lambda p, s=s, i=i: [p.shard_clients[s][i]],
                           framework="SE", after_stage=0)
            for s in (0, 1) for i in (0, 1)
        ])

    walls = {}
    for mode, batch in (("sequential", False), ("batched", True)):
        sim, _ = build_image_sim(sc, iid=True)    # one sim: jits stay warm
        per_iter = []
        report = None
        for it in range(ITERS + 1):            # iter 0 warms the jit caches
            session = FederatedSession(sim, store_kind="coded",
                                       engine="stage", batch_requests=batch)
            report = session.run(1, schedule=schedule())
            if it > 0:
                per_iter.append(report.total_unlearn_wall * 1e6)
        walls[mode] = statistics.median(per_iter)
        served = sum(len(st.unlearn) for st in report.stages)
        emit(f"fig6_unlearn_4req_{mode}{tag}", walls[mode],
             f"SE;4 requests;{served} serve(s);median_of={ITERS}")
        collect_report(f"fig6_4req_{mode}{tag}", report)
    emit(f"fig6_batched_unlearn_speedup{tag}", 0.0,
         f"batched_vs_sequential="
         f"{walls['sequential'] / walls['batched']:.2f}x")


def run(sc: Scale):
    _ab(sc, "")
    _batched_unlearn(sc, "")
    if sc.clients_per_round >= 12:      # skip the heavy pass under --fast
        large_c = dataclasses.replace(
            sc, clients_per_round=4 * sc.clients_per_round,
            num_clients=max(sc.num_clients, 4 * sc.clients_per_round + 16),
            local_epochs=max(sc.local_epochs // 2, 1),
            samples_per_client=max(sc.samples_per_client // 2, 20))
        _ab(large_c, "_largeC")


if __name__ == "__main__":                 # PYTHONPATH=src python -m benchmarks.fig6_round_engine
    run(Scale())
