"""Round-engine A/B: stage-training throughput, fused stacked path vs the
seed per-client path (same model, data, store kind, and RNG protocol).

The fused engine keeps client parameters stacked on device end-to-end: one
jitted ``shard_round`` per (shard, round) that folds in FedAvg and the update
norms, stored-norm fetch once per stage, flatten-once coded puts, and all G
round encodes batched into one coded matmul. The legacy engine is the seed
loop: per-client unstack, ``float(tree_norm(...))`` per (shard, round,
client), and a per-round re-flatten + encode.

Emits per-engine stage wall time and rounds/s, the fused/legacy speedup, and
the SE unlearning wall time (whose calibration now also runs stacked). Two
regimes are measured: the paper-protocol scale ``sc`` (local-SGD
compute-bound — the engine win is bounded by the training floor) and a
large-C bookkeeping-bound variant (4x the clients per round, half the local
epochs) where the per-client history handling the engine eliminates is a
first-order cost — the ROADMAP's large-fleet regime.
"""
from __future__ import annotations

import dataclasses

from benchmarks.common import Scale, build_image_sim, emit, timed


def _ab(sc: Scale, tag: str):
    stage_us = {}
    for engine in ("legacy", "fused"):
        sim, _ = build_image_sim(sc, iid=True)
        # warm the jit caches so the A/B measures steady-state round time
        sim.train_stage(store_kind="coded", rounds=1, engine=engine)
        record, us = timed(sim.train_stage, store_kind="coded", engine=engine)
        stage_us[engine] = us
        rounds_per_s = sc.global_rounds / (us / 1e6)
        emit(f"fig6_stage_train_{engine}{tag}", us,
             f"G={sc.global_rounds};S={sc.num_shards};"
             f"M={sc.clients_per_round};L={sc.local_epochs};"
             f"rounds_per_s={rounds_per_s:.2f}")
        victim = record.plan.shard_clients[0][0]
        res = sim.unlearn("SE", record, [victim])
        emit(f"fig6_unlearn_SE_{engine}_record{tag}", res.wall_time * 1e6,
             f"calibrated retraining wall;cost={res.cost_units:.0f}")
    emit(f"fig6_round_engine_speedup{tag}", 0.0,
         f"fused_vs_legacy={stage_us['legacy'] / stage_us['fused']:.2f}x")


def run(sc: Scale):
    _ab(sc, "")
    if sc.clients_per_round >= 12:      # skip the heavy pass under --fast
        large_c = dataclasses.replace(
            sc, clients_per_round=4 * sc.clients_per_round,
            num_clients=max(sc.num_clients, 4 * sc.clients_per_round + 16),
            local_epochs=max(sc.local_epochs // 2, 1),
            samples_per_client=max(sc.samples_per_client // 2, 20))
        _ab(large_c, "_largeC")


if __name__ == "__main__":                 # PYTHONPATH=src python -m benchmarks.fig6_round_engine
    run(Scale())
