"""Fig. 7 (beyond the paper): the online unlearning service — request
scheduling x device placement.

Serves the same trace of single-shard unlearning requests two ways and
measures the serving walls and SLA ledger:

* ``seq``   — FIFO policy on a 1-device placement: one request at a time,
  the sequential baseline (bit-identical to ``FederatedSession.run``).
* ``async`` — batch-window policy on an all-device placement: the window
  coalesces the requests, each impacted shard's retraining program is
  dispatched asynchronously to its own device, and the ledger blocks only
  at request completion.

Run under ``XLA_FLAGS=--xla_force_host_platform_device_count=4`` to get 4
virtual CPU devices (the CI bench job does); on a single device the async
row degenerates to the sequential wall.  CPU speedup is bounded by physical
cores — the placement caps its workers at ``os.cpu_count()``.

A third scenario serves a seeded Poisson trace with per-request deadlines
through the SLA policy for the latency-percentile / hit-rate trajectory.
"""
from __future__ import annotations

import jax

from benchmarks.common import Scale, build_image_session, collect_report, emit
from repro.core.sharding import even_requests
from repro.service import (DevicePlacement, UnlearningService, poisson_trace,
                           sequenced_trace, single_device_placement)


def _latency_derived(report) -> str:
    return (f"p50={report.p50:.3f}s;p95={report.p95:.3f}s;"
            f"p99={report.p99:.3f}s;throughput={report.throughput:.2f}rps")


def run(sc: Scale, rounds=None):
    session, _test = build_image_session(sc, iid=True)
    record = session.run_stage()
    plan = record.plan
    rounds = rounds or sc.global_rounds
    n_dev = len(jax.devices())

    # one single-victim request per shard — the concurrent-serving shape the
    # async placement spreads one-shard-program-per-device
    victims = even_requests(plan, plan.num_shards)
    trace = sequenced_trace(victims, spacing=0.0, rounds=rounds)

    seq = UnlearningService(session, policy="fifo",
                            placement=single_device_placement())
    qasync = UnlearningService(session, policy="window",
                               policy_opts={"width": 1.0},
                               placement=DevicePlacement())
    # warmup: per-device executable compiles stay out of the measured serves
    seq.serve(trace)
    qasync.serve(trace)
    rep_seq = seq.serve(trace)
    rep_async = qasync.serve(trace)

    speedup = (rep_seq.serve_wall / rep_async.serve_wall
               if rep_async.serve_wall else 0.0)
    emit("fig7_service_seq_wall", rep_seq.serve_wall * 1e6,
         f"policy=fifo;devices=1;requests={len(trace)};"
         + _latency_derived(rep_seq))
    emit("fig7_service_async_wall", rep_async.serve_wall * 1e6,
         f"policy=window;devices={n_dev};"
         f"workers={rep_async.placement['max_workers']};"
         f"requests={len(trace)};seq_vs_async={speedup:.2f}x;"
         + _latency_derived(rep_async))
    collect_report("fig7_service_seq", rep_seq)
    collect_report("fig7_service_async", rep_async)

    # SLA-measured serving of a seeded Poisson stream with deadlines
    sla_trace = poisson_trace(plan.clients, n=2 * plan.num_shards, rate=4.0,
                              seed=0, rounds=max(rounds // 2, 1),
                              deadline=30.0, skew=1.0)
    sla = UnlearningService(session, policy="sla",
                            policy_opts={"default_deadline": 30.0,
                                         "est_serve": 2.0, "max_hold": 1.0},
                            placement=DevicePlacement())
    rep_sla = sla.serve(sla_trace)
    emit("fig7_service_sla_wall", rep_sla.serve_wall * 1e6,
         f"policy=sla;devices={n_dev};requests={len(sla_trace)};"
         f"batches={rep_sla.num_batches};"
         f"sla_hit_rate={rep_sla.sla_hit_rate};" + _latency_derived(rep_sla))
    collect_report("fig7_service_sla", rep_sla)


if __name__ == "__main__":
    run(Scale())
