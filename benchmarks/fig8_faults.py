"""Fig. 8 (beyond the paper): chaos harness — recovery overhead vs fault
rate.

Serves the same single-victim-per-shard trace under increasingly hostile
seeded fault plans and measures what recovery costs:

* ``clean``    — no faults: the baseline serving wall.
* ``erase=k``  — k coded slices unreachable per read: erasure decoding from
  the survivors (cheapest recovery — one smaller re-interpolation).
* ``corrupt=k``— k slices bit-corrupted per read: Berlekamp-Welch / RANSAC
  error localization before the erasure decode (the expensive recovery).
* ``chaos``    — corruption + erasure + transient job failures: quorum reads
  plus the service's retry/backoff path.

Every plan spares the canonical quorum subset (injector default), so each
serve's models stay bit-identical to the clean serve while the ledger and
``StoreStats`` record the recovery work — overhead is measured on identical
outputs.  The derived column carries the recovery counters so the JSON
artifact (``BENCH_fig8.json``) exposes the overhead-vs-fault-rate curve.
"""
from __future__ import annotations

from benchmarks.common import Scale, build_image_session, collect_report, emit
from repro.core.sharding import even_requests
from repro.faults import FaultPlan
from repro.service import (RetryPolicy, UnlearningService, sequenced_trace,
                           single_device_placement)

FAULT_SEED = 7


def _plans(seed: int):
    return [
        ("clean", None),
        ("erase1", FaultPlan(seed).add("slice_erasure", count=1)),
        ("erase3", FaultPlan(seed).add("slice_erasure", count=3)),
        ("corrupt1", FaultPlan(seed).add("slice_corruption", count=1)),
        ("corrupt2", FaultPlan(seed).add("slice_corruption", count=2)),
        ("chaos", FaultPlan(seed)
         .add("slice_corruption", count=1)
         .add("slice_erasure", count=1)
         .add("job_exception", rate=0.5)),
    ]


def run(sc: Scale, rounds=None):
    session, _test = build_image_session(sc, iid=True)
    record = session.run_stage()
    plan = record.plan
    rounds = rounds or sc.global_rounds
    victims = even_requests(plan, plan.num_shards)
    trace = sequenced_trace(victims, spacing=0.0, rounds=rounds)

    def serve_once(fault_plan):
        placement = single_device_placement()
        svc = UnlearningService(session, policy="fifo", placement=placement,
                                faults=fault_plan,
                                retry=RetryPolicy(backoff=0.001))
        try:
            return svc.serve(trace)
        finally:
            placement.shutdown()
            for rec in session.records:
                if hasattr(rec.store, "attach_faults"):
                    rec.store.attach_faults(None)

    base_wall = None
    for name, fault_plan in _plans(FAULT_SEED):
        # warm up each plan's own decode/recovery shapes (distinct survivor
        # sets compile distinct programs), then measure the second serve
        serve_once(fault_plan)
        rep = serve_once(fault_plan)
        if base_wall is None:
            base_wall = rep.serve_wall
        overhead = (rep.serve_wall / base_wall - 1.0) if base_wall else 0.0
        f = rep.faults
        ledger = (fault_plan.ledger.kinds() if fault_plan is not None else {})
        emit(f"fig8_faults_{name}", rep.serve_wall * 1e6,
             f"requests={len(trace)};recoveries={f['recoveries']};"
             f"recovered_slices={f['recovered_slices']};"
             f"retries={f['retries']};aborts={f['aborts']};"
             f"overhead_vs_clean={overhead:.3f};"
             f"ledger={sum(ledger.values())}ev")
        collect_report(f"fig8_faults_{name}", rep)


if __name__ == "__main__":
    run(Scale())
