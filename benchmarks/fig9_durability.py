"""Fig. 9 (beyond the paper): durability — checkpoint overhead and
snapshot throughput.

Runs the same multi-stage session with interleaved unlearning requests
under three checkpoint cadences — ``off`` (no durability), ``every2``
(snapshot every other stage), and ``every1`` (snapshot per stage, the
crash-recovery default) — and measures what the write-ahead journal plus
snapshot commits cost relative to the bare run.  A second pass
microbenchmarks the snapshot path itself: ``save_snapshot`` /
``load_snapshot`` throughput on the captured session state (coded bf16
slices included) and the end-to-end resume (newest-good snapshot ->
restored session).  Emitted as ``BENCH_fig9.json`` through the standard
``--json-dir`` flow.
"""
from __future__ import annotations

import os
import shutil
import tempfile
import time

from benchmarks.common import Scale, build_image_session, collect_report, emit
from repro.durability import CheckpointManager, load_snapshot, save_snapshot
from repro.durability.session_state import capture_session, restore_session
from repro.fl.experiment import RequestSchedule, UnlearnRequest

SAVE_REPS = 3


def _schedule(num_stages: int) -> RequestSchedule:
    return RequestSchedule([
        UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                       after_stage=k, rounds=1)
        for k in range(num_stages)])


def _dir_bytes(path: str) -> int:
    return sum(os.path.getsize(os.path.join(path, f))
               for f in os.listdir(path))


def run(sc: Scale, num_stages: int = 2):
    tmp = tempfile.mkdtemp(prefix="fig9-durability-")
    summary = {"num_stages": num_stages, "cadences": {}}
    try:
        # warm-up run: pay the train/unlearn JIT compiles once so the
        # cadence walls compare checkpointing cost, not compile order
        warm, _test = build_image_session(sc, iid=True)
        warm.run(num_stages, schedule=_schedule(num_stages))
        base_wall = None
        last_ckpt = None
        for label, every in (("off", 0), ("every2", 2), ("every1", 1)):
            ckpt = os.path.join(tmp, label)
            session, _test = build_image_session(
                sc, iid=True,
                checkpoint_every=every,
                checkpoint_dir=ckpt if every else None)
            t0 = time.perf_counter()
            session.run(num_stages, schedule=_schedule(num_stages))
            wall = time.perf_counter() - t0
            if base_wall is None:
                base_wall = wall
            snaps = session.checkpointer.steps() if every else []
            disk = _dir_bytes(ckpt) if every else 0
            overhead = wall / base_wall - 1.0 if base_wall else 0.0
            emit(f"fig9_durability_{label}", wall * 1e6,
                 f"stages={num_stages};snapshots={len(snaps)};"
                 f"disk_bytes={disk};overhead_vs_off={overhead:.3f}")
            summary["cadences"][label] = {
                "checkpoint_every": every, "wall_s": wall,
                "snapshots": len(snaps), "disk_bytes": disk,
                "overhead_vs_off": overhead,
            }
            if every == 1:
                last_ckpt = ckpt

        # ---- snapshot write/restore throughput on the captured state ----
        session, _test = build_image_session(sc, iid=True)
        session.run(num_stages, schedule=_schedule(num_stages))
        state = capture_session(session)
        spath = os.path.join(tmp, "micro.ckpt")
        nbytes = save_snapshot(spath, state)           # warm-up + size
        t0 = time.perf_counter()
        for _ in range(SAVE_REPS):
            save_snapshot(spath, state)
        save_us = (time.perf_counter() - t0) / SAVE_REPS * 1e6
        t0 = time.perf_counter()
        for _ in range(SAVE_REPS):
            load_snapshot(spath)
        load_us = (time.perf_counter() - t0) / SAVE_REPS * 1e6
        save_mbs = nbytes / (save_us / 1e6) / 1e6
        load_mbs = nbytes / (load_us / 1e6) / 1e6
        emit("fig9_snapshot_save", save_us,
             f"bytes={nbytes};throughput_mb_s={save_mbs:.1f}")
        emit("fig9_snapshot_load", load_us,
             f"bytes={nbytes};throughput_mb_s={load_mbs:.1f}")

        # ---- end-to-end resume: newest good snapshot -> live session ----
        fresh, _test = build_image_session(sc, iid=True)
        t0 = time.perf_counter()
        got = CheckpointManager(last_ckpt).load_latest()
        restore_session(fresh, got[0])
        resume_us = (time.perf_counter() - t0) * 1e6
        emit("fig9_resume_restore", resume_us,
             f"from_step={got[1]};stages_restored={len(fresh.records)}")
        summary["snapshot"] = {
            "bytes": nbytes, "save_us": save_us, "load_us": load_us,
            "save_mb_s": save_mbs, "load_mb_s": load_mbs,
            "resume_us": resume_us,
        }
        collect_report("fig9_durability", summary)
    finally:
        shutil.rmtree(tmp, ignore_errors=True)


if __name__ == "__main__":
    run(Scale())
