"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path cost)
vs the pure-jnp oracle (XLA-compiled), plus the coded encode/decode end-to-end
on a realistic parameter payload. On-TPU wall times come from the same harness
with interpret=False."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import coding
from repro.kernels.calibrate.ops import calibrate_update
from repro.kernels.calibrate.ref import calibrate_update_ref
from repro.kernels.coded_matmul.ops import coded_matmul
from repro.kernels.coded_matmul.ref import coded_matmul_ref
from repro.kernels.window_attn.ops import window_attention
from repro.kernels.window_attn.ref import window_attention_ref


def _time(fn, *args, iters: int = 3) -> float:
    """Median of per-iteration wall times (robust to scheduler noise in
    shared/containerized environments)."""
    out = fn(*args)
    jax.block_until_ready(out)
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts)) * 1e6


def run(_sc=None):
    rng = np.random.default_rng(0)
    # coded matmul: C=100 clients, S=4 shards, 1M-param payload
    b = jnp.asarray(rng.standard_normal((100, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 1_000_000)), jnp.float32)
    emit("kernel_coded_matmul_ref", _time(jax.jit(coded_matmul_ref), b, w),
         "C=100;S=4;P=1e6")
    emit("kernel_coded_matmul_pallas", _time(coded_matmul, b, w),
         "interpret-mode on CPU")

    # calibrate: M=5 retained clients, 1M params
    wv = jnp.asarray(rng.standard_normal(1_000_000), jnp.float32)
    d = jnp.asarray(rng.standard_normal((5, 1_000_000)), jnp.float32)
    c = jnp.asarray(rng.standard_normal(5), jnp.float32)
    emit("kernel_calibrate_ref", _time(jax.jit(calibrate_update_ref), wv, d, c),
         "M=5;P=1e6")
    emit("kernel_calibrate_pallas", _time(calibrate_update, wv, d, c),
         "interpret-mode on CPU")

    # window attention: S=1024, window=256
    q = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1024, 2, 64)), jnp.float32)
    emit("kernel_window_attn_pallas",
         _time(lambda a, b_, c_: window_attention(a, b_, c_, 256), q, k, v),
         "S=1024;w=256;interpret")
    qf = q.transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    kf = jnp.repeat(k, 2, 2).transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    vf = jnp.repeat(v, 2, 2).transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    emit("kernel_window_attn_ref",
         _time(jax.jit(lambda a, b_, c_: window_attention_ref(a, b_, c_, 256)),
               qf, kf, vf), "O(S^2) oracle")

    # end-to-end coded store round-trip at paper scale
    sch = coding.CodingScheme(num_shards=4, num_clients=100)
    wmat = jnp.asarray(rng.standard_normal((4, 500_000)), jnp.float32)
    enc_us = _time(lambda m: coding.encode(sch, m), wmat)
    slices = coding.encode(sch, wmat)
    ids = list(range(0, 100, 25))
    dec_us = _time(lambda s_: coding.decode_erasure(sch, s_[jnp.asarray(ids)],
                                                    ids), slices)
    emit("coding_encode_e2e", enc_us, "C=100;S=4;P=5e5")
    emit("coding_decode_e2e", dec_us, "any-4-of-100 slices")

    # bf16 coded-slice storage (half the client bytes, one extra cast)
    emit("coding_encode_bf16",
         _time(lambda m: coding.encode(sch, m, out_dtype=jnp.bfloat16), wmat),
         "C=100;S=4;P=5e5;bf16-slices")

    # batched multi-round encode: G eager per-round encodes (each rebuilding
    # the coefficient matrix + one dispatch) vs ONE jitted multi-round
    # program — the paper's G=30 history setting
    g_rounds = 30
    mats = [jnp.asarray(rng.standard_normal((4, 20_000)), jnp.float32)
            for _ in range(g_rounds)]

    def encode_per_round(ms):
        return [coding.encode(sch, m) for m in ms]

    per_us = _time(encode_per_round, mats, iters=10)
    bat_us = _time(lambda ms: coding.encode_batched(sch, ms), mats, iters=10)
    emit("coding_encode_per_round", per_us, f"G={g_rounds};C=100;S=4;P=2e4")
    emit("coding_encode_batched", bat_us,
         f"G={g_rounds} rounds one dispatch;speedup={per_us / bat_us:.2f}x")

    # fused encode->decode round-trip (slice verification path): two full
    # passes vs the precomposed (S,S) operator (kernel path: D@(B@w) tiles)
    ed_two = _time(lambda m: coding.decode_erasure(
        sch, coding.encode(sch, m), list(range(100))), wmat, iters=10)
    ed_fused = _time(lambda m: coding.encode_decode(sch, m), wmat, iters=10)
    emit("coding_encode_decode_two_pass", ed_two, "C=100;S=4;P=5e5")
    emit("coding_encode_decode_fused", ed_fused,
         f"(D@B)@w one pass;speedup={ed_two / ed_fused:.2f}x")

    # stacked pytree flatten: one (M, P) pass vs M per-tree flattens
    m_clients = 20
    key = jax.random.key(0)
    stacked = {f"layer{i}": jax.random.normal(jax.random.fold_in(key, i),
                                              (m_clients, 64, 100), jnp.float32)
               for i in range(8)}
    per_trees = [jax.tree.map(lambda a, i=i: a[i], stacked)
                 for i in range(m_clients)]

    def flatten_per_tree(trees):
        return jnp.stack([coding.tree_to_flat(t)[0] for t in trees])

    flat_per_us = _time(flatten_per_tree, per_trees, iters=10)
    flat_stk_us = _time(lambda t: coding.tree_to_flat_stacked(t)[0], stacked, iters=10)
    emit("coding_flatten_per_tree", flat_per_us, f"M={m_clients};8 leaves;P=5e5")
    emit("coding_flatten_stacked", flat_stk_us,
         f"one-pass (M,P);speedup={flat_per_us / flat_stk_us:.2f}x")


if __name__ == "__main__":
    run()
