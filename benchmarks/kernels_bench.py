"""Kernel micro-benchmarks: Pallas (interpret on CPU — correctness-path cost)
vs the pure-jnp oracle (XLA-compiled), plus the coded encode/decode end-to-end
on a realistic parameter payload. On-TPU wall times come from the same harness
with interpret=False."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import coding
from repro.kernels.calibrate.ops import calibrate_update
from repro.kernels.calibrate.ref import calibrate_update_ref
from repro.kernels.coded_matmul.ops import coded_matmul
from repro.kernels.coded_matmul.ref import coded_matmul_ref
from repro.kernels.window_attn.ops import window_attention
from repro.kernels.window_attn.ref import window_attention_ref


def _time(fn, *args, iters: int = 3) -> float:
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run(_sc=None):
    rng = np.random.default_rng(0)
    # coded matmul: C=100 clients, S=4 shards, 1M-param payload
    b = jnp.asarray(rng.standard_normal((100, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((4, 1_000_000)), jnp.float32)
    emit("kernel_coded_matmul_ref", _time(jax.jit(coded_matmul_ref), b, w),
         "C=100;S=4;P=1e6")
    emit("kernel_coded_matmul_pallas", _time(coded_matmul, b, w),
         "interpret-mode on CPU")

    # calibrate: M=5 retained clients, 1M params
    wv = jnp.asarray(rng.standard_normal(1_000_000), jnp.float32)
    d = jnp.asarray(rng.standard_normal((5, 1_000_000)), jnp.float32)
    c = jnp.asarray(rng.standard_normal(5), jnp.float32)
    emit("kernel_calibrate_ref", _time(jax.jit(calibrate_update_ref), wv, d, c),
         "M=5;P=1e6")
    emit("kernel_calibrate_pallas", _time(calibrate_update, wv, d, c),
         "interpret-mode on CPU")

    # window attention: S=1024, window=256
    q = jnp.asarray(rng.standard_normal((1, 1024, 4, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 1024, 2, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 1024, 2, 64)), jnp.float32)
    emit("kernel_window_attn_pallas",
         _time(lambda a, b_, c_: window_attention(a, b_, c_, 256), q, k, v),
         "S=1024;w=256;interpret")
    qf = q.transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    kf = jnp.repeat(k, 2, 2).transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    vf = jnp.repeat(v, 2, 2).transpose(0, 2, 1, 3).reshape(4, 1024, 64)
    emit("kernel_window_attn_ref",
         _time(jax.jit(lambda a, b_, c_: window_attention_ref(a, b_, c_, 256)),
               qf, kf, vf), "O(S^2) oracle")

    # end-to-end coded store round-trip at paper scale
    sch = coding.CodingScheme(num_shards=4, num_clients=100)
    wmat = jnp.asarray(rng.standard_normal((4, 500_000)), jnp.float32)
    enc_us = _time(lambda m: coding.encode(sch, m), wmat)
    slices = coding.encode(sch, wmat)
    ids = list(range(0, 100, 25))
    dec_us = _time(lambda s_: coding.decode_erasure(sch, s_[jnp.asarray(ids)],
                                                    ids), slices)
    emit("coding_encode_e2e", enc_us, "C=100;S=4;P=5e5")
    emit("coding_decode_e2e", dec_us, "any-4-of-100 slices")


if __name__ == "__main__":
    run()
