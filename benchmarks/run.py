"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--only fig3,...] [--full] [--fast]
"""
from __future__ import annotations

import argparse
import sys
import time


def main(argv=None) -> None:
    sys.path.insert(0, "src")
    from benchmarks import (fig3_single_request, fig4_concurrent, fig5_storage,
                            fig6_round_engine, kernels_bench, table1_f1_time,
                            theory_check)
    from benchmarks.common import Scale, emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig5,fig6,table1,theory,kernels")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (100 clients, G=30, L=10) — slow on CPU")
    ap.add_argument("--fast", action="store_true",
                    help="minimal scale for CI")
    args = ap.parse_args(argv)

    sc = Scale.full() if args.full else Scale()
    if args.fast:
        sc = Scale(num_clients=8, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=2, samples_per_client=40,
                   image_size=12, seq_len=32, test_n=120)

    suites = {
        "theory": theory_check.run,
        "kernels": kernels_bench.run,
        "fig3": fig3_single_request.run,
        "fig4": fig4_concurrent.run,
        "fig5": fig5_storage.run,
        "fig6": fig6_round_engine.run,
        "table1": table1_f1_time.run,
    }
    only = args.only.split(",") if args.only else list(suites)
    t0 = time.time()
    for name in only:
        print(f"# --- {name} ---", flush=True)
        suites[name](sc)
    emit("bench_total_wall", (time.time() - t0) * 1e6, f"suites={len(only)}")


if __name__ == "__main__":
    main()
