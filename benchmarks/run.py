"""Benchmark driver — one module per paper table/figure. Prints
``name,us_per_call,derived`` CSV rows; ``--json-dir`` additionally writes one
machine-readable ``BENCH_<suite>.json`` per suite (the suite's rows plus any
session/unlearn trajectories collected via ``common.collect_report``).

    PYTHONPATH=src python -m benchmarks.run [--only fig3,...] [--full]
        [--fast] [--json-dir out/]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _parse_row(row: str) -> dict:
    name, us, derived = row.split(",", 2)
    return {"name": name, "us_per_call": float(us), "derived": derived}


def main(argv=None) -> None:
    sys.path.insert(0, "src")
    from benchmarks import (fig3_single_request, fig4_concurrent, fig5_storage,
                            fig6_round_engine, fig7_service, fig8_faults,
                            fig9_durability, fig10_telemetry, fig11_tiering,
                            kernels_bench, table1_f1_time, theory_check,
                            verify_bench)
    from benchmarks import common
    from benchmarks.common import Scale, emit

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,fig4,fig5,fig6,fig7,fig8,fig9,"
                         "fig10,fig11,table1,verify,theory,kernels")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale (100 clients, G=30, L=10) — slow on CPU")
    ap.add_argument("--fast", action="store_true",
                    help="minimal scale for CI")
    ap.add_argument("--json-dir", default=None,
                    help="write BENCH_<suite>.json per suite to this directory")
    ap.add_argument("--trace-summary", action="store_true",
                    help="run the suites under the span tracer and print the "
                         "aggregated span tree at the end")
    args = ap.parse_args(argv)

    if args.trace_summary:
        from repro.telemetry import configure
        configure(enabled=True)

    sc = Scale.full() if args.full else Scale()
    if args.fast:
        sc = Scale(num_clients=8, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=2, samples_per_client=40,
                   image_size=12, seq_len=32, test_n=120)

    suites = {
        "theory": theory_check.run,
        "kernels": kernels_bench.run,
        "fig3": fig3_single_request.run,
        "fig4": fig4_concurrent.run,
        "fig5": fig5_storage.run,
        "fig6": fig6_round_engine.run,
        "fig7": fig7_service.run,
        "fig8": fig8_faults.run,
        "fig9": fig9_durability.run,
        "fig10": fig10_telemetry.run,
        "fig11": fig11_tiering.run,
        "table1": table1_f1_time.run,
        "verify": verify_bench.run,
    }
    only = args.only.split(",") if args.only else list(suites)
    if args.json_dir:
        os.makedirs(args.json_dir, exist_ok=True)
    t0 = time.time()
    for name in only:
        print(f"# --- {name} ---", flush=True)
        rows_before = len(common.ROWS)
        reports_before = set(common.REPORTS)
        t_suite = time.time()
        suites[name](sc)
        if args.json_dir:
            payload = {
                "suite": name,
                "wall_s": time.time() - t_suite,
                "scale": vars(sc),
                "rows": [_parse_row(r) for r in common.ROWS[rows_before:]],
                "reports": {k: v for k, v in common.REPORTS.items()
                            if k not in reports_before},
            }
            path = os.path.join(args.json_dir, f"BENCH_{name}.json")
            with open(path, "w") as f:
                json.dump(payload, f, indent=2)
            print(f"# wrote {path}", flush=True)
    emit("bench_total_wall", (time.time() - t0) * 1e6, f"suites={len(only)}")
    if args.trace_summary:
        from repro.telemetry import get_tracer, render_tree
        print("# --- trace summary ---", flush=True)
        print(render_tree(get_tracer()), flush=True)


if __name__ == "__main__":
    main()
