"""Paper Table 1: MIA F1 score (down = better unlearning) and retraining time
for IID and non-IID distributions, both tasks, all four frameworks."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Scale, build_image_sim, build_lm_sim, emit
from repro.fl.mia import mia_f1

FRAMEWORKS = ("FR", "FE", "RR", "SE")


def run(sc: Scale, tasks=("image", "lm"), iids=(True, False)):
    for task in tasks:
        for iid in iids:
            tag = f"table1_{task}_{'iid' if iid else 'noniid'}"
            sim, test = (build_image_sim if task == "image" else build_lm_sim)(
                sc, iid=iid)
            record = sim.train_stage(store_kind="coded")
            victim = record.plan.shard_clients[0][0]
            members = [c for c in record.plan.clients if c != victim][:6]
            mx = np.concatenate([sim.client_data[c][0][:40] for c in members])
            my = np.concatenate([sim.client_data[c][1][:40] for c in members])
            for fw in FRAMEWORKS:
                res = sim.unlearn(fw, record, [victim])
                f1 = mia_f1(sim._pf, res.models, sim._make_batch, sim.task,
                            (mx, my), test, sim.client_data[victim])
                emit(f"{tag}_{fw}", res.wall_time * 1e6,
                     f"mia_f1={f1:.4f};retrain_s={res.wall_time:.2f};"
                     f"cost_units={res.cost_units:.0f}")
            fr = sim.unlearn("FR", record, [victim])
            se = sim.unlearn("SE", record, [victim])
            emit(f"{tag}_time_gain", 0.0,
                 f"gain={1 - se.cost_units / max(fr.cost_units, 1e-9):.2%}")


if __name__ == "__main__":
    run(Scale())
