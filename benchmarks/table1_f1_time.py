"""Paper Table 1: MIA F1 score (down = better unlearning) and retraining time
for IID and non-IID distributions, both tasks, all four registered frameworks
— driven through the forgetting-verification suite, so the reported F1 is the
shadow-model attack (calibrated without victim labels) scored against the
no-unlearn baseline and the retrain oracle, and the full Pareto report lands
in ``run.py --json-dir`` output."""
from __future__ import annotations

from benchmarks.common import (Scale, _partitioner, collect_report, emit,
                               scenario_config)
from repro.fl.experiment import FRAMEWORKS
from repro.verify import run_verification

FRAMEWORK_ORDER = ("FR", "FE", "RR", "SE")
assert all(fw in FRAMEWORKS for fw in FRAMEWORK_ORDER)

TASK_TAGS = {"classification": "image", "generation": "lm"}


def run(sc: Scale, tasks=("classification", "generation"), iids=(True, False)):
    for task in tasks:
        for iid in iids:
            tag = f"table1_{TASK_TAGS[task]}_{'iid' if iid else 'noniid'}"
            cfg = scenario_config(sc, task=task,
                                  partitioner=_partitioner(iid, task), seed=0)
            # Table 1's data protocol: shadow-MIA + utility, no canaries
            report = run_verification(cfg, frameworks=FRAMEWORK_ORDER,
                                      verifiers=("shadow-mia", "utility"),
                                      n_shadows=2)
            for name in FRAMEWORK_ORDER + ("oracle", "none"):
                c = report.candidate(name)
                emit(f"{tag}_{name}", c.wall_s * 1e6,
                     f"mia_f1={c.metrics['mia_f1']:.4f};"
                     f"retrain_s={c.wall_s:.2f};"
                     f"cost_units={c.cost_units:.0f}")
            cost = {c.name: c.cost_units for c in report.candidates}
            emit(f"{tag}_time_gain", 0.0,
                 f"gain={1 - cost['SE'] / max(cost['FR'], 1e-9):.2%}")
            collect_report(tag, report)


if __name__ == "__main__":
    run(Scale())
