"""Paper Table 1: MIA F1 score (down = better unlearning) and retraining time
for IID and non-IID distributions, both tasks, all four registered frameworks
— driven through ``FederatedSession`` so the per-request trajectory lands in
the session report (exported by ``run.py --json-dir``)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import (Scale, build_image_session, build_lm_session,
                               collect_report, emit)
from repro.fl.experiment import FRAMEWORKS, UnlearnRequest
from repro.fl.mia import mia_f1

FRAMEWORK_ORDER = ("FR", "FE", "RR", "SE")
assert all(fw in FRAMEWORKS for fw in FRAMEWORK_ORDER)


def run(sc: Scale, tasks=("image", "lm"), iids=(True, False)):
    for task in tasks:
        for iid in iids:
            tag = f"table1_{task}_{'iid' if iid else 'noniid'}"
            session, test = (build_image_session if task == "image"
                             else build_lm_session)(sc, iid=iid)
            sim = session.sim
            record = session.run_stage()
            victim = record.plan.shard_clients[0][0]
            members = [c for c in record.plan.clients if c != victim][:6]
            mx = np.concatenate([sim.client_data[c][0][:40] for c in members])
            my = np.concatenate([sim.client_data[c][1][:40] for c in members])
            cost = {}
            for fw in FRAMEWORK_ORDER:
                res = session.unlearn(UnlearnRequest([victim],
                                                     framework=fw))[0]
                cost[fw] = res.cost_units
                f1 = mia_f1(sim._pf, res.models, sim._make_batch, sim.task,
                            (mx, my), test, sim.client_data[victim])
                emit(f"{tag}_{fw}", res.wall_time * 1e6,
                     f"mia_f1={f1:.4f};retrain_s={res.wall_time:.2f};"
                     f"cost_units={res.cost_units:.0f}")
            emit(f"{tag}_time_gain", 0.0,
                 f"gain={1 - cost['SE'] / max(cost['FR'], 1e-9):.2%}")
            collect_report(tag, session.report)


if __name__ == "__main__":
    run(Scale())
