"""CI telemetry smoke: trace a tiny session + service run end-to-end and
validate every exported artifact.

    PYTHONPATH=src python -m benchmarks.telemetry_smoke [out_dir]

Runs two checkpointed training stages and a FIFO-served unlearning trace
under the span tracer, then asserts:

* span coverage — stage training, the fused XLA dispatch, coded-store
  writes/reads, snapshot + journal I/O, service planning/dispatch, and the
  unlearning retrain programs all produced spans;
* the Chrome/Perfetto ``trace.json`` validates against the trace-event
  schema (and is written to ``out_dir`` for the CI artifact upload);
* the service's hash-chained audit log verifies end-to-end AND re-deriving
  the chain from the write-ahead journal alone yields the same head — the
  resume/splice invariant;
* the ``ServiceReport`` carries its telemetry section.

Exits non-zero on the first failed check.
"""
from __future__ import annotations

import json
import os
import sys
import tempfile

REQUIRED_SPANS = {
    "session.stage", "stage.train", "xla.stage_program", "store.put_stage",
    "store.read", "durability.snapshot", "durability.journal_append",
    "service.plan", "service.serve", "service.dispatch", "service.job",
    "unlearn.dispatch", "unlearn.shard",
}


def main(argv=None) -> int:
    sys.path.insert(0, "src")
    args = list(sys.argv[1:] if argv is None else argv)
    out_dir = args[0] if args else "."

    from benchmarks.common import Scale, build_image_sim
    from repro.core.sharding import even_requests
    from repro.durability import Journal
    from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                     UnlearnRequest)
    from repro.service import (UnlearningService, sequenced_trace,
                               single_device_placement)
    from repro.telemetry import (NULL_TRACER, configure, get_tracer,
                                 render_tree, set_tracer,
                                 validate_chrome_trace, verify_journal,
                                 write_chrome_trace)

    failures = []

    def check(ok: bool, what: str):
        print(f"[telemetry-smoke] {'ok  ' if ok else 'FAIL'} {what}",
              flush=True)
        if not ok:
            failures.append(what)

    sc = Scale(num_clients=8, clients_per_round=8, num_shards=2,
               local_epochs=2, global_rounds=2, samples_per_client=40,
               image_size=12, seq_len=32, test_n=120)
    configure(enabled=True)
    with tempfile.TemporaryDirectory() as tmp:
        sim, _ = build_image_sim(sc, iid=True)
        session = FederatedSession(sim, store_kind="coded", engine="stage",
                                   checkpoint_every=1, checkpoint_dir=tmp)
        # two checkpointed stages with one scheduled SE request after stage 0
        # — covers snapshot I/O, the session unlearning dispatch, and the
        # session's own audit chain alongside the service's
        schedule = RequestSchedule([
            UnlearnRequest(lambda p: [p.shard_clients[0][0]],
                           framework="SE", after_stage=0)])
        session.run(2, schedule=schedule)
        sess_head = session.audit.verify()
        check(bool(sess_head) and len(session.audit) >= 3,
              f"session audit chain verifies ({len(session.audit)} events)")
        check(verify_journal(session.checkpointer.journal) == sess_head,
              "session journal replay re-derives the same audit head")

        plan = session.records[0].plan
        victims = even_requests(plan, plan.num_shards)
        trace = sequenced_trace(victims, spacing=0.0, rounds=sc.global_rounds)
        journal = Journal(os.path.join(tmp, "svc.journal"))
        svc = UnlearningService(session, policy="fifo",
                                placement=single_device_placement(),
                                journal=journal)
        report = svc.serve(trace)

        tr = get_tracer()
        missing = REQUIRED_SPANS - set(tr.span_names())
        check(not missing, f"span coverage (missing: {sorted(missing)})")

        os.makedirs(out_dir, exist_ok=True)
        trace_path = os.path.join(out_dir, "trace.json")
        write_chrome_trace(tr, trace_path)
        with open(trace_path) as f:
            obj = json.load(f)
        errors = validate_chrome_trace(obj)
        check(not errors, f"perfetto schema ({len(errors)} errors: "
                          f"{errors[:3]})")
        check(len(obj["traceEvents"]) > len(REQUIRED_SPANS),
              f"trace.json has {len(obj['traceEvents'])} events "
              f"({os.path.getsize(trace_path)} bytes) -> {trace_path}")

        head = svc.audit.verify()
        check(bool(head), f"service audit chain verifies (head {head[:12]}, "
                          f"{len(svc.audit)} events)")
        kinds = svc.audit.kinds()
        check({"received", "scheduled", "retrained",
               "committed"} <= set(kinds),
              f"audit lifecycle kinds {sorted(set(kinds))}")
        replayed = verify_journal(journal)
        check(replayed == head,
              "journal replay re-derives the same audit head")

        d = report.to_dict()
        check("telemetry" in d, "ServiceReport.to_dict has telemetry section")
        check(bool(d.get("client_latency_p99_s")),
              "per-client p99 latency populated")

        print(render_tree(tr), flush=True)

    set_tracer(NULL_TRACER)
    if failures:
        print(f"[telemetry-smoke] {len(failures)} check(s) failed",
              flush=True)
        return 1
    print("[telemetry-smoke] all checks passed", flush=True)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
