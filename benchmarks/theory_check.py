"""Sec 4 theory validation: eq. (9)/(10) closed forms vs Monte-Carlo, and the
eq. (12)/(13) storage-efficiency / throughput table."""
from __future__ import annotations

from benchmarks.common import emit
from repro.core import theory


def run(_sc=None):
    ct = 1.0
    for s in (2, 4, 8):
        for k in (1, 4, 16):
            seq_a = theory.sequential_time(s, k, ct)
            seq_m = theory.mc_sequential_time(s, k, ct)
            con_a = theory.concurrent_time(s, k, ct)
            con_m = theory.mc_concurrent_time(s, k, ct)
            emit(f"theory_S{s}_K{k}", 0.0,
                 f"eq9={seq_a:.3f};mc={seq_m:.3f};"
                 f"eq10={con_a:.3f};mc10={con_m:.3f};"
                 f"err={abs(con_a - con_m) / con_a:.3%}")
    for c, s, mu in ((100, 4, 0.1), (100, 8, 0.2), (1000, 16, 0.1)):
        lo, hi = theory.storage_efficiency_bounds(c, s, mu)
        emit(f"theory_eq12_C{c}_S{s}_mu{mu}", 0.0,
             f"gamma_lo={lo:.0f};gamma_hi={hi:.0f}")
        emit(f"theory_eq13_C{c}_S{s}", 0.0,
             f"lambda_c={theory.coded_throughput(c, s):.3e}")


if __name__ == "__main__":
    run()
