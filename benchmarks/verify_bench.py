"""Forgetting-verification suite benchmark: shadow-model MIA, canary
injection, and the retrain oracle, per framework — the forgetting × utility ×
cost Pareto report (``BENCH_verify.json`` via ``run.py --json-dir``).

The scenario is pushed into the memorization regime (more local epochs,
higher lr, fewer samples per client than the figure benchmarks) — both
probes measure *memorization residue*, so the victim stage must overfit its
clients for the no-unlearn baseline to separate from the oracle.  CI's
``--fast`` run covers SE and FR on the classification task; the default
scale adds FE/RR and the generation task.
"""
from __future__ import annotations

from benchmarks.common import Scale, collect_report, emit, scenario_config
from repro.verify import run_verification

# per-task memorization-regime overrides (classification tuned so the
# no-unlearn canary accuracy sits far above chance at tiny scale)
OVERRIDES = {
    "classification": dict(lr=0.3, noise=0.35),
    "generation": dict(),
}


def run(sc: Scale):
    small = sc.num_clients < 20
    frameworks = ("SE", "FR") if small else ("SE", "FE", "FR", "RR")
    n_shadows = 2 if small else 3
    tasks = ["classification"] + ([] if small else ["generation"])
    for task in tasks:
        cfg = scenario_config(
            sc, task=task, partitioner="iid", seed=0,
            local_epochs=max(sc.local_epochs, 8),
            global_rounds=max(sc.global_rounds, 6),
            samples_per_client=min(sc.samples_per_client, 32),
            **OVERRIDES[task])
        report = run_verification(cfg, frameworks=frameworks,
                                  n_shadows=n_shadows, n_canaries=12)
        tag = f"verify_{task}"
        for c in report.candidates:
            emit(f"{tag}_{c.name}", c.wall_s * 1e6,
                 f"mia_f1={c.metrics['mia_f1']:.4f};"
                 f"canary_acc={c.metrics['canary_acc']:.4f};"
                 f"retain_acc={c.metrics['retain_acc']:.4f};"
                 f"cost_units={c.cost_units:.0f}")
        emit(f"{tag}_pareto", 0.0,
             "front=" + "|".join(report.pareto_front()))
        collect_report(tag, report)


if __name__ == "__main__":
    run(Scale())
