"""Coded computing walkthrough (paper Sec 3.3): Lagrange-encode a round of
per-shard parameters into client slices, then reconstruct under (a) full
availability, (b) erasures (clients offline), (c) Byzantine corruption —
showing the eq. (11) tolerance in action. Uses the Pallas coded_matmul kernel
(interpret mode on CPU).

    PYTHONPATH=src python examples/coded_storage.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding


def main():
    C, S, P = 24, 4, 100_000
    scheme = coding.CodingScheme(num_shards=S, num_clients=C)
    rng = np.random.default_rng(0)
    shard_params = jnp.asarray(rng.standard_normal((S, P)), jnp.float32)

    print(f"== encode: S={S} shard vectors -> C={C} coded client slices ==")
    slices = coding.encode(scheme, shard_params, use_kernel=True)
    print(f"   slice matrix: {slices.shape}, "
          f"server stores only the {C} interpolation keys")
    print(f"   error tolerance (eq. 11): up to {scheme.max_errors} "
          f"corrupted slices")

    print("== (a) decode from any S slices ==")
    ids = [1, 7, 13, 22]
    rec = coding.decode_erasure(scheme, slices[jnp.asarray(ids)], ids,
                                use_kernel=True)
    print(f"   max |error| = {float(jnp.abs(rec - shard_params).max()):.2e}")

    print(f"== (b) erasures: only 6 of {C} clients reachable ==")
    avail = [0, 4, 9, 15, 18, 23]
    rec = coding.decode_erasure(scheme, slices[jnp.asarray(avail)], avail)
    print(f"   max |error| = {float(jnp.abs(rec - shard_params).max()):.2e}")

    print("== (c) corruption: 3 Byzantine clients send garbage ==")
    bad = [2, 11, 19]
    corrupted = np.array(slices)
    corrupted[bad] += rng.standard_normal((len(bad), P)) * 10
    rec, located = coding.decode_with_errors(scheme, jnp.asarray(corrupted))
    print(f"   Berlekamp-Welch located bad clients: {located.tolist()} "
          f"(truth: {bad})")
    print(f"   max |error| = {float(jnp.abs(rec - shard_params).max()):.2e}")


if __name__ == "__main__":
    main()
