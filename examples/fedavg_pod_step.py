"""The pod-scale FedAvg/unlearning step at CPU scale: runs the SAME jittable
step functions the 256-chip dry-run lowers (client-serial FedAvg round, then
one eq.-3 calibration round), on a reduced architecture — proving the
production step semantics end-to-end with real numbers.

    PYTHONPATH=src python examples/fedavg_pod_step.py [--arch granite-moe-1b-a400m]
"""
import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import FLConfig, OptimizerConfig, get_config, reduce_for_smoke
from repro.launch.train import (make_calibration_step, make_fedavg_step)
from repro.models import init_params
from repro.optim import init_optimizer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-moe-1b-a400m")
    ap.add_argument("--rounds", type=int, default=6)
    args = ap.parse_args()

    cfg = reduce_for_smoke(get_config(args.arch))
    fl = FLConfig(fl_clients_per_step=4, fl_local_steps=2)
    opt = OptimizerConfig(name="adamw", lr=2e-3)
    params = init_params(cfg, jax.random.key(0))
    state = (params, init_optimizer(opt, params))

    step = jax.jit(make_fedavg_step(cfg, fl, opt))
    rng = np.random.default_rng(0)

    def make_batch():
        toks = rng.integers(0, cfg.vocab_size, (4, 2, 64))
        b = {"tokens": jnp.asarray(toks, jnp.int32),
             "labels": jnp.asarray(toks, jnp.int32)}
        if cfg.family == "vlm":
            b["patches"] = jnp.zeros((4, 2, cfg.vision_tokens, cfg.d_model),
                                     jnp.float32)
        if cfg.family == "audio":
            b["frames"] = jnp.zeros((4, 2, 64, cfg.d_model), jnp.float32)
        return b

    print(f"== {args.rounds} FedAvg rounds ({cfg.name}, 4 clients x 2 local steps) ==")
    norms = []
    for i in range(args.rounds):
        state, mets = step(state, make_batch())
        norms.append(float(mets["delta_norm"]))
        print(f"   round {i}: loss={float(mets['loss']):.4f} "
              f"|mean delta|={norms[-1]:.4f}")

    print("== one calibrated retraining round (eq. 3) ==")
    cal = jax.jit(make_calibration_step(cfg, fl))
    stored_norms = jnp.asarray([norms[-1]] * 4, jnp.float32)
    new_params, mets = cal(state[0], make_batch(), stored_norms)
    print(f"   calibration loss={float(mets['loss']):.4f} "
          f"(delta rescaled to historical norms)")


if __name__ == "__main__":
    main()
