"""Quickstart: the paper's full loop in ~60 seconds on CPU, on the
experiment API.

One ``ScenarioConfig`` describes the federation; ``FederatedSession`` trains
the paper's CNN across isolated shards with coded parameter storage, serves
an unlearning request with SE (and the FR gold standard for comparison), and
a membership-inference attack checks the victim is actually forgotten.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.fl.experiment import ScenarioConfig, UnlearnRequest, build_session
from repro.fl.mia import mia_f1


def main():
    cfg = ScenarioConfig(task="classification", num_clients=12,
                         clients_per_round=8,
                         num_shards=2, local_epochs=4, global_rounds=5,
                         samples_per_client=100, image_size=14, test_n=400,
                         store="coded")
    session, (test_x, test_y) = build_session(cfg)
    sim = session.sim

    print("== train: 2 isolated shards, coded parameter store ==")
    record = session.run_stage()
    base = sim.evaluate(record.shard_models, test_x, test_y)
    print(f"   shard-ensemble accuracy: {base['acc']:.3f}")
    st = record.store.stats
    print(f"   server storage: {st.server_bytes} B (keys only); "
          f"coded slices on clients: {st.client_bytes / 1e6:.1f} MB")

    victim = record.plan.shard_clients[0][0]
    print(f"== unlearn client {victim} (shard 0) ==")
    for fw in ("SE", "FR"):
        res = session.unlearn(UnlearnRequest([victim], framework=fw))[0]
        m = sim.evaluate(res.models, test_x, test_y)
        print(f"   {fw:3s}: acc={m['acc']:.3f}  cost={res.cost_units:.0f} "
              f"client-epochs  wall={res.wall_time:.1f}s  "
              f"impacted_shards={res.impacted_shards}")

    res = session.unlearn(UnlearnRequest([victim], framework="SE"))[0]
    members = [c for c in record.plan.clients if c != victim][:4]
    mx = np.concatenate([sim.client_data[c][0][:40] for c in members])
    my = np.concatenate([sim.client_data[c][1][:40] for c in members])
    iface = sim.predict_interface()
    f1 = mia_f1(iface.predict, res.models, iface.make_batch, iface.task,
                (mx, my), (test_x, test_y), sim.client_data[victim])
    print("== membership-inference attack on the forgotten client ==")
    print(f"   attack F1 = {f1:.3f} (lower = better forgotten)")

    print("== session report (JSON excerpt) ==")
    report = session.report.to_dict()
    print(f"   stages={report['num_stages']} "
          f"train_wall={report['total_train_wall_s']:.1f}s "
          f"unlearn_wall={report['total_unlearn_wall_s']:.1f}s "
          f"cost_units={report['total_cost_units']:.0f}")


if __name__ == "__main__":
    main()
