"""Quickstart: the paper's full loop in ~60 seconds on CPU.

Trains the paper's CNN across a federation with isolated shards + coded
storage, serves an unlearning request with SE, and compares against the
FedRetrain gold standard.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses

from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import client_datasets_images, make_image_data
from repro.fl import FLSimulator
from repro.fl.mia import mia_f1

import numpy as np


def main():
    fl = FLConfig(num_clients=12, clients_per_round=8, num_shards=2,
                  local_epochs=4, global_rounds=5, retrain_ratio=2.0)
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=14,
                              d_model=48, cnn_channels=(8, 16))
    data = make_image_data(12 * 100, image_size=14, noise=0.25, seed=0)
    clients = client_datasets_images(data, fl.num_clients, iid=True)
    test = make_image_data(400, image_size=14, noise=0.25, seed=99)

    sim = FLSimulator(cfg, fl, clients, task="image",
                      opt_cfg=OptimizerConfig(name="sgd", lr=0.05,
                                              grad_clip=0.0), local_batch=20)

    print("== train: 2 isolated shards, coded parameter store ==")
    record = sim.train_stage(store_kind="coded")
    base = sim.evaluate(record.shard_models, test.images, test.labels)
    print(f"   shard-ensemble accuracy: {base['acc']:.3f}")
    st = record.store.stats
    print(f"   server storage: {st.server_bytes} B (keys only); "
          f"coded slices on clients: {st.client_bytes / 1e6:.1f} MB")

    victim = record.plan.shard_clients[0][0]
    print(f"== unlearn client {victim} (shard 0) ==")
    for fw in ("SE", "FR"):
        res = sim.unlearn(fw, record, [victim])
        m = sim.evaluate(res.models, test.images, test.labels)
        print(f"   {fw:3s}: acc={m['acc']:.3f}  cost={res.cost_units:.0f} "
              f"client-epochs  wall={res.wall_time:.1f}s  "
              f"impacted_shards={res.impacted_shards}")

    res = sim.unlearn("SE", record, [victim])
    members = [c for c in record.plan.clients if c != victim][:4]
    mx = np.concatenate([clients[c][0][:40] for c in members])
    my = np.concatenate([clients[c][1][:40] for c in members])
    f1 = mia_f1(sim._pf, res.models, sim._make_batch, "image",
                (mx, my), (test.images, test.labels), clients[victim])
    print(f"== membership-inference attack on the forgotten client ==")
    print(f"   attack F1 = {f1:.3f} (lower = better forgotten)")


if __name__ == "__main__":
    main()
