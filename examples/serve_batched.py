"""End-to-end serving driver: serve a small model with batched requests —
prefill a batch of prompts, decode autoregressively with the KV/state cache.
Runs each architecture family's reduced config to show the uniform serve API
(attention KV ring buffers, mamba states, rwkv states).

    PYTHONPATH=src python examples/serve_batched.py [--arch rwkv6-3b]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduce_for_smoke
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.models import init_params


def serve(arch: str, batch: int = 8, prompt_len: int = 48, gen: int = 32):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size, (batch, prompt_len)),
                       jnp.int32)
    b = {"tokens": toks}
    if cfg.family == "vlm":
        b["patches"] = jnp.zeros((batch, cfg.vision_tokens, cfg.d_model),
                                 jnp.float32)
    if cfg.family == "audio":
        b["frames"] = jnp.zeros((batch, 64, cfg.d_model), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, max_len=prompt_len + gen))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))

    t0 = time.perf_counter()
    logits, cache = prefill(params, b)
    jax.block_until_ready(logits)
    t_prefill = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
    out = [np.asarray(tok)[:, 0]]
    t0 = time.perf_counter()
    for _ in range(gen - 1):
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
        out.append(np.asarray(tok)[:, 0])
    t_dec = time.perf_counter() - t0
    print(f"{arch:24s} prefill({batch}x{prompt_len})={t_prefill*1e3:7.1f}ms  "
          f"decode {gen} toks: {t_dec/max(gen-1,1)*1e3:6.1f} ms/tok  "
          f"sample={np.stack(out,1)[0][:8].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    args = ap.parse_args()
    archs = ([args.arch] if args.arch else
             ["olmo-1b", "granite-moe-1b-a400m", "rwkv6-3b",
              "jamba-1.5-large-398b", "whisper-tiny", "internvl2-2b"])
    for a in archs:
        serve(a)


if __name__ == "__main__":
    main()
