"""Online unlearning serving demo: trace -> policy -> placement -> report.

Trains one coded-sharded stage, generates a seeded bursty request stream
with hot-client skew and per-request SLAs, and serves it three ways —
sequential FIFO, batch-window coalescing, and deadline-aware SLA admission —
printing each run's latency ledger.  Run with several virtual devices to see
the async placement spread shard programs:

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python examples/serve_unlearning.py
"""
import argparse

import jax

from repro.fl.experiment import ScenarioConfig, build_session
from repro.service import (DevicePlacement, UnlearningService, bursty_trace,
                           single_device_placement)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--deadline", type=float, default=20.0)
    args = ap.parse_args()

    cfg = ScenarioConfig(task="classification", num_clients=16,
                         clients_per_round=12,
                         num_shards=4, local_epochs=3, global_rounds=4,
                         samples_per_client=60, image_size=12, test_n=100,
                         store="coded")
    session, _test = build_session(cfg)
    print(f"== train: {cfg.num_shards} isolated shards, coded store, "
          f"{len(jax.devices())} device(s) ==")
    record = session.run_stage()

    print(f"== workload: {args.requests} bursty erasure requests, "
          f"hot-client skew, {args.deadline:.0f}s SLA ==")
    trace = bursty_trace(record.plan.clients, n=args.requests,
                         burst_rate=2.0, mean_burst=3.0, seed=0, skew=1.5,
                         deadline=args.deadline, rounds=cfg.global_rounds)
    for r in trace:
        print(f"   t={r.t:6.2f}s  client(s) {list(r.clients)}")

    configs = [
        ("fifo / 1 device", "fifo", {}, single_device_placement()),
        ("window(1s) / all devices", "window", {"width": 1.0},
         DevicePlacement()),
        ("sla / all devices", "sla",
         {"default_deadline": args.deadline, "est_serve": 2.0,
          "max_hold": 1.0},
         DevicePlacement()),
    ]
    for label, policy, opts, placement in configs:
        service = UnlearningService(session, policy=policy, policy_opts=opts,
                                    placement=placement)
        report = service.serve(trace)
        print(f"== {label} ==")
        print(f"   wall={report.serve_wall:.2f}s  batches="
              f"{report.num_batches}  throughput="
              f"{report.throughput:.2f} req/s  p50={report.p50:.2f}s  "
              f"p95={report.p95:.2f}s  p99={report.p99:.2f}s  "
              f"sla_hit={report.sla_hit_rate}")
        for e in report.entries:
            devs = ",".join(str(d) for d in e.devices) or "-"
            print(f"   req {e.rid}: queue={e.queue_wait:5.2f}s "
                  f"batch={e.batch_wait:5.2f}s "
                  f"retrain={e.retrain_wall:5.2f}s latency={e.latency:5.2f}s "
                  f"jobs={e.n_jobs} dev[{devs}] "
                  f"{'OK' if e.sla_met else 'LATE'}")


if __name__ == "__main__":
    main()
