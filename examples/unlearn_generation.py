"""Generation unlearning on a NON-transformer family: RWKV-6 through the
``wkv`` Pallas kernel (interpret mode on CPU, the real kernel on TPU).

The scenario registries make this a config, not a code path: pick
``task="generation"``, ``model="rwkv6"``, and a Zipf quantity-skew
partitioner, and the same ``FederatedSession`` -> coded store -> SE
machinery the paper validated on NanoGPT runs an attention-free SSM —
including calibrated shard retraining and perplexity/bits-per-char eval.

    PYTHONPATH=src python examples/unlearn_generation.py
"""
from repro.fl.experiment import ScenarioConfig, UnlearnRequest, build_session


def main():
    cfg = ScenarioConfig(task="generation", model="rwkv6",
                         partitioner="zipf",
                         partitioner_kwargs={"exponent": 1.0},
                         num_clients=10, clients_per_round=8, num_shards=2,
                         local_epochs=2, global_rounds=3,
                         samples_per_client=12, seq_len=24, test_n=60,
                         local_batch=4, store="coded")
    session, (test_x, test_y) = build_session(cfg)
    sim = session.sim

    print("== train: rwkv6 family, 2 isolated shards, coded store ==")
    record = session.run_stage()
    base = sim.evaluate(record.shard_models, test_x, test_y)
    print(f"   ensemble: ppl={base['ppl']:.1f}  bpc={base['bpc']:.2f}  "
          f"acc={base['acc']:.3f}")
    sizes = {c: len(sim.client_data[c][0]) for c in record.plan.clients}
    print(f"   zipf quantity skew — per-client examples: {sizes}")

    victim = record.plan.shard_clients[0][0]
    print(f"== SE unlearn client {victim} (shard 0 retrains, shard 1 "
          f"untouched) ==")
    res = session.unlearn(UnlearnRequest([victim], framework="SE"))[0]
    after = sim.evaluate(res.models, test_x, test_y)
    print(f"   SE : ppl={after['ppl']:.1f}  bpc={after['bpc']:.2f}  "
          f"cost={res.cost_units:.0f} client-epochs  "
          f"wall={res.wall_time:.1f}s  impacted={list(res.impacted_shards)}")


if __name__ == "__main__":
    main()
