from repro.checkpoint.store import (CodedStore, FullStore, StoreStats,  # noqa: F401
                                    UncodedShardStore, tree_bytes)
