from repro.checkpoint.store import (CodedStore, FullStore,  # noqa: F401
                                    ParameterStore, RoundPayload, STORES,
                                    StoreStats, UncodedShardStore, make_store,
                                    register_store, tree_bytes)
