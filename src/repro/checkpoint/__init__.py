"""Deprecated alias for :mod:`repro.stores`.

``repro.checkpoint`` always held the paper's *parameter stores* (full /
uncoded / coded), not training checkpoints — the name now belongs to the
real crash-recovery machinery in :mod:`repro.durability`. This shim keeps
old imports working; the re-exported objects are the exact same classes as
``repro.stores`` (identity, not copies), so registries and isinstance
checks are unaffected.
"""
import warnings

warnings.warn(
    "repro.checkpoint is deprecated; it holds parameter stores, not "
    "checkpoints — import repro.stores instead (crash-recovery "
    "checkpointing lives in repro.durability)",
    DeprecationWarning,
    stacklevel=2,
)

from repro.stores.store import (CodedStore, FullStore,  # noqa: F401,E402
                                ParameterStore, RoundPayload, STORES,
                                StoreStats, UncodedShardStore, make_store,
                                register_store, tree_bytes)
