"""Deprecated alias for :mod:`repro.stores.store` — see the package
docstring of :mod:`repro.checkpoint`."""
from repro.stores.store import *  # noqa: F401,F403
from repro.stores.store import _StackedRow  # noqa: F401
