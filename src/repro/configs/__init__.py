"""Config registry: ``get_config(arch_id)`` and the assigned-architecture list."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    FLConfig,
    MeshConfig,
    ModelConfig,
    OptimizerConfig,
    RunConfig,
    ShapeConfig,
    ShardingConfig,
    SHAPES,
    reduce_for_smoke,
)

# arch id (as assigned) -> module name
_ARCH_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "internvl2-2b": "internvl2_2b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "jamba-1.5-large-398b": "jamba_1p5_large_398b",
    "gemma3-27b": "gemma3_27b",
    "whisper-tiny": "whisper_tiny",
    "olmo-1b": "olmo_1b",
    "yi-6b": "yi_6b",
    "llama3.2-3b": "llama3p2_3b",
    "rwkv6-3b": "rwkv6_3b",
    # the paper's own models
    "nanogpt-paper": "nanogpt_paper",
    "cnn-paper": "cnn_paper",
}

ASSIGNED_ARCHS = tuple(k for k in _ARCH_MODULES if not k.endswith("-paper"))


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def list_archs() -> tuple:
    return tuple(_ARCH_MODULES)
