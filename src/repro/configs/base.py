"""Configuration dataclasses for the repro framework.

Every assigned architecture is expressed as a ``ModelConfig``; input shapes as
``ShapeConfig``; the federated-unlearning runtime as ``FLConfig``; and the
whole run (arch x shape x mesh x fl) as a ``RunConfig``.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Model
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyper-parameters.

    ``family`` selects the block stack:
      dense   -- decoder-only transformer (GQA)
      moe     -- decoder-only transformer with MoE FFN
      hybrid  -- interleaved attention + mamba blocks (+ optional MoE FFN)
      ssm     -- attention-free RWKV-6 stack
      vlm     -- decoder LM consuming a vision-patch prefix (frontend stub)
      audio   -- encoder-decoder consuming mel-frame embeddings (frontend stub)
      cnn     -- the paper's small conv classifier (CPU experiments only)
    """

    name: str
    family: str
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads
    source: str = ""   # citation bracket from the assignment

    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_d_ff: int = 0          # per-expert hidden dim (0 -> d_ff)
    moe_every: int = 1         # MoE FFN on every k-th layer (others dense d_ff)
    moe_impl: str = "einsum"   # einsum (one-hot dispatch) | gather (index-based)
    moe_capacity_factor: float = 1.25
    router_aux_weight: float = 0.01

    # --- attention pattern ---
    # Repeating pattern of layer kinds; entries in {"global","local","mamba","rwkv"}.
    # The stack is pattern tiled to num_layers (remainder unrolled).
    layer_pattern: Tuple[str, ...] = ("global",)
    sliding_window: int = 4096
    rope_theta: float = 10_000.0
    attn_block_skip: bool = False   # §Perf: triangle-only causal blocks
    attn_block_q: int = 512         # q tile; 0 = whole seq (seq-parallel mode)
    ssm_chunk_dtype: str = "float32"  # §Perf: mamba chunk internals dtype
    mamba_impl: str = "chunked"       # chunked (XLA) | pallas (fused TPU kernel)

    # --- ssm / rwkv ---
    ssm_state_dim: int = 16        # mamba d_state
    ssm_conv_width: int = 4        # mamba conv1d width
    ssm_expand: int = 2            # mamba d_inner = expand * d_model
    rwkv_head_dim: int = 64
    rwkv_impl: str = "chunked"     # chunked (XLA) | pallas (fused wkv kernel)

    # --- norm / misc ---
    norm_type: str = "rmsnorm"     # rmsnorm | layernorm | nonparametric
    act: str = "silu"              # silu | gelu
    tie_embeddings: bool = False
    logit_softcap: float = 0.0

    # --- encoder-decoder (audio) ---
    encoder_layers: int = 0
    decoder_context: int = 0       # architectural max decoder len (0 = unlimited)

    # --- frontends (stub per assignment carve-out) ---
    frontend: str = ""             # "" | "vision" | "audio"
    vision_tokens: int = 256       # VLM patch-prefix length

    # --- cnn (paper model) ---
    cnn_channels: Tuple[int, ...] = (16, 32)
    image_size: int = 28
    image_channels: int = 1
    num_classes: int = 10

    # --- numerics ---
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"

    def __post_init__(self):
        if self.head_dim == 0 and self.num_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_experts and self.moe_d_ff == 0:
            object.__setattr__(self, "moe_d_ff", self.d_ff)

    # -- derived ----------------------------------------------------------
    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Expand layer_pattern to num_layers entries."""
        pat = self.layer_pattern
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return tuple((pat * reps)[: self.num_layers])

    def param_count(self) -> int:
        """Analytic parameter count (matches init within rounding)."""
        d, h, kv, hd = self.d_model, self.num_heads, self.num_kv_heads, self.head_dim
        n = self.vocab_size * d                      # embed
        if not self.tie_embeddings and self.family != "cnn":
            n += self.vocab_size * d                 # unembed
        kinds = self.layer_kinds
        for i, kind in enumerate(kinds):
            if kind in ("global", "local"):
                n += d * (h * hd) + 2 * d * (kv * hd) + (h * hd) * d  # q,k,v,o
                n += self._ffn_params(i)
                n += 2 * self._norm_params()
            elif kind == "mamba":
                di = self.ssm_expand * self.d_model
                n += d * 2 * di            # in_proj (x and z)
                n += di * self.ssm_conv_width
                n += di * (2 * self.ssm_state_dim + 1)  # B,C,dt projections (x-dep)
                n += di + di               # dt bias, A (diag per-channel x state folded)
                n += di * self.ssm_state_dim  # A matrix (diag over channels x state)
                n += di * d                # out proj
                n += self._norm_params()
                n += self._ffn_params(i) + self._norm_params()  # hybrid: ffn too
            elif kind == "rwkv":
                n += 4 * d * d             # r,k,v,g (time mix)
                n += d * d                 # output
                n += 2 * d                 # decay base, bonus u
                n += 5 * d + 32 * d * 2    # token-shift mixers + lora-ish decay proj
                n += int(d * self.d_ff) + int(self.d_ff * d)  # channel-mix
                n += 2 * self._norm_params()
        if self.family == "audio":
            for _ in range(self.encoder_layers):
                n += 4 * d * (h * hd) + self._ffn_params() + 2 * self._norm_params()
            # decoder cross-attention
            n += len(kinds) * (4 * d * (h * hd) + self._norm_params())
        n += self._norm_params()           # final norm
        return n

    def ffn_is_moe(self, layer_idx: int) -> bool:
        return bool(self.num_experts) and (layer_idx % self.moe_every == self.moe_every - 1)

    def _ffn_params(self, layer_idx: int = 0) -> int:
        if self.ffn_is_moe(layer_idx):
            e, f = self.num_experts, self.moe_d_ff
            return self.d_model * e + e * (3 * self.d_model * f)  # router + gated mlp
        return 3 * self.d_model * self.d_ff  # gated mlp (gate,up,down)

    def _norm_params(self) -> int:
        return 0 if self.norm_type == "nonparametric" else self.d_model

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE: only top-k experts)."""
        if not self.num_experts:
            return self.param_count()
        full = self.param_count()
        e, k, f, d = self.num_experts, self.experts_per_token, self.moe_d_ff, self.d_model
        n_moe_layers = sum(1 for i in range(self.num_layers) if self.ffn_is_moe(i))
        unused = n_moe_layers * (e - k) * (3 * d * f)
        return full - unused


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Federated learning / unlearning
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FLConfig:
    num_clients: int = 100          # C (paper Sec 5.1)
    clients_per_round: int = 20     # sampled per training round
    num_shards: int = 4             # S
    local_epochs: int = 10          # L
    global_rounds: int = 30         # G
    retrain_ratio: float = 2.0      # r  (retraining uses L/r local epochs)
    coded: bool = True              # coded vs uncoded sharding
    mu: float = 0.1                 # tolerated erroneous-slice fraction
    # dry-run FL step parameters (production archs):
    fl_clients_per_step: int = 4    # clients folded into one fedavg round
    fl_local_steps: int = 1         # local steps per client per round
    client_mode: str = "serial"     # serial (scan) | parallel (vmap)

    @property
    def clients_per_shard(self) -> int:
        return self.clients_per_round // self.num_shards


# ---------------------------------------------------------------------------
# Training / serving runtime
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class OptimizerConfig:
    name: str = "adamw"        # adamw | sgdm | adamw_bf16
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    momentum: float = 0.9
    grad_clip: float = 1.0


@dataclass(frozen=True)
class MeshConfig:
    multi_pod: bool = False

    @property
    def shape(self) -> Tuple[int, ...]:
        return (2, 16, 16) if self.multi_pod else (16, 16)

    @property
    def axes(self) -> Tuple[str, ...]:
        return ("pod", "data", "model") if self.multi_pod else ("data", "model")

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


@dataclass(frozen=True)
class ShardingConfig:
    """Logical-axis -> mesh-axis rule set."""
    # parameter axes
    tensor_axes: Tuple[str, ...] = ("model",)        # mlp/heads/expert/vocab
    fsdp_axes: Tuple[str, ...] = ()                  # embed dim of params
    # activation axes
    batch_axes: Tuple[str, ...] = ("data",)
    kvseq_axes: Tuple[str, ...] = ()                 # decode long-context KV seq
    # policy knobs
    remat: str = "block"                             # none | block | full
    scan_layers: bool = True
    shard_optimizer: bool = True


@dataclass(frozen=True)
class RunConfig:
    model: ModelConfig
    shape: ShapeConfig
    mesh: MeshConfig = MeshConfig()
    sharding: ShardingConfig = ShardingConfig()
    fl: FLConfig = FLConfig()
    optimizer: OptimizerConfig = OptimizerConfig()
    seed: int = 0

    def replace(self, **kw) -> "RunConfig":
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Reduced variants for CPU smoke tests
# ---------------------------------------------------------------------------

def reduce_for_smoke(cfg: ModelConfig) -> ModelConfig:
    """2 layers, d_model<=512, <=4 experts — same family/block wiring."""
    d = min(cfg.d_model, 256)
    heads = min(cfg.num_heads, 4)
    kv = min(cfg.num_kv_heads, heads)
    head_dim = max(d // heads, 16)
    # keep the layer pattern's first two kinds so hybrid wiring is exercised
    kinds = cfg.layer_kinds[:2] if cfg.num_layers >= 2 else cfg.layer_kinds
    if cfg.family == "hybrid":
        kinds = ("global", "mamba")  # make sure both block types are hit
    if cfg.family == "ssm":
        kinds = ("rwkv", "rwkv")
    return dataclasses.replace(
        cfg,
        num_layers=2,
        d_model=d,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=head_dim,
        d_ff=min(cfg.d_ff, 512),
        moe_d_ff=min(cfg.moe_d_ff, 256) if cfg.num_experts else 0,
        vocab_size=min(cfg.vocab_size, 512),
        num_experts=min(cfg.num_experts, 4),
        experts_per_token=min(cfg.experts_per_token, 2),
        layer_pattern=kinds,
        encoder_layers=min(cfg.encoder_layers, 2),
        sliding_window=min(cfg.sliding_window, 64),
        vision_tokens=min(cfg.vision_tokens, 16),
        rwkv_head_dim=min(cfg.rwkv_head_dim, max(d // 4, 16)),
        param_dtype="float32",
        compute_dtype="float32",
    )
