"""The paper's CNN classifier (Sec 5.1): 2 conv + 2 pool + 2 fully-connected
layers, for MNIST / Fashion-MNIST / CIFAR-10 classification.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="cnn-paper",
    family="cnn",
    num_layers=2,            # conv layers
    d_model=128,             # fc hidden width
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=0,
    cnn_channels=(16, 32),
    image_size=28,
    image_channels=1,
    num_classes=10,
    param_dtype="float32",
    compute_dtype="float32",
    source="paper Sec 5.1 (CNN)",
)

import dataclasses as _dc

# CIFAR-10 variant: 32x32 RGB inputs, same topology.
CONFIG_CIFAR = _dc.replace(
    CONFIG, name="cnn-paper-cifar", image_size=32, image_channels=3
)
