"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504,
vocab=262144, 5:1 local:global attention interleave, 128k context,
decoupled head_dim=128, sliding window 1024. [hf:google/gemma-3-1b-pt]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,                       # decoupled from d_model (gemma family)
    d_ff=21504,
    vocab_size=262144,
    layer_pattern=("local",) * 5 + ("global",),
    sliding_window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    norm_type="rmsnorm",
    act="gelu",
    source="hf:google/gemma-3-1b-pt",
)
