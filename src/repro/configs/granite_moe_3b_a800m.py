"""granite-moe-3b-a800m [moe] — 32L d_model=1536 24H (GQA kv=8) d_ff=512/expert,
vocab=49155, MoE 40 experts top-8. [hf:ibm-granite/granite-3.0-1b-a400m-base]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    moe_d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    tie_embeddings=True,
    norm_type="rmsnorm",
    act="silu",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
)
