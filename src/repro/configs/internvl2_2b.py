"""internvl2-2b [vlm] — 24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.
InternViT vision encoder is a STUB: input_specs() provides projected patch
embeddings (B, 256, d_model); we implement the InternLM2 language backbone.
[arXiv:2404.16821]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    num_layers=24,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=8192,
    vocab_size=92553,
    frontend="vision",
    vision_tokens=256,
    norm_type="rmsnorm",
    act="silu",
    rope_theta=1_000_000.0,
    source="arXiv:2404.16821",
)
