"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8) d_ff=24576,
vocab=65536, MoE 16 experts top-2, Mamba:attention 7:1 interleave (each period
of 8 layers = 1 attention + 7 mamba), MoE FFN on every 2nd layer.
[arXiv:2403.19887]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24576,
    moe_d_ff=24576,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,                       # MoE on every other layer (jamba paper)
    layer_pattern=("global",) + ("mamba",) * 7,
    ssm_state_dim=16,
    ssm_conv_width=4,
    ssm_expand=2,
    norm_type="rmsnorm",
    act="silu",
    source="arXiv:2403.19887",
)
