"""NanoGPT as used by the paper (Sec 5.1): 4-layer transformer, 4 attention
heads, embedding dim 16, vocab 109, trained on Tiny Shakespeare.
[Radford et al. 2019 / karpathy/nanoGPT]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="nanogpt-paper",
    family="dense",
    num_layers=4,
    d_model=16,
    num_heads=4,
    num_kv_heads=4,
    d_ff=64,
    vocab_size=109,
    norm_type="layernorm",
    act="gelu",
    param_dtype="float32",
    compute_dtype="float32",
    source="paper Sec 5.1 (nanoGPT)",
)
