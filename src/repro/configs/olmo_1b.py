"""olmo-1b [dense] — 16L d_model=2048 16H (kv=16) d_ff=8192 vocab=50304,
non-parametric LayerNorm, tied embeddings. [arXiv:2402.00838]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="olmo-1b",
    family="dense",
    num_layers=16,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=8192,
    vocab_size=50304,
    norm_type="nonparametric",
    act="silu",
    tie_embeddings=True,
    source="arXiv:2402.00838",
)
