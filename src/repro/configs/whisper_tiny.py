"""whisper-tiny [audio] — 4L enc + 4L dec, d_model=384 6H (kv=6) d_ff=1536,
vocab=51865, encoder-decoder. The mel-spectrogram + conv frontend is a STUB:
input_specs() provides post-conv frame embeddings (B, S_enc, d_model).
[arXiv:2212.04356]
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="audio",
    num_layers=4,          # decoder layers
    encoder_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    frontend="audio",
    norm_type="layernorm",
    act="gelu",
    decoder_context=448,   # architectural decoder limit (model card)
    source="arXiv:2212.04356",
)
