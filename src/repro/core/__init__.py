from repro.core import coding, sharding, theory, unlearning  # noqa: F401
from repro.core.baselines import FRAMEWORKS, Framework  # noqa: F401
