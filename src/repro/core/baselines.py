"""Baseline framework descriptors (paper Sec 5.1).

The actual algorithms are implemented in ``repro.fl.simulator``; this module
is the registry + metadata used by benchmarks and docs.

  FR  FedRetrain   — retrain from scratch on retained clients (provable,
                     no storage, slowest). [Liu et al. 2021]
  FE  FedEraser    — calibrated retraining from full central storage of every
                     client's per-round parameters (provable, huge storage).
                     [Liu et al. 2021]
  RR  RapidRetrain — retraining accelerated with a diagonal empirical Fisher
                     preconditioner (unprovable). [Liu et al. 2022]
  SE  ShardEraser  — OURS: stage-based isolated sharding + coded storage
                     (provable at shard granularity, minimal server storage).
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class Framework:
    key: str
    name: str
    provable: bool
    uses_storage: bool
    sharded: bool
    coded: bool
    retrain_epoch_scale: float   # local epochs used in retraining = L * scale


FRAMEWORKS = {
    "FR": Framework("FR", "FedRetrain", True, False, False, False, 1.0),
    "FE": Framework("FE", "FedEraser", True, True, False, False, 0.5),
    "RR": Framework("RR", "RapidRetrain", False, False, False, False, 0.5),
    "SE": Framework("SE", "ShardEraser (ours)", True, True, True, True, 0.5),
    "SE-uncoded": Framework("SE-uncoded", "ShardEraser (uncoded)", True, True,
                            True, False, 0.5),
}
