"""Coded computing-based sharding (paper Sec 3.3).

The per-round, per-shard intermediate parameters ``w_{C_s}^g`` (one vector per
shard, stacked to ``W in R^{S x P}``) are Lagrange-encoded (eq. 5/6) at client
points ``alpha_i``:

    w~_i = u(alpha_i) = sum_s W[s] * l_s(alpha_i)        (a C x S matmul)

which is a Reed-Solomon code of dimension S and length C. Reconstruction:

  * erasure decode (eq. 7): any S intact slices determine W. We solve it in
    the *Lagrange basis* (re-interpolation matrix D[s,i] = l_i^{(I)}(omega_s))
    rather than inverting the power-basis Vandermonde — numerically stable at
    C=100 in float32. The paper's literal pseudo-inverse form is also provided
    (``decode_vandermonde``) for fidelity tests at small C.
  * error decode: up to floor((C-S)/2) corrupted slices are localized with
    Berlekamp-Welch (float64 least squares on a sample of coordinates,
    majority vote), then excluded and erasure-decoded. Matches the paper's
    ``2*mu*C <= C - S`` tolerance (eq. 11).

Encode/decode are *matmuls against small coefficient matrices*, so on TPU they
stream parameter blocks through the MXU — see kernels/coded_matmul for the
Pallas fast path; this module is the reference/driver layer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from functools import partial
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class CodingBudgetExceeded(RuntimeError):
    """Corruption (or erasure) beyond the correctable budget of eq. 11.

    Carries the ``observed`` fault count and the scheme's ``max_errors`` so
    callers (and tests) can assert the failure mode instead of parsing an
    opaque stack trace.
    """

    def __init__(self, observed: int, max_errors: int,
                 kind: str = "corrupted slices"):
        self.observed = int(observed)
        self.max_errors = int(max_errors)
        self.kind = kind
        super().__init__(
            f"{kind} count {self.observed} exceeds the correctable budget "
            f"max_errors={self.max_errors} (2*mu*C <= C - S, eq. 11)")


def chebyshev_points(n: int, lo: float = -1.0, hi: float = 1.0) -> np.ndarray:
    """Chebyshev nodes — well-conditioned interpolation points."""
    k = np.arange(n)
    x = np.cos((2 * k + 1) / (2 * n) * np.pi)
    return (lo + hi) / 2 + (hi - lo) / 2 * x


def lagrange_coeff_matrix(src: np.ndarray, dst: np.ndarray) -> np.ndarray:
    """M[j, i] = l_i^{(src)}(dst_j): evaluate the Lagrange basis over ``src``
    points at ``dst`` points. Encode: src=omega, dst=alpha. Decode: src=alpha
    subset, dst=omega."""
    src = np.asarray(src, np.float64)
    dst = np.asarray(dst, np.float64)
    n = len(src)
    m = np.ones((len(dst), n), np.float64)
    for i in range(n):
        for j in range(n):
            if j != i:
                m[:, i] *= (dst - src[j]) / (src[i] - src[j])
    return m


@dataclass(frozen=True)
class CodingScheme:
    """Evaluation-point layout for one (C clients, S shards) code."""
    num_shards: int                  # S — code dimension
    num_clients: int                 # C — code length
    alpha: np.ndarray = field(default=None)   # (C,) client points
    omega: np.ndarray = field(default=None)   # (S,) shard points

    def __post_init__(self):
        assert self.num_clients >= self.num_shards, "need C >= S"
        if self.alpha is None:
            object.__setattr__(self, "alpha",
                               chebyshev_points(self.num_clients, -1.0, 1.0))
        if self.omega is None:
            # interleave shard points strictly inside the alpha hull
            object.__setattr__(self, "omega",
                               chebyshev_points(self.num_shards, -0.95, 0.95))

    # -- matrices ----------------------------------------------------------
    def encode_matrix(self) -> np.ndarray:
        """(C, S): B[i, s] = l_s(alpha_i). eq. (6)."""
        return lagrange_coeff_matrix(self.omega, self.alpha)

    def decode_matrix(self, client_ids: Sequence[int]) -> np.ndarray:
        """(S, S): re-interpolation from a slice subset back to omega.

        When more than S slices are available we pick a well-spread subset
        (greedy farthest-point on the alpha line) — interpolation conditioning
        depends on node spread, and the first-S ids may cluster at one end of
        the Chebyshev layout."""
        ids = np.asarray(client_ids)
        assert len(ids) >= self.num_shards, "need at least S slices"
        if len(ids) > self.num_shards:
            pts = self.alpha[ids]
            chosen = [int(np.argmin(pts)), int(np.argmax(pts))]
            while len(chosen) < self.num_shards:
                dmin = np.min(np.abs(pts[:, None] - pts[chosen][None, :]), axis=1)
                dmin[chosen] = -1
                chosen.append(int(np.argmax(dmin)))
            ids = ids[np.sort(chosen)]
        return lagrange_coeff_matrix(self.alpha[ids], self.omega), ids

    def quorum(self, available: Optional[Sequence[int]] = None) -> np.ndarray:
        """The canonical S-slice read set: the well-spread subset
        ``decode_matrix`` selects from ``available`` (default: all C).

        Reads that only lose slices *outside* this subset decode through the
        identical re-interpolation matrix — bit-identical to the fault-free
        read (the greedy farthest-point choice never inspects rows it does
        not pick, so removing unpicked candidates cannot change it)."""
        ids = list(available) if available is not None \
            else list(range(self.num_clients))
        _, chosen = self.decode_matrix(ids)
        return np.asarray([int(i) for i in chosen])

    def reduced(self, available: Sequence[int]) -> "CodingScheme":
        """The code restricted to ``available`` slice rows: a valid RS code
        of the same dimension over the surviving alpha points, with the
        correspondingly tighter error budget ``(len(available) - S) // 2``.
        Used to run error localization after erasures."""
        avail = np.asarray(sorted(int(i) for i in available))
        return CodingScheme(self.num_shards, len(avail),
                            alpha=np.asarray(self.alpha)[avail],
                            omega=self.omega)

    @property
    def max_errors(self) -> int:
        """mu*C with 2*mu*C <= C - S (eq. 11)."""
        return (self.num_clients - self.num_shards) // 2


# ---------------------------------------------------------------------------
# Encode / decode on (stacked) parameter matrices
# ---------------------------------------------------------------------------

def encode(scheme: CodingScheme, shard_params: jnp.ndarray,
           use_kernel: bool = False, out_dtype=None) -> jnp.ndarray:
    """shard_params: (S, P) -> coded slices (C, P). eq. (6).

    ``out_dtype``: optional storage dtype for the slices (bf16 halves the
    client-side storage footprint; decode accumulates in f32 regardless).
    """
    b = jnp.asarray(scheme.encode_matrix(), jnp.float32)
    w = shard_params.astype(jnp.float32)
    if use_kernel:
        from repro.kernels.coded_matmul.ops import coded_matmul
        return coded_matmul(b, w, out_dtype=out_dtype)
    out = b @ w
    return out.astype(out_dtype) if out_dtype is not None else out


@partial(jax.jit, static_argnames=("out_dtype",))
def _encode_many(b: jnp.ndarray, mats: tuple, out_dtype=None) -> tuple:
    outs = tuple(b @ m.astype(jnp.float32) for m in mats)
    if out_dtype is not None:
        outs = tuple(o.astype(out_dtype) for o in outs)
    return outs


def encode_batched(scheme: CodingScheme, mats: Sequence[jnp.ndarray],
                   use_kernel: bool = False, out_dtype=None) -> list:
    """Encode G (S, P_g) matrices in ONE dispatch.

    jnp path: all G encodes run inside a single jitted XLA program — one
    launch and zero host round-trips instead of G eager dispatches (the G
    matrices stay separate buffers; no concat copy). Kernel path: the rounds
    are concatenated to (S, sum_g P_g) and streamed through ONE 2-D-grid
    ``coded_matmul`` — on TPU the tiny (C, S) coefficient matrix then makes a
    single resident pass over the whole multi-round payload. Identical
    per-column math to per-round ``encode``; used by ``CodedStore`` to batch
    the history encodes.
    """
    if not use_kernel:
        b = jnp.asarray(scheme.encode_matrix(), jnp.float32)
        return list(_encode_many(b, tuple(mats), out_dtype=out_dtype))
    widths = [int(m.shape[1]) for m in mats]
    w = mats[0] if len(mats) == 1 else jnp.concatenate(list(mats), axis=1)
    coded = encode(scheme, w, use_kernel=True, out_dtype=out_dtype)
    outs, off = [], 0
    for p in widths:
        outs.append(coded[:, off:off + p])
        off += p
    return outs


def encode_rounds(enc: jnp.ndarray, hist: jnp.ndarray,
                  use_kernel: bool = False, out_dtype=None) -> jnp.ndarray:
    """All-rounds Lagrange encode: ``hist (G, S, P) -> (G, C, P)`` in one op.

    ``enc`` is the (C, S) encode matrix (``CodingScheme.encode_matrix`` as a
    device array).  Fully traceable — this is the encode the stage-program
    engine fuses *into* the training program, replacing ``encode_batched``'s
    separate dispatch.  Per-round columns are identical math to
    ``encode(scheme, hist[g])``.  jnp path: one batched einsum over the round
    axis.  Kernel path: a (G, C_tiles, P_tiles)-grid Pallas matmul that
    streams each round's (S, block_p) tile through the MXU with NO
    concatenate copy (``encode_batched``'s kernel path concatenated the
    rounds host-visibly first).
    """
    if use_kernel:
        from repro.kernels.coded_matmul.ops import coded_matmul_rounds
        return coded_matmul_rounds(enc, hist, out_dtype=out_dtype)
    out = jnp.einsum("cs,gsp->gcp", enc.astype(jnp.float32),
                     hist.astype(jnp.float32))
    return out.astype(out_dtype) if out_dtype is not None else out


def encode_decode(scheme: CodingScheme, shard_params: jnp.ndarray,
                  client_ids: Optional[Sequence[int]] = None,
                  use_kernel: bool = False) -> jnp.ndarray:
    """Fused code round-trip: encode to C slices and immediately re-decode
    from ``client_ids`` (default: all C) — the slice-verification path.

    ``use_kernel``: the Pallas path streams ``D @ (B @ w_tile)`` per P-tile,
    so the (C, P) coded intermediate never touches HBM (the TPU form of the
    fusion). The jnp path exploits associativity instead: the (S, C) decode
    and (C, S) encode operators are precomposed into one (S, S) matrix on the
    host, turning the round-trip into a SINGLE small matmul over P — S*S*P
    FLOPs instead of 2*C*S*P (25x fewer at the paper's C=100, S=4).
    """
    ids = list(client_ids) if client_ids is not None else \
        list(range(scheme.num_clients))
    d, used = scheme.decode_matrix(ids)
    # (S, C) decode operator with zero columns for unused client slots
    dec = np.zeros((scheme.num_shards, scheme.num_clients), np.float64)
    dec[:, [int(i) for i in used]] = d
    enc_np = scheme.encode_matrix()
    if use_kernel:
        from repro.kernels.coded_matmul.ops import coded_encode_decode
        return coded_encode_decode(jnp.asarray(enc_np, jnp.float32),
                                   jnp.asarray(dec, jnp.float32),
                                   shard_params.astype(jnp.float32))
    composed = jnp.asarray(dec @ enc_np, jnp.float32)      # (S, S) ~ I
    return composed @ shard_params.astype(jnp.float32)


def decode_erasure(scheme: CodingScheme, slices: jnp.ndarray,
                   client_ids: Sequence[int],
                   use_kernel: bool = False) -> jnp.ndarray:
    """Reconstruct (S, P) from >=S intact slices (rows of ``slices``).

    slices: (len(client_ids), P) — coded slices from those clients.
    """
    d, ids = scheme.decode_matrix(client_ids)
    dm = jnp.asarray(d, jnp.float32)
    rows = jnp.asarray([list(client_ids).index(int(i)) for i in ids])
    sl = slices[rows].astype(jnp.float32)
    if use_kernel:
        from repro.kernels.coded_matmul.ops import coded_matmul
        return coded_matmul(dm, sl)
    return dm @ sl


def decode_vandermonde(scheme: CodingScheme, slices: jnp.ndarray) -> jnp.ndarray:
    """The paper's literal eq. (7): power-basis Vandermonde pseudo-inverse.

    Reconstructs the polynomial coefficients then evaluates at omega. Only
    numerically sane for small C; kept for fidelity testing.
    """
    a = np.vander(np.asarray(scheme.alpha), scheme.num_shards, increasing=True)
    pinv = np.linalg.pinv(a)                        # (S, C)
    coeffs = jnp.asarray(pinv, jnp.float32) @ slices.astype(jnp.float32)
    v_omega = np.vander(np.asarray(scheme.omega), scheme.num_shards,
                        increasing=True)            # (S, S)
    return jnp.asarray(v_omega, jnp.float32) @ coeffs


# ---------------------------------------------------------------------------
# Berlekamp-Welch error localization (float64, control-plane)
# ---------------------------------------------------------------------------

def _consistency_residual(scheme: CodingScheme, slices: np.ndarray,
                          trusted: np.ndarray) -> np.ndarray:
    """Decode from ``trusted[:S]`` rows, re-encode, return per-row residual."""
    d, ids = scheme.decode_matrix(list(trusted))
    rows = [list(trusted).index(int(i)) for i in ids]
    w = d @ slices[trusted[rows]]
    b = scheme.encode_matrix()
    recon = b @ w
    denom = np.abs(slices).mean() + 1e-12
    return np.abs(recon - slices).mean(axis=1) / denom


def locate_errors(scheme: CodingScheme, slices: np.ndarray,
                  num_probe: int = 8, seed: int = 0, tol: float = 1e-3,
                  method: str = "bw") -> np.ndarray:
    """Identify corrupted slice rows. slices: (C, P) float array.

    method="bw": Berlekamp-Welch — solve Q(a_i) = y_i E(a_i) (deg Q < S+e,
    E monic deg e) by float64 least squares on ``num_probe`` coordinates; the
    roots of E (|E(a_i)| ~ 0) are the corrupted clients; majority vote.
    method="ransac": consensus decoding — sample S-subsets, re-encode, pick
    the largest inlier set (robust production fallback at large C).
    A consistency pre-check short-circuits the no-error case.

    Raises ``CodingBudgetExceeded`` when the localized corruption exceeds
    ``scheme.max_errors`` — beyond eq. 11's budget localization is not
    information-theoretically sound, so failing loudly beats mis-decoding.
    """
    slices = np.asarray(slices, np.float64)
    c, p = slices.shape
    s = scheme.num_shards
    e = scheme.max_errors
    # fast path: no errors at all
    resid0 = _consistency_residual(scheme, slices, np.arange(c))
    if resid0.max() < tol:
        return np.array([], np.int64)
    if e == 0:
        raise CodingBudgetExceeded(int((resid0 >= tol).sum()), 0)
    a = np.asarray(scheme.alpha, np.float64)
    rng = np.random.default_rng(seed)

    if method == "ransac":
        best_bad, best_inliers = None, -1
        for _ in range(128):
            pick = rng.choice(c, size=s, replace=False)
            r = _consistency_residual(scheme, slices, pick)
            inliers = int((r < tol).sum())
            if inliers > best_inliers:
                best_inliers = inliers
                best_bad = np.where(r >= tol)[0]
            if inliers >= c - e:
                break
        bad = np.sort(best_bad)
        if len(bad) > e:
            raise CodingBudgetExceeded(len(bad), e)
        return bad

    cols = rng.choice(p, size=min(num_probe, p), replace=False)
    votes = np.zeros(c)
    va_q = np.vander(a, s + e, increasing=True)          # Q: deg < S+e
    va_e = np.vander(a, e, increasing=True)              # E: monic deg e
    for col in cols:
        y = slices[:, col]
        # Q(a_i) - y_i*(E_0 + ... + E_{e-1} a^{e-1}) = y_i * a^e
        lhs = np.concatenate([va_q, -y[:, None] * va_e], axis=1)
        rhs = y * a ** e
        sol, *_ = np.linalg.lstsq(lhs, rhs, rcond=None)
        e_coeffs = np.concatenate([sol[s + e:], [1.0]])  # monic
        e_vals = np.abs(np.polyval(e_coeffs[::-1], a))
        votes += e_vals < 0.05 * np.median(e_vals + 1e-300)
    bad = np.sort(np.where(votes > len(cols) / 2)[0])
    # verify: decoding without the located rows must be self-consistent on
    # EVERY surviving row — a median test would let one residual corruption
    # (beyond-budget under-localization) hide among the clean majority
    good = np.setdiff1d(np.arange(c), bad)
    if len(good) >= s and len(bad) <= e:
        r = _consistency_residual(scheme, slices, good)
        if r[good].max() < tol:
            return bad
    # fall back to consensus decoding
    return locate_errors(scheme, slices, num_probe, seed, tol, method="ransac")


def decode_with_errors(scheme: CodingScheme, slices: jnp.ndarray,
                       use_kernel: bool = False) -> Tuple[jnp.ndarray, np.ndarray]:
    """Full RS decode: localize corrupted slices, then erasure-decode without
    them. slices: (C, P). Returns (W (S,P), bad_ids).

    Raises ``CodingBudgetExceeded`` when corruption exceeds eq. 11's budget.
    """
    bad = locate_errors(scheme, np.asarray(slices, np.float64))
    good = np.setdiff1d(np.arange(scheme.num_clients), bad)
    if len(good) < scheme.num_shards:
        raise CodingBudgetExceeded(len(bad), scheme.max_errors)
    w = decode_erasure(scheme, slices[jnp.asarray(good)], list(good),
                       use_kernel=use_kernel)
    return w, bad


def decode_robust(scheme: CodingScheme, slices: jnp.ndarray,
                  available: Optional[Sequence[int]] = None,
                  use_kernel: bool = False, tol: float = 1e-3,
                  seed: int = 0
                  ) -> Tuple[jnp.ndarray, list, list]:
    """Quorum read: reconstruct (S, P) despite erased AND corrupted slices.

    ``slices``: the full (C, P) coded array (the content of unavailable rows
    is never read).  ``available``: the present row ids (None = all C).

    Pipeline: a consistency pre-check over the surviving rows; if clean,
    plain erasure decode from the canonical well-spread subset (bit-identical
    to the fault-free read whenever the faults spare ``scheme.quorum()``).
    Otherwise, error localization runs on the *reduced* scheme — the code
    restricted to surviving alpha points, a valid RS code whose budget
    ``(C - f - S) // 2`` tightens automatically with ``f`` erasures — and the
    located rows are excluded before the erasure decode.

    Returns ``(w, lost_ids, bad_ids)``.  Raises ``CodingBudgetExceeded``
    when the surviving-and-clean rows cannot determine the code.
    """
    c = scheme.num_clients
    avail = sorted(int(i) for i in (available if available is not None
                                    else range(c)))
    lost = sorted(set(range(c)) - set(avail))
    if len(avail) < scheme.num_shards:
        raise CodingBudgetExceeded(len(lost), c - scheme.num_shards,
                                   kind="erased slices")
    sub = np.asarray(jax.device_get(slices)).astype(np.float64)[avail]
    red = scheme if not lost else scheme.reduced(avail)
    resid = _consistency_residual(red, sub, np.arange(len(avail)))
    if resid.max() < tol:
        w = decode_erasure(scheme, slices[jnp.asarray(avail)], avail,
                           use_kernel=use_kernel)
        return w, lost, []
    bad_local = locate_errors(red, sub, tol=tol, seed=seed)
    bad = sorted(avail[int(i)] for i in bad_local)
    good = [i for i in avail if i not in set(bad)]
    if len(good) < scheme.num_shards:
        raise CodingBudgetExceeded(len(bad), red.max_errors)
    w = decode_erasure(scheme, slices[jnp.asarray(good)], good,
                       use_kernel=use_kernel)
    return w, lost, bad


# ---------------------------------------------------------------------------
# Pytree <-> flat parameter matrix
# ---------------------------------------------------------------------------

def tree_to_flat(tree) -> Tuple[jnp.ndarray, object]:
    """Flatten a param pytree to a 1-D f32 vector + re-assembly spec."""
    leaves, treedef = jax.tree.flatten(tree)
    shapes = [(l.shape, l.dtype) for l in leaves]
    flat = jnp.concatenate([jnp.ravel(l).astype(jnp.float32) for l in leaves])
    return flat, (treedef, shapes)


def flat_to_tree(flat: jnp.ndarray, spec) -> object:
    treedef, shapes = spec
    leaves = []
    off = 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(flat[off: off + n].reshape(shape).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, leaves)


def tree_to_flat_stacked(tree) -> Tuple[jnp.ndarray, object]:
    """Flatten a stacked ``(M, ...)`` pytree to an ``(M, P)`` f32 matrix in
    one pass (one reshape+concat over leaves — NOT one flatten per client).

    Row ``i`` is bit-identical to ``tree_to_flat`` of the unstacked tree
    ``jax.tree.map(lambda a: a[i], tree)``, and the returned spec is the
    per-row spec: ``flat_to_tree(flat[i], spec)`` reassembles client ``i``.
    Traceable — usable inside jit (ignore the spec there).
    """
    leaves, treedef = jax.tree.flatten(tree)
    m = leaves[0].shape[0]
    flat = jnp.concatenate(
        [l.reshape(m, -1).astype(jnp.float32) for l in leaves], axis=1)
    spec = (treedef, [(l.shape[1:], l.dtype) for l in leaves])
    return flat, spec


def flat_to_stacked_tree(flat: jnp.ndarray, spec) -> object:
    """Inverse of ``tree_to_flat_stacked``: (M, P) -> stacked (M, ...) tree."""
    treedef, shapes = spec
    m = flat.shape[0]
    leaves, off = [], 0
    for shape, dtype in shapes:
        n = int(np.prod(shape)) if shape else 1
        leaves.append(flat[:, off: off + n].reshape((m, *shape)).astype(dtype))
        off += n
    return jax.tree.unflatten(treedef, leaves)


@dataclass(frozen=True)
class StackedRowSpec:
    """Re-assembly spec for a shard vector laid out as M client rows.

    The shard's stored vector is ``stacked_flat.reshape(-1)`` — client-major
    concat of ``row_len``-sized rows, one per client in ``client_ids`` order.
    ``row_spec`` is the per-client spec from ``tree_to_flat_stacked``.
    """
    client_ids: Tuple[int, ...]
    row_len: int
    row_spec: object


def flat_to_client_trees(flat: jnp.ndarray, spec: StackedRowSpec) -> dict:
    """Reassemble a decoded shard vector into {client_id: param tree}."""
    rows = flat[: len(spec.client_ids) * spec.row_len].reshape(
        len(spec.client_ids), spec.row_len)
    return {c: flat_to_tree(rows[i], spec.row_spec)
            for i, c in enumerate(spec.client_ids)}


def encode_pytrees(scheme: CodingScheme, shard_trees: Sequence,
                   use_kernel: bool = False):
    """Encode S parameter pytrees (one per shard) into C coded slices.

    Returns (slices (C, P), spec) — spec reassembles decoded rows to pytrees.
    """
    flats, specs = zip(*[tree_to_flat(t) for t in shard_trees])
    pmax = max(f.shape[0] for f in flats)
    w = jnp.stack([jnp.pad(f, (0, pmax - f.shape[0])) for f in flats])
    return encode(scheme, w, use_kernel=use_kernel), specs


def decode_pytrees(scheme: CodingScheme, slices: jnp.ndarray,
                   client_ids: Sequence[int], specs,
                   use_kernel: bool = False):
    w = decode_erasure(scheme, slices, client_ids, use_kernel=use_kernel)
    return [flat_to_tree(w[s], specs[s]) for s in range(scheme.num_shards)]
