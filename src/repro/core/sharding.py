"""Stage-based isolated sharding (paper Sec 3.2).

The learning/unlearning timeline is divided into *stages*; within a stage the
participating clients are partitioned into ``S`` isolated shards, each with
its own aggregation server. No cross-shard interaction happens inside a stage,
which is what makes shard-local retraining a *provable* unlearning operation
(eq. 4): a shard's model is a pure function of its own clients' data.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Sequence, Set, Tuple

import numpy as np


@dataclass
class StagePlan:
    """Client -> shard assignment for one stage."""
    stage: int
    shard_clients: Dict[int, List[int]]          # shard id -> client ids

    @property
    def num_shards(self) -> int:
        return len(self.shard_clients)

    def shard_of(self, client: int) -> int:
        for s, cs in self.shard_clients.items():
            if client in cs:
                return s
        raise KeyError(f"client {client} not in stage {self.stage}")

    @property
    def clients(self) -> List[int]:
        return sorted(c for cs in self.shard_clients.values() for c in cs)


class ShardManager:
    """Stage/shard bookkeeping: sampling, assignment, impact analysis."""

    def __init__(self, num_clients: int, num_shards: int,
                 clients_per_round: int, seed: int = 0):
        self.num_clients = num_clients
        self.num_shards = num_shards
        self.clients_per_round = clients_per_round
        self._rng = np.random.default_rng(seed)
        self.stages: List[StagePlan] = []

    def new_stage(self) -> StagePlan:
        """Sample participating clients and split them into isolated shards."""
        chosen = self._rng.choice(self.num_clients, self.clients_per_round,
                                  replace=False)
        per = self.clients_per_round // self.num_shards
        plan = StagePlan(
            stage=len(self.stages),
            shard_clients={s: sorted(int(c) for c in chosen[s * per:(s + 1) * per])
                           for s in range(self.num_shards)},
        )
        self.stages.append(plan)
        return plan

    # -- unlearning impact ---------------------------------------------------
    def impacted_shards(self, plan: StagePlan,
                        unlearn_clients: Sequence[int]) -> Set[int]:
        """S' — shards containing at least one unlearning client (isolation
        means only these retrain)."""
        out = set()
        for c in unlearn_clients:
            for s, cs in plan.shard_clients.items():
                if c in cs:
                    out.add(s)
        return out

    def retained(self, plan: StagePlan, shard: int,
                 unlearn_clients: Sequence[int]) -> List[int]:
        return [c for c in plan.shard_clients[shard] if c not in unlearn_clients]


def even_requests(plan: StagePlan, k: int, seed: int = 0) -> List[int]:
    """'Even' request pattern: requests spread evenly across shards."""
    rng = np.random.default_rng(seed)
    out: List[int] = []
    shards = sorted(plan.shard_clients)
    i = 0
    while len(out) < k:
        pool = [c for c in plan.shard_clients[shards[i % len(shards)]]
                if c not in out]
        if pool:
            out.append(int(rng.choice(pool)))
        i += 1
    return out


def adaptive_requests(plan: StagePlan, k: int, seed: int = 0) -> List[int]:
    """'Adapt' request pattern: all requests hit one shard (paper Sec 5.1)."""
    rng = np.random.default_rng(seed)
    shard = int(rng.choice(sorted(plan.shard_clients)))
    pool = list(plan.shard_clients[shard])
    k = min(k, max(len(pool) - 1, 1))
    return [int(c) for c in rng.choice(pool, size=k, replace=False)]
