"""Theoretical analysis (paper Sec 4): closed forms + Monte-Carlo validators.

Time efficiency of isolated sharding (eq. 8-10) and storage effectiveness of
coded sharding (eq. 11-13).
"""
from __future__ import annotations

import math
from typing import Tuple

import numpy as np


# --- Sec 4.1: time efficiency ------------------------------------------------

def sequential_time(num_shards: int, num_requests: int, avg_cost: float) -> float:
    """eq. (9): T_s = K * C_t (each request retrains one shard)."""
    return num_requests * avg_cost


def concurrent_time(num_shards: int, num_requests: int, avg_cost: float) -> float:
    """eq. (10): T_c = S * C_t * (1 - (1 - 1/S)^K)."""
    s, k = num_shards, num_requests
    return s * avg_cost * (1.0 - (1.0 - 1.0 / s) ** k)


def unsharded_time(num_clients_scope: float, num_requests: int,
                   avg_cost: float, concurrent: bool) -> float:
    """Benchmark without isolation: every request retrains the full federation
    (S=1). Sequential: K * S*C_t-equivalent; concurrent: one full retrain."""
    full = num_clients_scope * avg_cost
    return full if concurrent else num_requests * full


def mc_sequential_time(num_shards: int, num_requests: int, avg_cost: float,
                       trials: int = 20_000, seed: int = 0) -> float:
    """Monte-Carlo estimate of the sequential expected cost, for validating
    eq. (9) (requests land on uniformly random shards; each is processed
    individually at cost avg_cost)."""
    rng = np.random.default_rng(seed)
    hits = rng.integers(0, num_shards, size=(trials, num_requests))
    return float(np.mean((hits >= 0).sum(axis=1)) * avg_cost)


def mc_concurrent_time(num_shards: int, num_requests: int, avg_cost: float,
                       trials: int = 20_000, seed: int = 0) -> float:
    """Monte-Carlo estimate for eq. (10): cost = (#distinct impacted shards)
    * avg_cost when the K requests are batched."""
    rng = np.random.default_rng(seed)
    hits = rng.integers(0, num_shards, size=(trials, num_requests))
    distinct = np.array([len(np.unique(h)) for h in hits])
    return float(distinct.mean() * avg_cost)


# --- Sec 4.2: storage effectiveness ------------------------------------------

def storage_efficiency_bounds(num_clients: int, num_shards: int,
                              mu: float) -> Tuple[float, float]:
    """eq. (12): S <= gamma_c <= (1 - 2 mu) C, with feasibility eq. (11)."""
    assert 2 * mu * num_clients <= num_clients - num_shards + 1e-9, \
        "violates 2*mu*C <= C - S (eq. 11)"
    return float(num_shards), (1.0 - 2.0 * mu) * num_clients


def coded_throughput(num_clients: int, num_shards: int) -> float:
    """eq. (13): lambda_c = S / O(C^2 log^2 C loglog C) — relative units."""
    c = max(num_clients, 3)
    denom = c ** 2 * math.log(c) ** 2 * math.log(math.log(c))
    return num_shards / denom


def storage_bytes(model_bytes: int, num_clients: int, num_shards: int,
                  rounds: int, mechanism: str) -> dict:
    """Byte-level accounting used by the Fig. 5 benchmark.

    Returns dict with per-server and per-client storage for one stage.
    ``model_bytes`` is the size of ONE client's parameter vector.
    mechanism in {"full", "uncoded", "coded"}:
      full    — FedEraser: the central server stores every participating
                client's params for every round.
      uncoded — isolated sharding: each shard server stores only its own
                clients' params per round.
      coded   — coded sharding: servers store only the interpolation keys;
                each client stores one coded slice per round (a mix of the
                S shard vectors, each sized clients_per_shard*model_bytes).
    """
    per_shard_clients = num_clients // num_shards
    shard_vec = per_shard_clients * model_bytes
    if mechanism == "full":
        return {"server_bytes": num_clients * rounds * model_bytes,
                "client_bytes": 0,
                "total_bytes": num_clients * rounds * model_bytes}
    if mechanism == "uncoded":
        return {"server_bytes": per_shard_clients * rounds * model_bytes,
                "client_bytes": 0,
                "total_bytes": num_clients * rounds * model_bytes}
    if mechanism == "coded":
        keys = 16 * num_clients  # alpha/omega points + MACs, negligible
        return {"server_bytes": keys,
                "client_bytes": rounds * shard_vec,
                "total_bytes": keys * num_shards + num_clients * rounds * shard_vec}
    raise ValueError(mechanism)
