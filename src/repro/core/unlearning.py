"""SE (Sharding Eraser) unlearning engine: preparation (eq. 2) and calibrated
retraining (eq. 3), operating on parameter pytrees.

These are the *algebraic* operations; the FL loop that drives them lives in
``repro.fl.simulator`` (CPU paper-scale) and ``repro.fl.fedavg`` (pod-scale).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp


def tree_mean(trees: Sequence):
    """Average a list of pytrees — eq. (2)'s aggregation."""
    n = float(len(trees))
    return jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / n,
                        *trees)


def tree_add(a, b, scale: float = 1.0):
    return jax.tree.map(lambda x, y: x + scale * y.astype(x.dtype), a, b)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y.astype(x.dtype), a, b)


def tree_norm(tree) -> jnp.ndarray:
    """Global L2 norm of a pytree (f32 accumulate)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def tree_scale(tree, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), tree)


def prepare_initial_model(retained_locals: Sequence) -> object:
    """eq. (2): the initial unlearned global model is the average of the
    retained clients' stored local models (the unlearned clients' parameters
    have already been removed from the set)."""
    assert retained_locals, "no retained clients in shard"
    return tree_mean(retained_locals)


def calibrate(global_model, retrained_deltas: Sequence,
              stored_deltas: Sequence, eps: float = 1e-12):
    """eq. (3): one calibrated-retraining aggregation round.

        w^{g'+1} = w^{g'} + (1/M) * sum_m  (||w^g_m|| / ||w'^{g'}_m||) w'^{g'}_m

    ``retrained_deltas``: the retained clients' *new* local updates at
    unlearning round g' (trained with L/r epochs from the current unlearned
    global model).  ``stored_deltas``: the same clients' *historical* updates
    at the matching learning round g = g' — only their norms are used, to
    restore the update magnitude the full training had.
    """
    assert len(retrained_deltas) == len(stored_deltas)
    m = len(retrained_deltas)
    out = global_model
    for new, old in zip(retrained_deltas, stored_deltas):
        ratio = tree_norm(old) / jnp.maximum(tree_norm(new), eps)
        out = tree_add(out, tree_scale(new, ratio / m))
    return out


def remove_client_effect(all_locals: dict, unlearn_clients: Sequence[int]) -> dict:
    """Preparation step: drop the unlearning clients' stored parameters from a
    {client_id: pytree} mapping (w^g_{s_i} = w^g_{C_si} - w^g_{C'_si})."""
    return {c: p for c, p in all_locals.items() if c not in set(unlearn_clients)}
