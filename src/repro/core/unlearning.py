"""SE (Sharding Eraser) unlearning engine: preparation (eq. 2) and calibrated
retraining (eq. 3), operating on parameter pytrees.

These are the *algebraic* operations; the FL loop that drives them lives in
``repro.fl.simulator`` (CPU paper-scale) and ``repro.fl.fedavg`` (pod-scale).
"""
from __future__ import annotations

from typing import List, Sequence

import jax
import jax.numpy as jnp


def tree_mean(trees: Sequence):
    """Average a list of pytrees — eq. (2)'s aggregation."""
    n = float(len(trees))
    return jax.tree.map(lambda *xs: sum(x.astype(jnp.float32) for x in xs) / n,
                        *trees)


def tree_add(a, b, scale: float = 1.0):
    return jax.tree.map(lambda x, y: x + scale * y.astype(x.dtype), a, b)


def tree_sub(a, b):
    return jax.tree.map(lambda x, y: x - y.astype(x.dtype), a, b)


def tree_norm(tree) -> jnp.ndarray:
    """Global L2 norm of a pytree (f32 accumulate)."""
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in jax.tree.leaves(tree)))


def tree_scale(tree, s):
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * s).astype(x.dtype), tree)


def stacked_mean(stacked) -> object:
    """FedAvg over a stacked (M, ...) pytree: one fused mean per leaf — the
    device-resident replacement for unstack + ``tree_mean``.

    The reduction is a strict left fold over the M rows, matching
    ``tree_mean``'s Python-``sum`` association exactly, so the fused round
    engine reproduces the legacy per-client loop bit-for-bit (f32 adds are
    order-sensitive; XLA keeps strict semantics and still fuses the chain).
    """
    def mean_leaf(a):
        a = a.astype(jnp.float32)
        acc = a[0]
        for i in range(1, a.shape[0]):
            acc = acc + a[i]
        return acc / a.shape[0]
    return jax.tree.map(mean_leaf, stacked)


def stacked_norms(stacked) -> jnp.ndarray:
    """(M,) global L2 norms of the rows of a stacked (M, ...) pytree — one
    vmap-style reduction instead of M host-synced ``tree_norm`` calls."""
    leaves = jax.tree.leaves(stacked)
    m = leaves[0].shape[0]
    sq = sum(jnp.sum(jnp.square(l.astype(jnp.float32).reshape(m, -1)), axis=1)
             for l in leaves)
    return jnp.sqrt(sq)


def stacked_sub(stacked, base):
    """Row-wise ``stacked - base`` (broadcast the unstacked base tree)."""
    return jax.tree.map(lambda a, b: a.astype(jnp.float32)
                        - b.astype(jnp.float32), stacked, base)


def calibrate_stacked(global_model, stacked_deltas, stored_norms: jnp.ndarray,
                      eps: float = 1e-12, use_kernel: bool = False):
    """eq. (3) on a stacked (M, ...) delta tree — the fused, device-resident
    form of ``calibrate``:

        w <- w + sum_m (||old_m|| / ||new_m|| / M) * new_m

    ``stored_norms``: (M,) historical update norms. With ``use_kernel`` the
    accumulate runs through the Pallas ``calibrate`` kernel on the flattened
    (M, P) delta matrix (one HBM pass); otherwise a per-leaf tensordot, which
    XLA fuses the same way.
    """
    m = jax.tree.leaves(stacked_deltas)[0].shape[0]
    new_norms = stacked_norms(stacked_deltas)
    coeffs = (stored_norms.astype(jnp.float32)
              / jnp.maximum(new_norms, eps)) / m
    if use_kernel:
        from repro.core import coding
        from repro.kernels.calibrate.ops import calibrate_update
        wf, spec = coding.tree_to_flat(global_model)
        df, _ = coding.tree_to_flat_stacked(stacked_deltas)
        return coding.flat_to_tree(calibrate_update(wf, df, coeffs), spec)
    return jax.tree.map(
        lambda w, d: (w.astype(jnp.float32)
                      + jnp.tensordot(coeffs, d.astype(jnp.float32), axes=1)
                      ).astype(w.dtype),
        global_model, stacked_deltas)


def prepare_initial_model(retained_locals: Sequence) -> object:
    """eq. (2): the initial unlearned global model is the average of the
    retained clients' stored local models (the unlearned clients' parameters
    have already been removed from the set)."""
    assert retained_locals, "no retained clients in shard"
    return tree_mean(retained_locals)


def calibrate(global_model, retrained_deltas: Sequence,
              stored_deltas: Sequence, eps: float = 1e-12):
    """eq. (3): one calibrated-retraining aggregation round.

        w^{g'+1} = w^{g'} + (1/M) * sum_m  (||w^g_m|| / ||w'^{g'}_m||) w'^{g'}_m

    ``retrained_deltas``: the retained clients' *new* local updates at
    unlearning round g' (trained with L/r epochs from the current unlearned
    global model).  ``stored_deltas``: the same clients' *historical* updates
    at the matching learning round g = g' — only their norms are used, to
    restore the update magnitude the full training had.
    """
    assert len(retrained_deltas) == len(stored_deltas)
    m = len(retrained_deltas)
    out = global_model
    for new, old in zip(retrained_deltas, stored_deltas):
        ratio = tree_norm(old) / jnp.maximum(tree_norm(new), eps)
        out = tree_add(out, tree_scale(new, ratio / m))
    return out


def remove_client_effect(all_locals: dict, unlearn_clients: Sequence[int]) -> dict:
    """Preparation step: drop the unlearning clients' stored parameters from a
    {client_id: pytree} mapping (w^g_{s_i} = w^g_{C_si} - w^g_{C'_si})."""
    return {c: p for c, p in all_locals.items() if c not in set(unlearn_clients)}
