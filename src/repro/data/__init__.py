from repro.data.synthetic import (ImageData, batch_iterator, lm_examples,  # noqa: F401
                                  make_char_data, make_image_data)
from repro.data.federated import (PARTITIONERS, client_datasets_images,  # noqa: F401
                                  client_datasets_lm, get_partitioner,
                                  partition_dirichlet, partition_iid,
                                  partition_zipf, register_partitioner)
