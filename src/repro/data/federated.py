"""Federated data partitioning (paper Sec 5.1), behind a registry.

Every partitioner maps ``(n, labels, num_clients, seed)`` to a list of
``num_clients`` disjoint index arrays and registers under a name
(``@register_partitioner``), generalizing the old ``iid: bool`` flag:

* ``iid``           — random equal split (the paper's IID setting).
* ``primary-class`` — 80% of each client from one class [Wang et al., 2020]
                      (the paper's non-IID classification setting).
* ``buckets``       — unbalanced dirichlet buckets, two per client (the
                      paper's non-IID language setting).
* ``dirichlet``     — Dirichlet(alpha) label skew [Hsu et al., 2019]: small
                      alpha -> each client concentrated on few classes.
* ``zipf``          — Zipf quantity skew: client k holds ~k^-exponent of the
                      data; large exponent -> heavy imbalance.

``labels`` may be ``None`` (generation tasks have no class labels);
label-skew partitioners raise an actionable error in that case.  All
partitioners are deterministic in ``seed`` — identical inputs reproduce the
partition bit-for-bit.
"""
from __future__ import annotations

import inspect
from typing import Callable, Dict, List, Optional, Tuple

import numpy as np

from repro.data.synthetic import ImageData

Partitioner = Callable[..., List[np.ndarray]]

PARTITIONERS: Dict[str, Partitioner] = {}


def register_partitioner(*names: str):
    """Decorator registering ``fn(n, labels, num_clients, seed, **params)``
    under ``names`` (the first is canonical)."""
    if not names:
        raise ValueError("register_partitioner needs at least one name")

    def deco(fn: Partitioner) -> Partitioner:
        fn.partitioner_name = names[0]
        for n in names:
            PARTITIONERS[n] = fn
        return fn
    return deco


def get_partitioner(name: str, **params) -> Partitioner:
    """Resolve a registered partitioner, with ``params`` (e.g. dirichlet
    ``alpha``) bound.  Unknown parameter names fail here — at resolution
    time — with the partitioner's accepted names, not as a deep
    ``TypeError`` inside data building."""
    try:
        fn = PARTITIONERS[name]
    except KeyError:
        raise ValueError(f"unknown partitioner {name!r}; registered: "
                         f"{sorted(PARTITIONERS)}") from None
    if not params:
        return fn
    sig = inspect.signature(fn)
    accepted = list(sig.parameters)[4:]          # after (n, labels, nc, seed)
    bad = sorted(set(params) - set(accepted))
    if bad:
        raise ValueError(
            f"invalid parameter(s) {bad} for partitioner {name!r}; "
            f"accepted: {accepted}")
    return lambda n, labels, num_clients, seed=0: fn(n, labels, num_clients,
                                                     seed, **params)


def _require_labels(labels, name: str):
    if labels is None:
        raise ValueError(
            f"partitioner {name!r} needs class labels (label skew), but the "
            f"task provides none (generation examples are unlabeled); use a "
            f"quantity-skew partitioner such as 'zipf' or 'buckets'")


def _spread_to_empty(parts: List[List[int]]) -> List[np.ndarray]:
    """Deterministically move samples from the largest clients to empty ones
    so every client trains on >=1 example."""
    total = sum(len(p) for p in parts)
    if total < len(parts):
        raise ValueError(
            f"cannot give each of {len(parts)} clients >=1 example from "
            f"{total} examples; increase samples_per_client or reduce "
            f"num_clients")
    for k, p in enumerate(parts):
        if not p:
            donor = max(range(len(parts)), key=lambda j: len(parts[j]))
            parts[k] = [parts[donor].pop()]
    return [np.asarray(sorted(p), np.int64) for p in parts]


# ---------------------------------------------------------------------------
# Seed partitioners (the paper's settings)
# ---------------------------------------------------------------------------

def partition_iid(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(perm, num_clients)]


def partition_noniid_classes(labels: np.ndarray, num_clients: int,
                             primary_frac: float = 0.8,
                             seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    n = len(labels)
    num_classes = int(labels.max()) + 1
    per_client = n // num_clients
    by_class = {c: list(rng.permutation(np.where(labels == c)[0]))
                for c in range(num_classes)}
    rest_pool = list(rng.permutation(n))
    out = []
    for k in range(num_clients):
        primary = k % num_classes
        n_prim = int(per_client * primary_frac)
        take = []
        pool = by_class[primary]
        take.extend(pool[:n_prim])
        by_class[primary] = pool[n_prim:]
        while len(take) < per_client and rest_pool:
            cand = rest_pool.pop()
            take.append(cand)
        out.append(np.asarray(sorted(take[:per_client]), np.int64))
    return out


def partition_noniid_buckets(n_examples: int, num_clients: int,
                             seed: int = 0) -> List[np.ndarray]:
    """Unbalanced buckets; each client is assigned two buckets."""
    rng = np.random.default_rng(seed)
    n_buckets = num_clients * 2
    # unbalanced bucket sizes via dirichlet
    sizes = rng.dirichlet(np.full(n_buckets, 0.5)) * n_examples
    sizes = np.maximum(sizes.astype(np.int64), 1)
    edges = np.minimum(np.cumsum(sizes), n_examples)
    buckets = np.split(np.arange(n_examples), edges[:-1])
    order = rng.permutation(n_buckets)
    return [np.concatenate([buckets[order[2 * k]], buckets[order[2 * k + 1]]])
            for k in range(num_clients)]


@register_partitioner("iid")
def _iid(n: int, labels, num_clients: int, seed: int = 0):
    return partition_iid(n, num_clients, seed)


@register_partitioner("primary-class", "noniid-classes")
def _primary_class(n: int, labels, num_clients: int, seed: int = 0,
                   primary_frac: float = 0.8):
    _require_labels(labels, "primary-class")
    return partition_noniid_classes(labels, num_clients,
                                    primary_frac=primary_frac, seed=seed)


@register_partitioner("buckets", "noniid-buckets")
def _buckets(n: int, labels, num_clients: int, seed: int = 0):
    return partition_noniid_buckets(n, num_clients, seed)


# ---------------------------------------------------------------------------
# Heterogeneity axes beyond the paper (FedShard / Hsu et al. style)
# ---------------------------------------------------------------------------

@register_partitioner("dirichlet")
def partition_dirichlet(n: int, labels, num_clients: int, seed: int = 0,
                        alpha: float = 0.5) -> List[np.ndarray]:
    """Dirichlet(alpha) label skew: for each class, the class's samples are
    split across clients by proportions drawn from Dir(alpha * 1).  Small
    alpha concentrates each class on few clients; alpha -> inf recovers an
    even spread."""
    _require_labels(labels, "dirichlet")
    if alpha <= 0:
        raise ValueError(f"dirichlet alpha must be > 0, got {alpha}")
    rng = np.random.default_rng(seed)
    num_classes = int(labels.max()) + 1
    parts: List[List[int]] = [[] for _ in range(num_clients)]
    for c in range(num_classes):
        idx = rng.permutation(np.where(labels == c)[0])
        props = rng.dirichlet(np.full(num_clients, alpha))
        cuts = (np.cumsum(props)[:-1] * len(idx)).astype(np.int64)
        for k, chunk in enumerate(np.split(idx, cuts)):
            parts[k].extend(int(i) for i in chunk)
    return _spread_to_empty(parts)


@register_partitioner("zipf")
def partition_zipf(n: int, labels, num_clients: int, seed: int = 0,
                   exponent: float = 1.2) -> List[np.ndarray]:
    """Zipf quantity skew: client k receives a share ~ (k+1)^-exponent of the
    examples (client 0 largest).  exponent=0 is an equal split; larger
    exponents concentrate the data on few clients."""
    if exponent < 0:
        raise ValueError(f"zipf exponent must be >= 0, got {exponent}")
    rng = np.random.default_rng(seed)
    weights = (1.0 / np.arange(1, num_clients + 1) ** exponent)
    shares = weights / weights.sum()
    sizes = np.maximum((shares * n).astype(np.int64), 1)
    # deterministic fixup so sizes sum exactly to n: trim/pad the largest
    sizes[0] += n - int(sizes.sum())
    if sizes[0] < 1:
        raise ValueError(
            f"zipf partition infeasible: {n} examples over {num_clients} "
            f"clients at exponent {exponent}; increase samples_per_client")
    perm = rng.permutation(n)
    edges = np.cumsum(sizes)[:-1]
    return [np.sort(p) for p in np.split(perm, edges)]


# ---------------------------------------------------------------------------
# Client-dataset builders (the ``iid: bool`` flag lives on as a shim)
# ---------------------------------------------------------------------------

def _resolve(partitioner: Optional[str], iid: Optional[bool],
             legacy_skew: str, **params) -> Partitioner:
    if partitioner is None:
        partitioner = "iid" if (iid is None or iid) else legacy_skew
    return get_partitioner(partitioner, **params)


def client_datasets_images(data: ImageData, num_clients: int,
                           iid: Optional[bool] = None, seed: int = 0,
                           partitioner: Optional[str] = None,
                           **params) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    part = _resolve(partitioner, iid, "primary-class", **params)
    parts = part(len(data.labels), data.labels, num_clients, seed)
    return {k: (data.images[idx], data.labels[idx])
            for k, idx in enumerate(parts)}


def client_datasets_lm(tokens: np.ndarray, labels: np.ndarray,
                       num_clients: int, iid: Optional[bool] = None,
                       seed: int = 0, partitioner: Optional[str] = None,
                       **params) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    part = _resolve(partitioner, iid, "buckets", **params)
    parts = part(len(tokens), None, num_clients, seed)
    return {k: (tokens[idx], labels[idx]) for k, idx in enumerate(parts)}
