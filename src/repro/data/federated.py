"""Federated data partitioning (paper Sec 5.1).

IID: random equal split across C clients.
Non-IID (classification): 80% of each client's samples from one primary
class, the rest uniform [Wang et al., 2020].
Non-IID (language): the stream is cut into unbalanced buckets; each client
gets two buckets.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

import numpy as np

from repro.data.synthetic import ImageData


def partition_iid(n: int, num_clients: int, seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    perm = rng.permutation(n)
    return [np.sort(p) for p in np.array_split(perm, num_clients)]


def partition_noniid_classes(labels: np.ndarray, num_clients: int,
                             primary_frac: float = 0.8,
                             seed: int = 0) -> List[np.ndarray]:
    rng = np.random.default_rng(seed)
    n = len(labels)
    num_classes = int(labels.max()) + 1
    per_client = n // num_clients
    by_class = {c: list(rng.permutation(np.where(labels == c)[0]))
                for c in range(num_classes)}
    rest_pool = list(rng.permutation(n))
    out = []
    for k in range(num_clients):
        primary = k % num_classes
        n_prim = int(per_client * primary_frac)
        take = []
        pool = by_class[primary]
        take.extend(pool[:n_prim])
        by_class[primary] = pool[n_prim:]
        while len(take) < per_client and rest_pool:
            cand = rest_pool.pop()
            take.append(cand)
        out.append(np.asarray(sorted(take[:per_client]), np.int64))
    return out


def partition_noniid_buckets(n_examples: int, num_clients: int,
                             seed: int = 0) -> List[np.ndarray]:
    """Unbalanced buckets; each client is assigned two buckets."""
    rng = np.random.default_rng(seed)
    n_buckets = num_clients * 2
    # unbalanced bucket sizes via dirichlet
    sizes = rng.dirichlet(np.full(n_buckets, 0.5)) * n_examples
    sizes = np.maximum(sizes.astype(np.int64), 1)
    edges = np.minimum(np.cumsum(sizes), n_examples)
    buckets = np.split(np.arange(n_examples), edges[:-1])
    order = rng.permutation(n_buckets)
    return [np.concatenate([buckets[order[2 * k]], buckets[order[2 * k + 1]]])
            for k in range(num_clients)]


def client_datasets_images(data: ImageData, num_clients: int, iid: bool,
                           seed: int = 0) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    parts = (partition_iid(len(data.labels), num_clients, seed) if iid
             else partition_noniid_classes(data.labels, num_clients, seed=seed))
    return {k: (data.images[idx], data.labels[idx]) for k, idx in enumerate(parts)}


def client_datasets_lm(tokens: np.ndarray, labels: np.ndarray, num_clients: int,
                       iid: bool, seed: int = 0) -> Dict[int, Tuple[np.ndarray, np.ndarray]]:
    n = len(tokens)
    parts = (partition_iid(n, num_clients, seed) if iid
             else partition_noniid_buckets(n, num_clients, seed))
    return {k: (tokens[idx], labels[idx]) for k, idx in enumerate(parts)}
