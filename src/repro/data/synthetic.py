"""Synthetic datasets standing in for MNIST/Fashion-MNIST/CIFAR-10 and Tiny
Shakespeare (no network access in this environment).

* image data: class-conditional smooth Gaussian patterns + pixel noise —
  learnable by the paper's CNN within a few epochs, and class structure makes
  membership-inference measurable.
* char data: a seeded stochastic grammar (zipf-weighted word inventory over a
  109-symbol alphabet, matching the paper's NanoGPT vocab) — produces text
  with real n-gram structure so the LM loss drops during training.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Tuple

import numpy as np


@dataclass
class ImageData:
    images: np.ndarray   # (N, H, W, C) float32 in [0,1]
    labels: np.ndarray   # (N,) int32


def make_image_data(n: int, num_classes: int = 10, image_size: int = 28,
                    channels: int = 1, noise: float = 0.35,
                    seed: int = 0, proto_seed: int = 1234) -> ImageData:
    """``seed`` draws the samples; ``proto_seed`` fixes the class prototypes,
    so different seeds give train/test splits of the SAME distribution."""
    proto_rng = np.random.default_rng(proto_seed)
    rng = np.random.default_rng(seed)
    # smooth class prototypes: superposed low-frequency sinusoids
    yy, xx = np.mgrid[0:image_size, 0:image_size] / image_size
    protos = np.zeros((num_classes, image_size, image_size, channels), np.float32)
    for c in range(num_classes):
        for ch in range(channels):
            for _ in range(3):
                fx, fy = proto_rng.uniform(1, 4, 2)
                ph = proto_rng.uniform(0, 2 * np.pi, 2)
                protos[c, :, :, ch] += np.sin(2 * np.pi * fx * xx + ph[0]) \
                    * np.sin(2 * np.pi * fy * yy + ph[1])
    protos = (protos - protos.min()) / (np.ptp(protos) + 1e-9)
    labels = rng.integers(0, num_classes, n).astype(np.int32)
    images = protos[labels] + noise * rng.standard_normal(
        (n, image_size, image_size, channels)).astype(np.float32)
    return ImageData(np.clip(images, 0, 1).astype(np.float32), labels)


def make_char_data(n_tokens: int, vocab_size: int = 109, seed: int = 0,
                   n_words: int = 400) -> np.ndarray:
    """Token stream with zipfian word structure (word = 2-8 symbol string)."""
    rng = np.random.default_rng(seed)
    space = 0
    words = [rng.integers(1, vocab_size, rng.integers(2, 9)).tolist()
             for _ in range(n_words)]
    ranks = np.arange(1, n_words + 1, dtype=np.float64)
    probs = (1 / ranks) / (1 / ranks).sum()
    out = []
    while len(out) < n_tokens:
        w = words[rng.choice(n_words, p=probs)]
        out.extend(w)
        out.append(space)
    return np.asarray(out[:n_tokens], np.int32)


def batch_iterator(data, labels, batch: int, seed: int = 0, epochs: int = 1):
    rng = np.random.default_rng(seed)
    n = len(data)
    for _ in range(epochs):
        perm = rng.permutation(n)
        for i in range(0, n - batch + 1, batch):
            idx = perm[i:i + batch]
            yield data[idx], labels[idx]


def lm_examples(stream: np.ndarray, seq_len: int) -> Tuple[np.ndarray, np.ndarray]:
    """Chop a token stream into (tokens, labels) next-token pairs."""
    n = (len(stream) - 1) // seq_len
    toks = stream[: n * seq_len].reshape(n, seq_len)
    labs = stream[1: n * seq_len + 1].reshape(n, seq_len)
    return toks.astype(np.int32), labs.astype(np.int32)
