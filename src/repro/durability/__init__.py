"""Crash-consistent durability: snapshots, write-ahead journal, recovery.

* ``snapshot``     — versioned, checksummed snapshot format for pytrees +
  store state (coded slices incl. bf16, coding keys, ``StoreStats``) with
  atomic rename-commit.
* ``journal``      — append-only, per-record-checksummed write-ahead log
  of session events (stage completions, request dispatch/commit).
* ``checkpointer`` — ``CheckpointManager``: snapshot rotation with
  corrupt-snapshot fallback, paired with the journal.
* ``session_state`` — capture/restore of ``FederatedSession`` state (the
  resume path; imported lazily to avoid a cycle with the session module).

Wired through ``FederatedSession(checkpoint_every=, checkpoint_dir=)`` /
``ScenarioConfig`` and ``UnlearningService(journal=)``; crash injection
lives in ``repro.faults`` (``process_kill`` / ``torn_write``).
"""
from repro.durability.checkpointer import CheckpointManager
from repro.durability.journal import Journal, replay
from repro.durability.snapshot import (SnapshotCorruption, load_snapshot,
                                       save_snapshot)

__all__ = [
    "CheckpointManager", "Journal", "replay",
    "SnapshotCorruption", "load_snapshot", "save_snapshot",
    "capture_session", "restore_session",
]


def __getattr__(name):
    # session_state pulls in repro.fl.experiment.session; load lazily so
    # importing repro.durability from the session module itself is cycle-free
    if name in ("capture_session", "restore_session"):
        from repro.durability import session_state
        return getattr(session_state, name)
    raise AttributeError(name)
