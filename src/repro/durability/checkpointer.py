"""Snapshot rotation + journal pairing: the durable face of a session.

A ``CheckpointManager`` owns one directory::

    <dir>/snap-000003.ckpt     # versioned, checksummed snapshots
    <dir>/journal.wal          # write-ahead journal (append-only)

``save`` commits a snapshot atomically (``repro.durability.snapshot``),
fires the fault plan's ``post_snapshot`` hook (the torn-write injector's
site), and prunes old snapshots — always keeping at least the two most
recent, so a snapshot corrupted *after* commit still has a good
predecessor to fall back to.  ``load_latest`` walks snapshots newest-first
and skips any that fail checksum validation (recording them in
``skipped``), returning the newest *good* state.
"""
from __future__ import annotations

import os
import re
from typing import List, Optional, Tuple

from repro.durability.journal import Journal
from repro.durability.snapshot import (SnapshotCorruption, load_snapshot,
                                       save_snapshot)

_SNAP_RE = re.compile(r"^snap-(\d{6})\.ckpt$")


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 2, faults=None):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.keep = max(int(keep), 2)
        self.faults = faults                       # optional FaultPlan
        self.journal = Journal(os.path.join(directory, "journal.wal"))
        self.skipped: List[str] = []               # corrupt snaps last load
        self.last_save_bytes = 0

    def snapshot_path(self, step: int) -> str:
        return os.path.join(self.directory, f"snap-{step:06d}.ckpt")

    def steps(self) -> List[int]:
        out = []
        for name in os.listdir(self.directory):
            m = _SNAP_RE.match(name)
            if m:
                out.append(int(m.group(1)))
        return sorted(out)

    def save(self, state, step: int) -> str:
        from repro.telemetry import get_tracer
        path = self.snapshot_path(step)
        with get_tracer().span("durability.snapshot", step=step) as sp:
            self.last_save_bytes = save_snapshot(path, state)
            sp.annotate(bytes=self.last_save_bytes)
            if self.faults is not None and hasattr(self.faults,
                                                   "post_snapshot"):
                self.faults.post_snapshot(path, step)
            self._prune()
        return path

    def load_latest(self) -> Optional[Tuple[object, int, str]]:
        """Newest good ``(state, step, path)``; corrupt snapshots are skipped
        (collected in ``self.skipped``) — the torn-write fallback path."""
        from repro.telemetry import get_tracer
        self.skipped = []
        with get_tracer().span("durability.restore") as sp:
            for step in reversed(self.steps()):
                path = self.snapshot_path(step)
                try:
                    state = load_snapshot(path)
                    sp.annotate(step=step, skipped=len(self.skipped))
                    return state, step, path
                except SnapshotCorruption:
                    self.skipped.append(path)
        return None

    def _prune(self) -> None:
        for step in self.steps()[:-self.keep]:
            try:
                os.remove(self.snapshot_path(step))
            except OSError:
                pass
