"""Write-ahead journal of session/service events.

An append-only, per-line-checksummed JSONL file: each record is
``<crc32 hex> <json {"seq": n, "ev": {...}}>``, fsynced on append so a
committed record survives a process kill.  ``replay`` tolerates a torn
tail — the one partially-written record a crash mid-append can leave —
by stopping at the first line that fails its checksum or fails to parse;
everything before it is trusted (each line carries its own crc).

Sequence numbers continue across reopens, so a resumed session appends to
the same journal and replay yields one totally-ordered event history.
"""
from __future__ import annotations

import json
import os
import zlib
from typing import List, Optional


def replay(path: str) -> List[dict]:
    """Parse the journal at ``path`` into ``[{"seq": n, "ev": {...}}, ...]``,
    stopping at the first corrupt or truncated record (torn tail)."""
    out: List[dict] = []
    if not os.path.exists(path):
        return out
    with open(path, "rb") as f:
        data = f.read()
    for raw in data.split(b"\n"):
        if not raw:
            continue
        try:
            crc_hex, rec = raw.split(b" ", 1)
            if int(crc_hex, 16) != zlib.crc32(rec):
                break
            row = json.loads(rec)
        except ValueError:
            break
        out.append(row)
    return out


class Journal:
    """Append-only write-ahead log.  ``append`` is durable (fsync per
    record); ``events`` replays the on-disk history (prior runs included)."""

    def __init__(self, path: str):
        self.path = path
        existing = replay(path)
        self._seq = existing[-1]["seq"] + 1 if existing else 0
        self._f: Optional[object] = None

    def append(self, event: dict) -> int:
        """Durably append one event; returns its sequence number."""
        rec = json.dumps({"seq": self._seq, "ev": event}, sort_keys=True)
        if self._f is None:
            self._f = open(self.path, "a")
        from repro.telemetry import get_tracer
        tr = get_tracer()
        with tr.span("durability.journal_append",
                     kind=str(event.get("ev", "?"))[:24]):
            self._f.write(f"{zlib.crc32(rec.encode()):08x} {rec}\n")
            self._f.flush()
            os.fsync(self._f.fileno())
        tr.metrics.counter("durability.journal_appends").inc()
        seq, self._seq = self._seq, self._seq + 1
        return seq

    def events(self) -> List[dict]:
        """The full replayed event history (``ev`` payloads, in order)."""
        return [row["ev"] for row in replay(self.path)]

    def records(self) -> List[dict]:
        """Replayed records including sequence numbers."""
        return replay(self.path)

    def close(self) -> None:
        if self._f is not None:
            self._f.close()
            self._f = None
