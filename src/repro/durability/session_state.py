"""Capture/restore a ``FederatedSession``'s durable state.

``capture_session`` turns the session's live state into a plain object
graph the snapshot format can serialize exactly: the shard manager's RNG
state and stage plans, every completed ``StageRecord`` (plan, shard
models, materialized round globals, history norms, and the parameter
store's contents — coded slices + specs + layouts, or per-client trees),
the ``SessionReport`` (including per-request ``UnlearnResult`` models),
and the set of served request ids.

``restore_session`` rebuilds that state onto a *freshly constructed*
session of the same configuration (same simulator seed / store kind /
engine).  Because stage training is deterministic given the restored RNG
state, a resumed ``run`` re-trains post-snapshot stages bit-identically —
the durability acceptance test's whole premise.
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np

from repro.stores.store import (CodedStore, FullStore, StoreStats,
                                UncodedShardStore, _StackedRow)
from repro.tiering.budget import MemoryBudget
from repro.tiering.store import TieredStore
from repro.tiering.tiers import TierEntry, cold_file_crc

STATE_VERSION = 1


def _materialize(tree):
    return tree.materialize() if isinstance(tree, _StackedRow) else tree


# ---------------------------------------------------------------------------
# Stores
# ---------------------------------------------------------------------------

def _capture_store(store) -> dict:
    if isinstance(store, TieredStore):      # before CodedStore: a subclass
        store.flush()
        entries = {}
        for rnd, e in store._slices.entries().items():
            ent = {"tier": e.tier, "hits": int(e.hits),
                   "last_access": int(e.last_access), "stage": int(e.stage),
                   "lossy": bool(e.lossy),
                   "shape": (int(e.shape[0]), int(e.shape[1])),
                   "dtype": np.dtype(e.dtype).name,
                   "scales": e.scales,
                   # cold pointer: basename + crc — the file itself is NOT
                   # copied into the snapshot; resume revalidates it in place
                   "path": (os.path.basename(e.path) if e.path else None),
                   "file_crc": e.file_crc}
            if e.tier == "hot":
                ent["device"] = e.device
            if e.q is not None and e.path is None:
                ent["q"] = e.q              # RAM-only lossy payload
            entries[rnd] = ent
        return {"kind": "tiered",
                "scheme": store.scheme,
                "shard_clients": store.shard_clients,
                "use_kernel": bool(store.use_kernel),
                "slice_dtype": (np.dtype(store.slice_dtype).name
                                if store.slice_dtype is not None else None),
                "group_rounds": int(store.group_rounds),
                "budget": store.budget.to_dict(),
                "eviction": store.eviction,
                "promote_on_read": bool(store.promote_on_read),
                "offload_dir": store.offload_dir,
                "cold_dir": store._cold_dir,
                "seq": int(store._slices._seq),
                "births": int(store._slices._births),
                "entries": entries,
                "specs": dict(store._specs),
                "layouts": dict(store._layouts),
                "stats": store.stats}
    if isinstance(store, CodedStore):
        store.flush()                       # materialize deferred encodes
        return {"kind": "coded",
                "scheme": store.scheme,
                "shard_clients": store.shard_clients,
                "use_kernel": bool(store.use_kernel),
                "slice_dtype": (np.dtype(store.slice_dtype).name
                                if store.slice_dtype is not None else None),
                "group_rounds": int(store.group_rounds),
                "slices": dict(store._slices),
                "specs": dict(store._specs),
                "layouts": dict(store._layouts),
                "stats": store.stats}
    if isinstance(store, UncodedShardStore):
        return {"kind": "uncoded",
                "data": {k: _materialize(v) for k, v in store._data.items()},
                "shards": store._shards,
                "shard_of": store.shard_of,
                "per_shard": store._per_shard,
                "stats": store.stats}
    if isinstance(store, FullStore):
        return {"kind": "full",
                "data": {k: _materialize(v) for k, v in store._data.items()},
                "shards": store._shards,
                "stats": store.stats}
    raise TypeError(f"cannot capture store of type {type(store).__name__}; "
                    f"durable sessions support full/uncoded/coded/tiered")


def _restore_store(st: dict):
    kind = st["kind"]
    if kind == "tiered":
        dtype = np.dtype(st["slice_dtype"]) if st["slice_dtype"] else None
        store = TieredStore(st["scheme"], st["shard_clients"],
                            use_kernel=st["use_kernel"], slice_dtype=dtype,
                            group_rounds=st["group_rounds"],
                            budget=MemoryBudget(**st["budget"]),
                            eviction=st["eviction"],
                            offload_dir=st["offload_dir"],
                            promote_on_read=st["promote_on_read"])
        store._cold_dir = st["cold_dir"]
        table = store._slices
        for rnd, ent in st["entries"].items():
            path = None
            if ent["path"] is not None:
                path = os.path.join(st["cold_dir"], ent["path"])
                if not os.path.exists(path):
                    raise FileNotFoundError(
                        f"cold-tier file missing on resume: {path}")
                if ent["file_crc"] is not None \
                        and cold_file_crc(path) != ent["file_crc"]:
                    raise IOError(f"cold-tier file corrupted: {path} "
                                  f"(crc mismatch)")
            scales = ent["scales"]
            table._entries[rnd] = TierEntry(
                key=rnd, shape=tuple(ent["shape"]),
                dtype=jnp.dtype(ent["dtype"]), tier=ent["tier"],
                device=ent.get("device"),
                q=(np.asarray(ent["q"], np.int8).reshape(ent["shape"])
                   if "q" in ent else None),
                scales=(np.asarray(scales, np.float32)
                        if scales is not None else None),
                path=path, file_crc=ent["file_crc"], lossy=ent["lossy"],
                hits=ent["hits"], last_access=ent["last_access"],
                stage=ent["stage"])
        table._seq = st["seq"]
        table._births = st["births"]
        store._specs = dict(st["specs"])
        store._layouts = dict(st["layouts"])
        store.stats = st["stats"]
        return store
    if kind == "coded":
        dtype = np.dtype(st["slice_dtype"]) if st["slice_dtype"] else None
        store = CodedStore(st["scheme"], st["shard_clients"],
                           use_kernel=st["use_kernel"], slice_dtype=dtype,
                           group_rounds=st["group_rounds"])
        store._slices = dict(st["slices"])
        store._specs = dict(st["specs"])
        store._layouts = dict(st["layouts"])
        store.stats = st["stats"]
        return store
    if kind == "uncoded":
        store = UncodedShardStore(st["shard_of"])
        store._per_shard = dict(st["per_shard"])
    elif kind == "full":
        store = FullStore()
    else:
        raise ValueError(f"unknown store kind {kind!r} in snapshot")
    store._data = dict(st["data"])
    store._shards = dict(st["shards"])
    store.stats = st["stats"]
    return store


# ---------------------------------------------------------------------------
# Records + report
# ---------------------------------------------------------------------------

def _capture_record(record) -> dict:
    return {"plan": record.plan,
            "shard_models": dict(record.shard_models),
            # materialize lazy StackedRoundGlobals views into plain lists
            "round_globals": {s: list(v)
                              for s, v in record.round_globals.items()},
            "history_norms": dict(record.history_norms),
            "store": _capture_store(record.store)}


def _restore_record(st: dict):
    from repro.fl.simulator import StageRecord
    return StageRecord(plan=st["plan"], shard_models=st["shard_models"],
                       round_globals=st["round_globals"],
                       store=_restore_store(st["store"]),
                       history_norms=st["history_norms"])


def _capture_result(res, live_stats) -> dict:
    # the serving paths hand UnlearnResult the record store's LIVE StoreStats
    # object, so later reads mutate already-recorded results; a restored
    # report must re-alias (not copy) to stay bit-identical with the
    # uninterrupted run
    return {"framework": res.framework, "models": dict(res.models),
            "wall_time": float(res.wall_time),
            "cost_units": float(res.cost_units),
            "store_stats": res.store_stats,
            "stats_live": res.store_stats is live_stats,
            "impacted_shards": [int(s) for s in res.impacted_shards],
            "request_id": getattr(res, "request_id", "")}


def _restore_result(st: dict):
    from repro.fl.simulator import UnlearnResult
    return UnlearnResult(framework=st["framework"], models=st["models"],
                         wall_time=st["wall_time"],
                         cost_units=st["cost_units"],
                         store_stats=st["store_stats"],
                         impacted_shards=st["impacted_shards"],
                         request_id=st.get("request_id", ""))


def _capture_report(report, records) -> dict:
    return {"store_kind": report.store_kind,
            "stages": [{"stage": s.stage, "plan_stage": s.plan_stage,
                        "train_wall": float(s.train_wall),
                        "num_shards": int(s.num_shards),
                        "clients": [int(c) for c in s.clients],
                        "store_stats": s.store_stats,
                        "unlearn": [_capture_result(
                            u, records[s.stage].store.stats)
                            for u in s.unlearn]}
                       for s in report.stages]}


def _restore_report(st: dict, records):
    from repro.fl.experiment.session import SessionReport, StageReport
    report = SessionReport(store_kind=st["store_kind"])
    for s in st["stages"]:
        unlearn = []
        for u in s["unlearn"]:
            res = _restore_result(u)
            if u.get("stats_live"):
                res.store_stats = records[s["stage"]].store.stats
            unlearn.append(res)
        report.stages.append(StageReport(
            stage=s["stage"], plan_stage=s["plan_stage"],
            train_wall=s["train_wall"], num_shards=s["num_shards"],
            clients=s["clients"], store_stats=s["store_stats"],
            unlearn=unlearn))
    return report


# ---------------------------------------------------------------------------
# Session
# ---------------------------------------------------------------------------

def capture_session(session) -> dict:
    sim = session.sim
    return {
        "version": STATE_VERSION,
        "store_kind": session.store_kind,
        "engine": session.engine,
        "seed": int(sim.seed),
        "num_stages": len(session.records),
        "rng_state": sim.mgr._rng.bit_generator.state,
        "mgr_stages": list(sim.mgr.stages),
        "records": [_capture_record(r) for r in session.records],
        "report": _capture_report(session.report, session.records),
        "served": sorted(session._served),
    }


def restore_session(session, state: dict) -> int:
    """Load ``state`` (from ``capture_session``) into ``session``; returns
    the number of completed stages restored.  The session must be freshly
    built with the same configuration the snapshot was taken under."""
    if state.get("version") != STATE_VERSION:
        raise ValueError(f"snapshot state version {state.get('version')!r} "
                         f"!= supported {STATE_VERSION}")
    for knob in ("store_kind", "engine"):
        if state[knob] != getattr(session, knob):
            raise ValueError(
                f"snapshot was taken with {knob}={state[knob]!r} but this "
                f"session has {knob}={getattr(session, knob)!r}; resume "
                f"needs an identically configured session")
    if state["seed"] != session.sim.seed:
        raise ValueError(f"snapshot seed {state['seed']} != simulator seed "
                         f"{session.sim.seed}; resumed training would "
                         f"diverge from the original run")
    session.sim.mgr._rng.bit_generator.state = state["rng_state"]
    session.sim.mgr.stages = list(state["mgr_stages"])
    session.records = [_restore_record(r) for r in state["records"]]
    session.report = _restore_report(state["report"], session.records)
    session._served = set(state["served"])
    return int(state["num_stages"])
