"""Versioned, checksummed snapshot format with atomic rename-commit.

One snapshot file holds one Python object graph — pytrees of jax/numpy
arrays (any dtype, including the coded store's bf16 slices), containers
with non-string keys, coding keys (``CodingScheme``), re-assembly specs
(``StackedRowSpec`` and raw ``(treedef, shapes)`` pairs), ``StoreStats``,
and ``StagePlan``.  The encoding is exact: array payloads are raw bytes
(dtype/shape preserved bit-for-bit, never promoted), scalars ride in the
JSON header (Python's json round-trips finite floats exactly via repr).

File layout::

    MAGIC "REPROSN1" | u32 version | u64 header_len | u64 payload_len
    | u32 header_crc32 | u32 payload_crc32 | header JSON | array payload

``save_snapshot`` commits atomically: write to ``<path>.tmp``, fsync,
``os.replace`` onto ``path``, fsync the directory — a crash mid-write can
only ever leave the tmp file behind, never a half-written snapshot under
the committed name.  ``load_snapshot`` validates magic, declared lengths,
and both checksums before decoding; any mismatch (torn write, truncation,
bit corruption) raises ``SnapshotCorruption`` so recovery can fall back to
an earlier snapshot instead of silently loading garbage.
"""
from __future__ import annotations

import json
import os
import struct
import zlib
from dataclasses import asdict

import jax
import jax.numpy as jnp
import numpy as np

try:  # registers bfloat16 (and friends) with numpy's dtype lookup
    import ml_dtypes  # noqa: F401
except ImportError:  # pragma: no cover - baked into the image
    pass

from repro.core import coding
from repro.core.sharding import StagePlan
from repro.stores.store import StoreStats

MAGIC = b"REPROSN1"
VERSION = 1
_FIXED = struct.Struct("<IQQII")       # version, hlen, plen, hcrc, pcrc


class SnapshotCorruption(RuntimeError):
    """A snapshot failed structural or checksum validation (torn write,
    truncation, or bit corruption).  Recovery falls back to the previous
    good snapshot (``CheckpointManager.load_latest``)."""


def _treedef_type():
    return type(jax.tree.structure(0))


# ---------------------------------------------------------------------------
# Encode
# ---------------------------------------------------------------------------

def _enc(obj, arrays: list, blobs: list):
    """Recursively encode ``obj`` to a JSON-able node; array data lands in
    ``blobs`` with its geometry in ``arrays``."""
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    if isinstance(obj, (jax.Array, np.ndarray, np.generic)):
        is_jax = isinstance(obj, jax.Array)
        a = np.ascontiguousarray(np.asarray(jax.device_get(obj)))
        idx = len(arrays)
        arrays.append({"dtype": a.dtype.name, "shape": list(a.shape),
                       "nbytes": int(a.nbytes), "jax": is_jax})
        blobs.append(a.tobytes())
        return {"_t": "arr", "i": idx}
    if isinstance(obj, np.dtype):
        return {"_t": "dtype", "name": obj.name}
    if isinstance(obj, StoreStats):
        return {"_t": "StoreStats", "v": _enc(asdict(obj), arrays, blobs)}
    if isinstance(obj, coding.CodingScheme):
        return {"_t": "CodingScheme",
                "S": obj.num_shards, "C": obj.num_clients,
                "alpha": _enc(np.asarray(obj.alpha), arrays, blobs),
                "omega": _enc(np.asarray(obj.omega), arrays, blobs)}
    if isinstance(obj, coding.StackedRowSpec):
        return {"_t": "StackedRowSpec",
                "clients": [int(c) for c in obj.client_ids],
                "row_len": int(obj.row_len),
                "row_spec": _enc(obj.row_spec, arrays, blobs)}
    if isinstance(obj, StagePlan):
        return {"_t": "StagePlan", "stage": int(obj.stage),
                "shard_clients": _enc(obj.shard_clients, arrays, blobs)}
    if isinstance(obj, _treedef_type()):
        # the example-tree trick: a treedef is exactly the structure of the
        # tree it unflattens int placeholders into
        example = jax.tree.unflatten(obj, list(range(obj.num_leaves)))
        return {"_t": "treedef", "example": _enc(example, arrays, blobs)}
    if isinstance(obj, tuple):
        return {"_t": "tuple", "v": [_enc(x, arrays, blobs) for x in obj]}
    if isinstance(obj, list):
        return {"_t": "list", "v": [_enc(x, arrays, blobs) for x in obj]}
    if isinstance(obj, (set, frozenset)):
        return {"_t": "set", "v": [_enc(x, arrays, blobs)
                                   for x in sorted(obj, key=repr)]}
    if isinstance(obj, dict):
        return {"_t": "dict", "v": [[_enc(k, arrays, blobs),
                                     _enc(v, arrays, blobs)]
                                    for k, v in obj.items()]}
    raise TypeError(f"snapshot cannot encode {type(obj).__name__}: {obj!r}")


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------

def _dec(node, arrays: list):
    if node is None or isinstance(node, (bool, int, float, str)):
        return node
    t = node["_t"]
    if t == "arr":
        return arrays[node["i"]]
    if t == "dtype":
        return np.dtype(node["name"])
    if t == "StoreStats":
        return StoreStats(**_dec(node["v"], arrays))
    if t == "CodingScheme":
        return coding.CodingScheme(
            num_shards=node["S"], num_clients=node["C"],
            alpha=np.asarray(_dec(node["alpha"], arrays)),
            omega=np.asarray(_dec(node["omega"], arrays)))
    if t == "StackedRowSpec":
        return coding.StackedRowSpec(tuple(node["clients"]), node["row_len"],
                                     _dec(node["row_spec"], arrays))
    if t == "StagePlan":
        return StagePlan(stage=node["stage"],
                         shard_clients=_dec(node["shard_clients"], arrays))
    if t == "treedef":
        return jax.tree.structure(_dec(node["example"], arrays))
    if t == "tuple":
        return tuple(_dec(x, arrays) for x in node["v"])
    if t == "list":
        return [_dec(x, arrays) for x in node["v"]]
    if t == "set":
        return set(_dec(x, arrays) for x in node["v"])
    if t == "dict":
        return {_dec(k, arrays): _dec(v, arrays) for k, v in node["v"]}
    raise SnapshotCorruption(f"unknown node tag {t!r}")


def _decode_array(meta: dict, payload: bytes) -> object:
    a = np.frombuffer(payload[meta["off"]: meta["off"] + meta["nbytes"]],
                      dtype=np.dtype(meta["dtype"]))
    a = a.reshape(tuple(meta["shape"]))
    return jnp.asarray(a) if meta["jax"] else a.copy()


# ---------------------------------------------------------------------------
# File I/O
# ---------------------------------------------------------------------------

def save_snapshot(path: str, obj) -> int:
    """Serialize ``obj`` to ``path`` with an atomic rename-commit.  Returns
    the committed file size in bytes."""
    arrays: list = []
    blobs: list = []
    root = _enc(obj, arrays, blobs)
    off = 0
    for meta, blob in zip(arrays, blobs):
        meta["off"] = off
        off += len(blob)
    header = json.dumps({"version": VERSION, "arrays": arrays, "root": root},
                        separators=(",", ":")).encode()
    payload = b"".join(blobs)
    buf = b"".join([MAGIC,
                    _FIXED.pack(VERSION, len(header), len(payload),
                                zlib.crc32(header), zlib.crc32(payload)),
                    header, payload])
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return len(buf)


def load_snapshot(path: str):
    """Read, validate (magic, lengths, both checksums), and decode ``path``.
    Raises ``SnapshotCorruption`` on any validation failure."""
    try:
        with open(path, "rb") as f:
            buf = f.read()
    except OSError as exc:
        raise SnapshotCorruption(f"unreadable snapshot {path}: {exc}") from exc
    fixed_end = len(MAGIC) + _FIXED.size
    if len(buf) < fixed_end or buf[:len(MAGIC)] != MAGIC:
        raise SnapshotCorruption(f"{path}: bad magic or truncated preamble")
    version, hlen, plen, hcrc, pcrc = _FIXED.unpack_from(buf, len(MAGIC))
    if version != VERSION:
        raise SnapshotCorruption(f"{path}: unsupported version {version}")
    if len(buf) != fixed_end + hlen + plen:
        raise SnapshotCorruption(
            f"{path}: size {len(buf)} != declared {fixed_end + hlen + plen} "
            f"(torn write)")
    header = buf[fixed_end: fixed_end + hlen]
    payload = buf[fixed_end + hlen:]
    if zlib.crc32(header) != hcrc:
        raise SnapshotCorruption(f"{path}: header checksum mismatch")
    if zlib.crc32(payload) != pcrc:
        raise SnapshotCorruption(f"{path}: payload checksum mismatch")
    hd = json.loads(header)
    arrays = [_decode_array(meta, payload) for meta in hd["arrays"]]
    return _dec(hd["root"], arrays)
