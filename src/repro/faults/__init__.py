"""Deterministic fault injection and recovery (the chaos harness).

``FaultPlan`` composes seeded injectors (registered in ``INJECTORS``) that
fire at well-defined sites in the store, session, and service layers; every
firing and every downstream recovery decision lands in a ``FaultLedger``
whose ``signature()`` is reproducible bit-for-bit from the plan seed.
"""
from repro.faults.events import (DegradedModeEvent, DeviceFault, FaultError,
                                 FaultEvent, FaultLedger, InjectedCrash,
                                 JobHang, RecoveryEvent, TransientJobError)
from repro.faults.plan import (INJECTORS, FaultInjector, FaultPlan,
                               chaos_plan, make_injector, register_injector)

__all__ = [
    "DegradedModeEvent", "DeviceFault", "FaultError", "FaultEvent",
    "FaultLedger", "InjectedCrash", "JobHang", "RecoveryEvent",
    "TransientJobError",
    "INJECTORS", "FaultInjector", "FaultPlan", "chaos_plan",
    "make_injector", "register_injector",
]
