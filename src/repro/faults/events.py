"""Fault events, typed fault errors, and the deterministic fault ledger.

Every injected fault and every recovery decision taken downstream (retry,
re-dispatch, degraded-mode fallback, abort) is recorded as a structured
event in a ``FaultLedger``.  Because injection decisions are pure functions
of ``(plan seed, site key)`` (see ``repro.faults.plan``), replaying the same
fault plan against the same workload reproduces the *identical* ledger —
``FaultLedger.signature()`` is the canonical, thread-order-independent form
two runs are compared by.
"""
from __future__ import annotations

import threading
from collections import Counter
from dataclasses import dataclass, field
from typing import Dict, List, Tuple


# ---------------------------------------------------------------------------
# Typed fault errors (raised at injection sites, handled by recovery paths)
# ---------------------------------------------------------------------------

class FaultError(RuntimeError):
    """Base class for injected faults the recovery paths know how to handle.
    Anything *not* derived from this propagates — a chaos run must never
    swallow a genuine bug."""


class DeviceFault(FaultError):
    """A device failed: every job routed to it errors until it is marked
    unhealthy and traffic re-dispatches elsewhere."""

    def __init__(self, device: int):
        self.device = int(device)
        super().__init__(f"device {device} failed")


class JobHang(FaultError):
    """A job hung on its device.  The engine sleeps the (budget-capped)
    simulated hang, then treats the attempt as timed out.  Real stuck XLA
    programs cannot be preempted from a worker thread — a genuine hang needs
    process-level isolation; this models the *scheduling* consequence."""

    def __init__(self, device: int, hang_s: float):
        self.device = int(device)
        self.hang_s = float(hang_s)
        super().__init__(f"job hung on device {device} ({hang_s:.3f}s)")


class TransientJobError(FaultError):
    """A transient job exception (e.g. a flaky collective): retrying the
    same job — on the same or another device — is expected to succeed."""

    def __init__(self, key):
        self.key = key
        super().__init__(f"transient failure in job {key!r}")


class InjectedCrash(FaultError):
    """An injected process crash (the ``process_kill`` injector's in-process
    ``mode="raise"`` form): the session dies at a named crash site, leaving
    only its snapshots + journal behind.  Recovery is a *restart* —
    ``FederatedSession.run(resume_from=...)`` — not a retry."""

    def __init__(self, site):
        self.site = tuple(site)
        super().__init__(f"injected process crash at {self.site!r}")


# ---------------------------------------------------------------------------
# Structured events
# ---------------------------------------------------------------------------

def _canon(value):
    """Canonicalize event payloads so ``signature`` sorts deterministically."""
    if isinstance(value, dict):
        return tuple(sorted((k, _canon(v)) for k, v in value.items()))
    if isinstance(value, (list, tuple, set, frozenset)):
        return tuple(_canon(v) for v in (sorted(value)
                                         if isinstance(value, (set, frozenset))
                                         else value))
    if isinstance(value, float):
        return round(value, 9)
    return value


@dataclass(frozen=True)
class FaultEvent:
    """One injected fault: which injector fired, where, and with what."""
    kind: str                       # injector name, e.g. "slice_corruption"
    site: Tuple                     # deterministic site key it was drawn at
    detail: Tuple = ()              # canonicalized injector payload

    def to_dict(self) -> dict:
        return {"kind": self.kind, "site": list(map(str, self.site)),
                "detail": str(self.detail)}


@dataclass(frozen=True)
class DegradedModeEvent:
    """A subsystem degraded instead of failing: e.g. mid-stage client
    dropout made the stage ragged, so the stage-program engine fell back to
    the per-shard fused path (PR 3's ragged path) rather than raising."""
    kind: str = field(default="degraded_mode", init=False)
    stage: int = 0
    reason: str = ""
    fallback: str = ""
    dropped_clients: Tuple[int, ...] = ()

    @property
    def site(self) -> Tuple:
        return ("stage", self.stage)

    @property
    def detail(self) -> Tuple:
        return (self.reason, self.fallback, self.dropped_clients)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "stage": self.stage, "reason": self.reason,
                "fallback": self.fallback,
                "dropped_clients": list(self.dropped_clients)}


@dataclass(frozen=True)
class RecoveryEvent:
    """One recovery decision taken downstream of a fault: a retry, a
    re-dispatch to a healthy device, a quorum-read decode, or an abort."""
    kind: str                       # "retry" | "redispatch" | "abort" | ...
    site: Tuple
    detail: Tuple = ()

    def to_dict(self) -> dict:
        return {"kind": self.kind, "site": list(map(str, self.site)),
                "detail": str(self.detail)}


class FaultLedger:
    """Thread-safe, append-only record of fault/recovery events.

    Worker threads record concurrently, so the in-memory order is not
    deterministic — ``signature()`` (sorted canonical tuples) is, and it is
    what replay tests compare.
    """

    def __init__(self):
        self._events: List = []
        self._lock = threading.Lock()

    def record(self, event) -> None:
        with self._lock:
            self._events.append(event)
        # single telemetry hook: every injection/recovery/degradation flows
        # through here, so the tracer sees them all as instant events
        from repro.telemetry import get_tracer
        tr = get_tracer()
        if tr.enabled:
            family = ("fault.recovery" if isinstance(event, RecoveryEvent)
                      else "fault.degraded"
                      if isinstance(event, DegradedModeEvent)
                      else "fault.inject")
            tr.event(family, kind=event.kind, site=str(event.site))
            tr.metrics.counter(family, kind=event.kind).inc()

    @property
    def events(self) -> List:
        with self._lock:
            return list(self._events)

    def count(self, kind: str = None) -> int:
        evs = self.events
        if kind is None:
            return len(evs)
        return sum(1 for e in evs if e.kind == kind)

    def kinds(self) -> Dict[str, int]:
        return dict(Counter(e.kind for e in self.events))

    def signature(self) -> List[Tuple]:
        """Canonical, thread-order-independent form: the multiset of
        ``(kind, site, detail)`` tuples, sorted.  Two runs of the same plan
        on the same workload must produce equal signatures."""
        return sorted((e.kind, _canon(e.site), _canon(e.detail))
                      for e in self.events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()

    def to_dict(self) -> dict:
        return {"num_events": self.count(), "by_kind": self.kinds(),
                "events": [e.to_dict() for e in self.events]}
