"""Deterministic, seeded fault plans — the chaos harness's control plane.

A ``FaultPlan`` is a registry-style collection of seeded injectors
(``INJECTORS`` / ``@register_injector``, mirroring ``STORES``/``POLICIES``)
threaded through the store, session, and service layers:

* ``client_dropout``    — stage-level client churn: clients vanish from the
  stage before their params are stored, making shards ragged (the training
  engines degrade to the per-shard path instead of crashing).
* ``straggler``         — per-job straggler delay in the serving path.
* ``slice_erasure``     — coded slices become unreachable at read time
  (``CodedStore`` recovers via erasure decoding from any >= S survivors).
* ``slice_corruption``  — coded slices are bit-corrupted at read time
  (recovered via Berlekamp-Welch / RANSAC error decoding).
* ``device_failure``    — a device fails: every job routed to it errors, the
  service marks it unhealthy and re-dispatches to healthy devices.
* ``device_hang``       — a job hangs on its device; the engine times the
  attempt out and retries elsewhere.
* ``job_exception``     — transient job exceptions that succeed on retry.

Every decision an injector makes is a pure function of ``(plan seed, site
key)`` — *not* of call order, thread interleaving, or the wall clock — so a
chaotic run reproduces bit-for-bit: the same plan seed against the same
workload injects the same faults at the same sites and yields the identical
``FaultLedger.signature()``.  Site keys are content-derived (round ids,
stage ids, shard ids, client tuples), which also means two concurrent reads
of the same round observe the *same* injected fault — corruption is a
property of the data, not of the reader.
"""
from __future__ import annotations

import zlib
from typing import Callable, Dict, List, Optional, Tuple, Type

import numpy as np

from repro.faults.events import (DeviceFault, FaultEvent, FaultLedger,
                                 JobHang, TransientJobError)


def _site_entropy(site: Tuple) -> List[int]:
    """Stable integer entropy for a site key (``hash()`` is salted per
    process; crc32 is not)."""
    return [zlib.crc32(repr(x).encode()) for x in site]


class FaultInjector:
    """Base injector.  Subclass, implement the hook(s) you inject at, and
    register with ``@register_injector("name")``.  Hooks return ``None``
    when the injector does not fire at that site."""

    name: str = ""

    # ----- hooks (all optional) -------------------------------------------
    def stage_dropout(self, plan: "FaultPlan", stage: int,
                      shard_clients: Dict[int, List[int]]
                      ) -> Dict[int, List[int]]:
        """Clients to drop per shard for one training stage."""
        return {}

    def slice_loss(self, plan: "FaultPlan", rnd: int, scheme) -> List[int]:
        """Coded-slice row ids unreachable for round ``rnd``."""
        return []

    def slice_noise(self, plan: "FaultPlan", rnd: int, scheme,
                    width: int, scale_ref: float) -> Dict[int, np.ndarray]:
        """row id -> additive corruption vector for round ``rnd``."""
        return {}

    def cold_noise(self, plan: "FaultPlan", rnd: int, scheme,
                   width: int, scale_ref: float) -> Dict[int, np.ndarray]:
        """row id -> additive corruption for a round served from the
        *cold* (disk-offloaded) tier of a tiered store."""
        return {}

    def job_action(self, plan: "FaultPlan", key: Tuple, attempt: int,
                   device: int) -> Optional[Tuple[float, Optional[Exception]]]:
        """(delay_s, error-or-None) for one job attempt, or ``None``."""
        return None

    def crash(self, plan: "FaultPlan", site: Tuple) -> None:
        """Process-crash hook: fired at the session's named crash sites
        (``("session", phase, stage)``); may raise ``InjectedCrash`` or
        kill the process outright."""

    def snapshot_written(self, plan: "FaultPlan", path: str,
                         step: int) -> None:
        """Durability hook: fired right after a snapshot commit — the
        torn-write injector corrupts the file here."""

    def describe(self) -> dict:
        return {"injector": self.name}


INJECTORS: Dict[str, Type[FaultInjector]] = {}


def register_injector(*names: str):
    """Class decorator registering a ``FaultInjector`` under ``names``."""
    if not names:
        raise ValueError("register_injector needs at least one name")

    def deco(cls: Type[FaultInjector]) -> Type[FaultInjector]:
        cls.name = names[0]
        for n in names:
            INJECTORS[n] = cls
        return cls
    return deco


def make_injector(name: str, **options) -> FaultInjector:
    try:
        cls = INJECTORS[name]
    except KeyError:
        raise ValueError(f"unknown fault injector {name!r}; registered: "
                         f"{sorted(INJECTORS)}") from None
    return cls(**options)


class FaultPlan:
    """A seeded set of injectors plus the ledger their firings land in.

    >>> plan = (FaultPlan(seed=7)
    ...         .add("slice_corruption", count=2, scale=10.0)
    ...         .add("job_exception", rate=1.0))
    >>> session = FederatedSession(sim, faults=plan)        # doctest: +SKIP
    """

    def __init__(self, seed: int = 0):
        self.seed = int(seed)
        self.injectors: List[FaultInjector] = []
        self.ledger = FaultLedger()

    def add(self, name: str, **options) -> "FaultPlan":
        self.injectors.append(make_injector(name, **options))
        return self

    def rng(self, *site) -> np.random.Generator:
        """Deterministic per-site generator: a pure function of
        ``(plan seed, site)`` — independent of call order and threads."""
        return np.random.default_rng([self.seed] + _site_entropy(site))

    # ------------------------------------------------------------- hooks
    def dropped_clients(self, stage: int,
                        shard_clients: Dict[int, List[int]]
                        ) -> Dict[int, List[int]]:
        """Union of every injector's stage-level dropout for ``stage``."""
        out: Dict[int, List[int]] = {}
        for inj in self.injectors:
            for s, cs in inj.stage_dropout(self, stage, shard_clients).items():
                keep = out.setdefault(s, [])
                keep.extend(c for c in cs if c not in keep)
        for s in out:
            out[s] = sorted(out[s])
        if any(out.values()):
            self.ledger.record(FaultEvent(
                "client_dropout", site=("stage", stage),
                detail=tuple(sorted((s, tuple(cs)) for s, cs in out.items()
                                    if cs))))
        return out

    def slice_faults(self, rnd: int, scheme, width: int,
                     scale_ref: float = 1.0
                     ) -> Tuple[List[int], Dict[int, np.ndarray]]:
        """(lost row ids, {row id: corruption vector}) for one stored round.
        Keyed on the round — every reader of the round sees the same fault."""
        lost: set = set()
        noise: Dict[int, np.ndarray] = {}
        for inj in self.injectors:
            got = inj.slice_loss(self, rnd, scheme)
            if got:
                lost.update(int(i) for i in got)
                self.ledger.record(FaultEvent(
                    inj.name, site=("round", rnd),
                    detail=tuple(sorted(int(i) for i in got))))
            nz = inj.slice_noise(self, rnd, scheme, width, scale_ref)
            if nz:
                noise.update(nz)
                self.ledger.record(FaultEvent(
                    inj.name, site=("round", rnd),
                    detail=tuple(sorted(int(i) for i in nz))))
        return sorted(lost), noise

    def cold_faults(self, rnd: int, scheme, width: int,
                    scale_ref: float = 1.0) -> Dict[int, np.ndarray]:
        """{row id: corruption vector} for one round *served from the cold
        tier* of a tiered store.  Keyed on the round like ``slice_faults`` —
        every cold read of the round observes the same corruption (it models
        media rot on the offloaded file, not a flaky reader)."""
        noise: Dict[int, np.ndarray] = {}
        for inj in self.injectors:
            nz = inj.cold_noise(self, rnd, scheme, width, scale_ref)
            if nz:
                noise.update(nz)
                self.ledger.record(FaultEvent(
                    inj.name, site=("cold", rnd),
                    detail=tuple(sorted(int(i) for i in nz))))
        return noise

    def job_action(self, key: Tuple, attempt: int,
                   device: int) -> Tuple[float, Optional[Exception]]:
        """Aggregate every injector's verdict on one job attempt: total
        straggler delay plus the first error (if any)."""
        delay, err = 0.0, None
        for inj in self.injectors:
            act = inj.job_action(self, key, attempt, device)
            if act is None:
                continue
            d, e = act
            delay += d
            if e is not None and err is None:
                err = e
            # the device index stays OUT of the event: re-dispatch targets
            # are a recovery detail, not part of the injected-fault identity
            self.ledger.record(FaultEvent(
                inj.name, site=("job",) + tuple(key) + (attempt,),
                detail=(round(d, 9), type(e).__name__ if e else "")))
        return delay, err

    def crash_site(self, site: Tuple) -> None:
        """Fire every injector's process-crash hook at one named site (the
        session calls this after stage training, after request serving, and
        after a snapshot commit)."""
        for inj in self.injectors:
            inj.crash(self, site)

    def post_snapshot(self, path: str, step: int) -> None:
        """Fire every injector's snapshot-written hook (torn-write site)."""
        for inj in self.injectors:
            inj.snapshot_written(self, path, step)

    def describe(self) -> dict:
        return {"seed": self.seed,
                "injectors": [inj.describe() for inj in self.injectors]}

    def to_dict(self) -> dict:
        return {**self.describe(), "ledger": self.ledger.to_dict()}


# ---------------------------------------------------------------------------
# Built-in injectors
# ---------------------------------------------------------------------------

def _quorum_rows(scheme) -> set:
    """The canonical well-spread decode subset — the S rows a fault-free
    quorum read actually consumes (see ``CodingScheme.quorum``)."""
    return set(int(i) for i in scheme.quorum())


@register_injector("client_dropout")
class ClientDropout(FaultInjector):
    """Stage-level client churn: each stage client independently drops out
    with probability ``rate`` (seeded per (stage, client)); ``min_keep``
    clients always survive per shard so training stays well-posed."""

    def __init__(self, rate: float = 0.0, min_keep: int = 1,
                 stages: Optional[Tuple[int, ...]] = None):
        if not 0.0 <= rate <= 1.0:
            raise ValueError("dropout rate must be in [0, 1]")
        self.rate = float(rate)
        self.min_keep = max(int(min_keep), 1)
        self.stages = tuple(stages) if stages is not None else None

    def stage_dropout(self, plan, stage, shard_clients):
        if self.stages is not None and stage not in self.stages:
            return {}
        out = {}
        for s, cs in sorted(shard_clients.items()):
            rng = plan.rng(self.name, stage, s)
            drop = [c for c in cs if rng.random() < self.rate]
            # keep the shard trainable: spare the lowest-id clients
            excess = len(cs) - len(drop)
            if excess < self.min_keep:
                spare = len(drop) - (len(cs) - self.min_keep)
                drop = drop[spare:]
            if drop:
                out[s] = drop
        return out

    def describe(self):
        return {"injector": self.name, "rate": self.rate,
                "min_keep": self.min_keep, "stages": self.stages}


@register_injector("straggler")
class StragglerDelay(FaultInjector):
    """Per-job straggler: with probability ``rate`` (seeded per job) the
    first attempt is delayed by ``delay_s`` before the work runs — the job
    still completes; only its measured wall (and SLA verdict) suffers."""

    def __init__(self, rate: float = 0.0, delay_s: float = 0.05):
        self.rate = float(rate)
        self.delay_s = float(delay_s)

    def job_action(self, plan, key, attempt, device):
        if attempt != 1:
            return None
        if plan.rng(self.name, *key).random() < self.rate:
            return (self.delay_s, None)
        return None

    def describe(self):
        return {"injector": self.name, "rate": self.rate,
                "delay_s": self.delay_s}


@register_injector("slice_erasure")
class SliceErasure(FaultInjector):
    """``count`` coded slices of each targeted round become unreachable
    (seeded per round).  ``spare_quorum=True`` (default) only erases slices
    outside the canonical decode subset — the regime where quorum-read
    recovery is *bit-identical* to the fault-free decode; set it ``False``
    to also hit the read set (recovery then re-interpolates from a different
    well-spread subset: correct, but only float-close).  ``rounds``
    restricts targeting."""

    def __init__(self, count: int = 1, spare_quorum: bool = True,
                 rounds: Optional[Tuple[int, ...]] = None):
        self.count = int(count)
        self.spare_quorum = bool(spare_quorum)
        self.rounds = tuple(rounds) if rounds is not None else None

    def _eligible(self, scheme) -> List[int]:
        rows = set(range(scheme.num_clients))
        if self.spare_quorum:
            rows -= _quorum_rows(scheme)
        return sorted(rows)

    def slice_loss(self, plan, rnd, scheme):
        if self.count <= 0 or (self.rounds is not None
                               and rnd not in self.rounds):
            return []
        rows = self._eligible(scheme)
        rng = plan.rng(self.name, rnd)
        k = min(self.count, len(rows))
        return sorted(int(i) for i in
                      rng.choice(rows, size=k, replace=False))

    def describe(self):
        return {"injector": self.name, "count": self.count,
                "spare_quorum": self.spare_quorum, "rounds": self.rounds}


@register_injector("slice_corruption")
class SliceCorruption(SliceErasure):
    """``count`` coded slices of each targeted round are bit-corrupted with
    additive noise at ``scale`` x the slice magnitude (seeded per round).
    Same ``spare_quorum`` semantics as ``slice_erasure``; the recovery path
    must now *localize* the corruption (Berlekamp-Welch / RANSAC) before
    excluding it."""

    def __init__(self, count: int = 1, scale: float = 10.0,
                 spare_quorum: bool = True,
                 rounds: Optional[Tuple[int, ...]] = None):
        super().__init__(count=count, spare_quorum=spare_quorum,
                         rounds=rounds)
        self.scale = float(scale)

    def slice_loss(self, plan, rnd, scheme):
        return []

    def slice_noise(self, plan, rnd, scheme, width, scale_ref):
        if self.count <= 0 or (self.rounds is not None
                               and rnd not in self.rounds):
            return {}
        rows = self._eligible(scheme)
        rng = plan.rng(self.name, rnd)
        k = min(self.count, len(rows))
        picked = sorted(int(i) for i in
                        rng.choice(rows, size=k, replace=False))
        amp = self.scale * (abs(scale_ref) + 1e-8)
        return {i: rng.standard_normal(width) * amp for i in picked}

    def describe(self):
        return {**super().describe(), "scale": self.scale}


@register_injector("cold_corrupt")
class ColdCorruption(SliceCorruption):
    """Corruption on *offloaded* slices: ``count`` rows of a round gain
    additive noise only when the round is served from the cold
    (disk-offloaded) tier of a tiered store — bit-rot on the cold medium.
    Hot/warm serves of the same round are clean, so the injector exercises
    the ``locate_errors``/RANSAC localization path precisely on the mmap'd
    read-back.  Same ``count``/``scale``/``spare_quorum``/``rounds`` knobs
    as ``slice_corruption``; seeded per ``("cold", round)`` site."""

    def slice_noise(self, plan, rnd, scheme, width, scale_ref):
        return {}

    def cold_noise(self, plan, rnd, scheme, width, scale_ref):
        if self.count <= 0 or (self.rounds is not None
                               and rnd not in self.rounds):
            return {}
        rows = self._eligible(scheme)
        rng = plan.rng(self.name, rnd)
        k = min(self.count, len(rows))
        picked = sorted(int(i) for i in
                        rng.choice(rows, size=k, replace=False))
        amp = self.scale * (abs(scale_ref) + 1e-8)
        return {i: rng.standard_normal(width) * amp for i in picked}


@register_injector("device_failure")
class DeviceFailure(FaultInjector):
    """Device ``device`` is dead: every job routed to it raises
    ``DeviceFault``.  The service marks it unhealthy after the first
    failure and re-dispatches — with >= 2 devices the serve completes with
    bit-identical models (the retried program is the same program)."""

    def __init__(self, device: int = 0):
        self.device = int(device)

    def job_action(self, plan, key, attempt, device):
        if device == self.device:
            return (0.0, DeviceFault(device))
        return None

    def describe(self):
        return {"injector": self.name, "device": self.device}


@register_injector("device_hang")
class DeviceHangInjector(FaultInjector):
    """A job hangs for ``hang_s`` (then errors as a timeout): targets a
    specific ``device``, or fires with probability ``rate`` per job."""

    def __init__(self, device: Optional[int] = None, rate: float = 0.0,
                 hang_s: float = 0.05):
        self.device = device if device is None else int(device)
        self.rate = float(rate)
        self.hang_s = float(hang_s)

    def job_action(self, plan, key, attempt, device):
        if self.device is not None:
            if device == self.device:
                return (0.0, JobHang(device, self.hang_s))
            return None
        if plan.rng(self.name, *key).random() < self.rate:
            return (0.0, JobHang(device, self.hang_s))
        return None

    def describe(self):
        return {"injector": self.name, "device": self.device,
                "rate": self.rate, "hang_s": self.hang_s}


@register_injector("job_exception")
class TransientJobException(FaultInjector):
    """Transient job failures: with probability ``rate`` (seeded per job —
    the *job* is flaky, not the attempt) the first ``fail_attempts``
    attempts raise ``TransientJobError``; later attempts succeed.  With
    ``fail_attempts`` <= the service's retry budget every request still
    completes; beyond it, the job aborts cleanly."""

    def __init__(self, rate: float = 0.0, fail_attempts: int = 1):
        self.rate = float(rate)
        self.fail_attempts = int(fail_attempts)

    def job_action(self, plan, key, attempt, device):
        if attempt > self.fail_attempts:
            return None
        if plan.rng(self.name, *key).random() < self.rate:
            return (0.0, TransientJobError(key))
        return None

    def describe(self):
        return {"injector": self.name, "rate": self.rate,
                "fail_attempts": self.fail_attempts}


@register_injector("process_kill")
class ProcessKill(FaultInjector):
    """Kill the process at one named session crash site — the crash half of
    the durability acceptance test.

    Sites are ``("session", phase, stage)`` with ``phase`` one of
    ``after_stage`` (training done, nothing served or snapshotted),
    ``after_requests`` (requests served, snapshot not yet written), and
    ``after_snapshot`` (snapshot committed, stage not yet journal-marked).

    ``mode="exit"`` is the real thing — ``os._exit(exit_code)``, no atexit,
    no flushes, for the subprocess kill test.  ``mode="raise"`` throws the
    typed ``InjectedCrash`` instead, so in-process tests can simulate the
    crash and then resume from the snapshots the dead session left behind.
    Fires at most once per plan (a resumed run must pass a fresh plan or
    none at all — a durable restart does not replay the crash)."""

    def __init__(self, stage: int = 0, phase: str = "after_stage",
                 mode: str = "raise", exit_code: int = 137):
        phases = ("after_stage", "after_requests", "after_snapshot")
        if phase not in phases:
            raise ValueError(f"phase must be one of {phases}, got {phase!r}")
        if mode not in ("exit", "raise"):
            raise ValueError(f"mode must be 'exit' or 'raise', got {mode!r}")
        self.stage = int(stage)
        self.phase = phase
        self.mode = mode
        self.exit_code = int(exit_code)
        self.fired = False

    def crash(self, plan, site):
        if self.fired or len(site) != 3:
            return
        kind, phase, stage = site
        if kind != "session" or phase != self.phase or stage != self.stage:
            return
        self.fired = True
        plan.ledger.record(FaultEvent("process_kill", site=tuple(site),
                                      detail=(self.mode,)))
        if self.mode == "raise":
            from repro.faults.events import InjectedCrash
            raise InjectedCrash(site)
        import os
        os._exit(self.exit_code)

    def describe(self):
        return {"injector": self.name, "stage": self.stage,
                "phase": self.phase, "mode": self.mode,
                "exit_code": self.exit_code}


@register_injector("torn_write")
class TornWrite(FaultInjector):
    """Corrupt a just-committed snapshot — a torn write the checksum layer
    must catch.  ``flip=False`` (default) truncates the file to ``frac`` of
    its bytes (power loss mid-write-back); ``flip=True`` XOR-flips a byte
    run in place (media corruption).  Targets the snapshot at ``step``;
    recovery must fall back to the previous good snapshot."""

    def __init__(self, step: int = 0, frac: float = 0.5, flip: bool = False):
        if not 0.0 <= frac < 1.0:
            raise ValueError("frac must be in [0, 1)")
        self.step = int(step)
        self.frac = float(frac)
        self.flip = bool(flip)

    def snapshot_written(self, plan, path, step):
        if step != self.step:
            return
        import os
        size = os.path.getsize(path)
        if self.flip:
            with open(path, "r+b") as f:
                f.seek(size // 2)
                chunk = f.read(min(16, size - size // 2))
                f.seek(size // 2)
                f.write(bytes(b ^ 0xFF for b in chunk))
            detail = ("flip", size)
        else:
            with open(path, "r+b") as f:
                f.truncate(int(size * self.frac))
            detail = ("truncate", size, int(size * self.frac))
        plan.ledger.record(FaultEvent("torn_write",
                                      site=("snapshot", step), detail=detail))

    def describe(self):
        return {"injector": self.name, "step": self.step,
                "frac": self.frac, "flip": self.flip}


def chaos_plan(seed: int = 0, *, corrupt: int = 0, erase: int = 0,
               job_rate: float = 0.0, dead_device: Optional[int] = None,
               dropout: float = 0.0,
               spec: Optional[Callable[["FaultPlan"], None]] = None
               ) -> FaultPlan:
    """Convenience builder for the common chaos mixtures (benchmarks, CI)."""
    plan = FaultPlan(seed=seed)
    if corrupt:
        plan.add("slice_corruption", count=corrupt)
    if erase:
        plan.add("slice_erasure", count=erase)
    if job_rate:
        plan.add("job_exception", rate=job_rate)
    if dead_device is not None:
        plan.add("device_failure", device=dead_device)
    if dropout:
        plan.add("client_dropout", rate=dropout)
    if spec is not None:
        spec(plan)
    return plan
