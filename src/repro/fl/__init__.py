from repro.fl.simulator import FLSimulator, StageRecord, UnlearnResult  # noqa: F401
from repro.fl import experiment  # noqa: F401
