from repro.fl.simulator import FLSimulator, StageRecord, UnlearnResult  # noqa: F401
