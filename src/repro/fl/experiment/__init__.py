"""Pluggable experiment-orchestration layer for federated unlearning.

Six registries/drivers make new scenarios drop-in plugins instead of
simulator surgery:

* ``STORES`` (``repro.stores.store``) — parameter stores behind one
  ``put_round(RoundPayload)`` protocol (``full`` / ``uncoded`` / ``coded``).
* ``FRAMEWORKS`` — unlearning strategies (``SE`` / ``FE`` / ``FR`` / ``RR``)
  as ``@register_framework`` classes receiving an ``UnlearnContext``.
* ``TASKS`` (``repro.fl.tasks``) — learning tasks owning data synthesis,
  batching, and eval metrics (``classification`` / ``generation``).
* ``FAMILIES`` (``repro.fl.families``) — model-family adapters (``cnn`` /
  ``transformer`` / ``mamba`` / ``rwkv6`` / ``moe``) building CPU-trainable
  ``ModelConfig``s and declaring their Pallas kernel ops.
* ``PARTITIONERS`` (``repro.data.federated``) — client partitioners (``iid``
  / ``primary-class`` / ``buckets`` / ``dirichlet`` / ``zipf``).
* ``FederatedSession`` — the multi-stage driver serving a scheduled stream
  of unlearning requests across isolated stages, with ``run_scenario``
  turning one ``ScenarioConfig`` into a ``SessionReport``.
"""
from repro.stores.store import (ParameterStore, RoundPayload,  # noqa: F401
                                    STORES, StoreStats, make_store,
                                    register_store)
from repro.data.federated import (PARTITIONERS,  # noqa: F401
                                  get_partitioner, register_partitioner)
from repro.fl.experiment.frameworks import (FRAMEWORKS,  # noqa: F401
                                            UnlearnContext, UnlearnFramework,
                                            get_framework, register_framework,
                                            run_unlearn)
from repro.fl.families import (FAMILIES, ModelFamily,  # noqa: F401
                               get_model_family, register_model_family)
from repro.fl.tasks import (TASKS, TaskSpec, get_task,  # noqa: F401
                            register_task)
from repro.fl.experiment.scenario import (ScenarioConfig,  # noqa: F401
                                          build_session, build_simulator,
                                          run_scenario)
from repro.fl.experiment.session import (FederatedSession,  # noqa: F401
                                         RequestSchedule, SessionReport,
                                         StageReport, UnlearnRequest)
from repro.fl.experiment.stage import train_stage  # noqa: F401
from repro.fl.simulator import StageRecord, UnlearnResult  # noqa: F401

# Auto-register the verification subsystem (the retrain ``oracle`` framework
# and the VERIFIERS registry).  Plain module import — ``repro.verify`` pulls
# only submodules of this package, never the package itself, so the cycle is
# safe at any import order.
import repro.verify  # noqa: F401, E402
