"""Unlearning-framework registry — strategy classes replacing the simulator's
if/elif chain.

Each framework is a class registered under one or more names
(``@register_framework("SE", "SE-uncoded")``).  ``run`` receives an
``UnlearnContext`` — the stage record plus every capability the seed
``FLSimulator.unlearn`` body used (stacked client data, jitted
calibrated-retraining / local-training steps, historical update norms moved
to device once, shard-impact analysis, stored-round reconstruction through
the parameter store) — and returns ``(models, cost_units)``.  A third-party
framework (e.g. Halimi et al.'s PGD client erasure) is therefore one file:
subclass ``UnlearnFramework``, decorate, and every driver (``FLSimulator``
shim, ``FederatedSession``, ``run_scenario``) can dispatch to it by name.

``run_unlearn`` is the dispatch entry point: it times the framework, blocks
on the result, and packages an ``UnlearnResult``.
"""
from __future__ import annotations

import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import unlearning
from repro.models import init_params
from repro.telemetry import get_tracer


@dataclass
class UnlearnContext:
    """Everything a framework needs to serve one unlearning request against
    one stage record."""
    sim: object                       # FLSimulator (jitted steps, data, cfg)
    record: object                    # StageRecord
    requests: List[int]               # client ids to erase
    rounds: int                       # unlearning rounds G'
    available: Optional[Sequence[int]] = None   # reachable coded slices
    corrupt: Optional[np.ndarray] = None        # modelled slice corruption

    # ------------------------------------------------------------ accessors
    @property
    def plan(self):
        return self.record.plan

    @property
    def fl(self):
        return self.sim.fl

    @property
    def mgr(self):
        return self.sim.mgr

    @property
    def retrain_epochs(self) -> int:
        """L/r — the reduced local-epoch budget of calibrated retraining."""
        return max(int(self.fl.local_epochs / self.fl.retrain_ratio), 1)

    @property
    def impacted(self) -> List[int]:
        """S' — shards containing at least one requested client."""
        return sorted(self.mgr.impacted_shards(self.plan, self.requests))

    def retained(self, shard: int) -> List[int]:
        return self.mgr.retained(self.plan, shard, self.requests)

    def retained_all(self) -> List[int]:
        gone = set(self.requests)
        return [c for c in self.plan.clients if c not in gone]

    # ------------------------------------------------------------- data/steps
    def stack_client_data(self, clients: Sequence[int]):
        return self.sim._stack_client_data(clients)

    def stored_round(self, shard: int, rnd: int) -> Dict[int, object]:
        """Reconstruct one shard's stored round from the parameter store
        (decoding through erasures/corruption for the coded store)."""
        return self.record.store.get_shard(rnd, shard,
                                           available=self.available,
                                           corrupt=self.corrupt)

    def all_stored_round(self, rnd: int) -> Dict[int, object]:
        out = {}
        for s in self.plan.shard_clients:
            out.update(self.stored_round(s, rnd))
        return out

    def stored_norms(self, shard_of: Callable[[int], int],
                     retained: Sequence[int], n_rounds: int) -> jnp.ndarray:
        """(G', M) historical update norms, moved to device once."""
        hn = self.record.history_norms
        return jnp.asarray(
            [[hn[(shard_of(c), g, c)] for c in retained]
             for g in range(n_rounds)], jnp.float32)

    def calib_round(self, w, xs, ys, round_norms):
        """One fused calibrated-retraining round (eq. 3) at L/r epochs."""
        return self.sim._calib_round[self.retrain_epochs](w, xs, ys,
                                                          round_norms)

    def calib_stage(self, ws, xs, ys, nmats):
        """The whole calibrated-retraining pass of K shards in ONE dispatch:
        ``calib_round`` vmapped over the stacked (K, ...) shard models and
        scanned over the G' rounds.  nmats: (G', K, M') stored norms."""
        return self.sim._calib_stage[self.retrain_epochs](ws, xs, ys, nmats)

    def local_train(self, w, xs, ys, epochs: int, fisher=None):
        """Vmapped local training -> stacked (M, ...) client params."""
        if fisher is not None:
            return self.sim._local_train[(epochs, "fisher")](w, xs, ys, fisher)
        return self.sim._local_train[epochs](w, xs, ys)

    def stacked_mean(self, stacked):
        return self.sim._stacked_mean(stacked)

    def init_model(self, salt: int = 777):
        return init_params(self.sim.cfg, jax.random.key(self.sim.seed + salt))

    def stage_init_model(self):
        """The stage's ACTUAL initial model w0 (seeded by ``plan.stage``,
        exactly as ``train_stage`` built it) — retraining from it with a
        client removed is the bit-exact counterfactual the retrain oracle
        (``repro.verify.oracle``) measures against."""
        return init_params(self.sim.cfg,
                           jax.random.key(self.sim.seed + self.plan.stage))

    def retrain_shards(self, w0, xs, ys, g_rounds: int):
        """From-scratch FedAvg of a stacked ``(K, M, n, ...)`` batch of
        shards at the FULL L local epochs in one dispatch (vmap-over-shards
        × scan-over-rounds, reusing the stage engine's round body) — the
        exact-unlearning ground-truth pass.  Returns the ``(K, ...)`` final
        shard models."""
        prog = self.sim._get_retrain_program(self.fl.local_epochs, g_rounds)
        return prog(w0, xs, ys)

    def estimate_fisher(self, w, clients: Sequence[int]):
        return self.sim._estimate_fisher(w, clients)


class UnlearnFramework:
    """Base class for unlearning strategies.  Subclass, implement ``run``,
    and register with ``@register_framework(name, *aliases)``."""

    name: str = ""
    # shard-level strategies retrain only impacted shards and return one
    # model per shard; federation-level ones retrain everything ({0: w})
    shard_level: bool = False

    def run(self, ctx: UnlearnContext):
        """Return ``(models, cost_units)`` where ``models`` maps shard id to
        the unlearned model ({0: w} for federation-level frameworks) and
        ``cost_units`` counts client-epochs of retraining."""
        raise NotImplementedError

    @classmethod
    def impacted_shards(cls, plan, clients: Sequence[int]) -> List[int]:
        """The shards this strategy would retrain for ``clients`` on
        ``plan`` — what the strategy reports to the service scheduler so it
        can merge due requests per impacted shard and place shard programs
        on devices.  Federation-level strategies touch every shard; SE
        overrides with the membership-based impacted set."""
        return sorted(plan.shard_clients)


FRAMEWORKS: Dict[str, Type[UnlearnFramework]] = {}


def register_framework(*names: str):
    """Class decorator registering an ``UnlearnFramework`` under ``names``."""
    if not names:
        raise ValueError("register_framework needs at least one name")

    def deco(cls: Type[UnlearnFramework]) -> Type[UnlearnFramework]:
        cls.name = names[0]
        for n in names:
            FRAMEWORKS[n] = cls
        return cls
    return deco


def get_framework(name: str) -> UnlearnFramework:
    try:
        return FRAMEWORKS[name]()
    except KeyError:
        raise ValueError(f"unknown unlearning framework {name!r}; "
                         f"registered: {sorted(FRAMEWORKS)}") from None


def run_unlearn(sim, framework: str, record, requests: Sequence[int],
                rounds: Optional[int] = None,
                available: Optional[Sequence[int]] = None,
                corrupt: Optional[np.ndarray] = None):
    """Dispatch one unlearning request to the registered framework and
    package the timed ``UnlearnResult``."""
    from repro.fl.simulator import UnlearnResult

    fw = get_framework(framework)
    ctx = UnlearnContext(sim, record, list(requests),
                         rounds or sim.fl.global_rounds, available, corrupt)
    t0 = time.perf_counter()
    impacted = ctx.impacted
    with get_tracer().span("unlearn.dispatch", framework=fw.name,
                           clients=sorted(requests),
                           impacted=impacted) as sp:
        models, cost = fw.run(ctx)
        # block on EVERY returned model: blocking only the first dict entry
        # under-measures serves whose impacted shard is not the first key
        # (its retrain would still be in flight when the wall is recorded)
        jax.block_until_ready(list(models.values()))
        sp.annotate(cost_units=float(cost))
    wall = time.perf_counter() - t0
    stats = getattr(record.store, "stats", None)
    return UnlearnResult(framework, models, wall, cost, stats, impacted)


# ---------------------------------------------------------------------------
# The paper's four frameworks
# ---------------------------------------------------------------------------

@register_framework("SE", "SE-uncoded")
class ShardedEraser(UnlearnFramework):
    """SE (paper Sec 4): isolation means only impacted shards retrain —
    preparation from the stored round-0 locals (eq. 2), then calibrated
    retraining at L/r epochs (eq. 3).  "SE-uncoded" is the same algorithm
    reading from an uncoded shard store.

    When the request (or a batched group of requests) impacts SEVERAL shards
    with identical geometry (same retained count, sample count, and round
    budget), the whole retraining pass runs as one ``calib_stage`` program —
    the impacted shards vmapped together, the G' rounds scanned — instead of
    a Python loop of G' dispatches per shard.  Ragged shard batches fall back
    to the per-shard loop (identical math).

    The per-shard pieces are exposed for the online service
    (``repro.service``): ``prepare_shard_job`` builds one shard's job and
    ``run_prepared_job`` (module-level) retrains it — optionally on an
    explicit device — so independent shard programs can dispatch
    asynchronously across devices."""

    shard_level = True

    def run(self, ctx: UnlearnContext):
        models = dict(ctx.record.shard_models)
        jobs = self.prepare_jobs(ctx)
        if len(jobs) > 1 and self._batchable(jobs):
            out, cost = self._run_batched(ctx, jobs)
        else:
            out, cost = self._run_sequential(ctx, jobs)
        models.update(out)
        return models, cost

    @classmethod
    def impacted_shards(cls, plan, clients: Sequence[int]) -> List[int]:
        hit = set(clients)
        return sorted(s for s, cs in plan.shard_clients.items()
                      if hit & set(cs))

    # ------------------------------------------------------------- plumbing
    @staticmethod
    def prepare_shard_job(ctx: UnlearnContext, shard: int):
        """One impacted shard's retraining job: stacked retained data, the
        eq.-(2) prepared initial model (from the store's reconstructed
        round-0 locals), and the (G', M') stored-norm matrix.  ``None`` when
        every client of the shard was requested (nothing to retrain on)."""
        retained = ctx.retained(shard)
        if not retained:
            return None
        xs, ys = ctx.stack_client_data(retained)
        stored0 = ctx.stored_round(shard, 0)
        w0 = unlearning.prepare_initial_model(
            [stored0[c] for c in retained])
        n_r = min(ctx.rounds, len(ctx.record.round_globals[shard]) - 1)
        nmat = ctx.stored_norms(lambda c, s=shard: s, retained, n_r)
        return (shard, retained, xs, ys, w0, nmat, n_r)

    def prepare_jobs(self, ctx: UnlearnContext):
        jobs = (self.prepare_shard_job(ctx, s) for s in ctx.impacted)
        return [j for j in jobs if j is not None]

    @staticmethod
    def _batchable(jobs) -> bool:
        shapes = {(j[2].shape, j[6]) for j in jobs}
        return len(shapes) == 1

    def _run_sequential(self, ctx: UnlearnContext, jobs):
        models, cost = {}, 0.0
        for job in jobs:
            s, w, c = run_prepared_job(ctx, job)
            models[s] = w
            cost += c
        return models, cost

    def _run_batched(self, ctx: UnlearnContext, jobs):
        """All impacted shards retrain in ONE ``calib_stage`` dispatch."""
        ws = jax.tree.map(lambda *a: jnp.stack(a), *[j[4] for j in jobs])
        xs = jnp.stack([j[2] for j in jobs])
        ys = jnp.stack([j[3] for j in jobs])
        nmats = jnp.stack([j[5] for j in jobs], axis=1)      # (G', K, M')
        out = ctx.calib_stage(ws, xs, ys, nmats)
        models, cost = {}, 0.0
        for i, (s, retained, *_rest, n_r) in enumerate(jobs):
            models[s] = jax.tree.map(lambda a, i=i: a[i], out)
            cost += n_r * len(retained) * ctx.retrain_epochs
        return models, cost


def run_prepared_job(ctx: UnlearnContext, job, device=None):
    """Retrain ONE prepared shard job (eq. 3, fused stacked rounds) and
    return ``(shard, model, cost_units)``.

    With ``device`` set, the job's tensors are committed there first, so the
    G' jitted calibration rounds dispatch asynchronously *on that device* —
    the unit of work the service's ``DevicePlacement`` spreads across
    ``jax.devices()``.  ``device=None`` is bit-identical to the in-process
    sequential path (it IS the sequential path)."""
    s, retained, xs, ys, w, nmat, n_r = job
    with get_tracer().span("unlearn.shard", shard=s, rounds=n_r,
                           retained=len(retained)):
        if device is not None:
            xs, ys, w, nmat = jax.device_put((xs, ys, w, nmat), device)
        cost = 0.0
        for g in range(n_r):
            w = ctx.calib_round(w, xs, ys, nmat[g])
            cost += len(retained) * ctx.retrain_epochs
    return s, w, cost


@register_framework("FE")
class FedEraser(UnlearnFramework):
    """FedEraser without sharding: calibrated retraining over ALL retained
    clients from the full central store."""

    def run(self, ctx: UnlearnContext):
        retained = ctx.retained_all()
        xs, ys = ctx.stack_client_data(retained)
        stored0 = ctx.all_stored_round(0)
        w = unlearning.prepare_initial_model([stored0[c] for c in retained])
        nmat = ctx.stored_norms(ctx.plan.shard_of, retained, ctx.rounds)
        cost = 0.0
        for g in range(ctx.rounds):
            w = ctx.calib_round(w, xs, ys, nmat[g])
            cost += len(retained) * ctx.retrain_epochs
        return {0: w}, cost


class _FullRetrain(UnlearnFramework):
    """Federation-wide retraining from scratch (no stored parameters used)."""

    use_fisher = False

    def run(self, ctx: UnlearnContext):
        retained = ctx.retained_all()
        xs, ys = ctx.stack_client_data(retained)
        w = ctx.init_model(777)
        ep = ctx.retrain_epochs if self.use_fisher else ctx.fl.local_epochs
        # RR: estimate the diagonal Fisher on retained data once
        fisher = ctx.estimate_fisher(w, retained) if self.use_fisher else None
        cost = 0.0
        for g in range(ctx.rounds):
            locals_ = ctx.local_train(w, xs, ys, ep, fisher)
            w = ctx.stacked_mean(locals_)
            cost += len(retained) * ep
        return {0: w}, cost


@register_framework("FR")
class FedRetrain(_FullRetrain):
    """The gold standard: full retraining at the original L epochs."""
    use_fisher = False


@register_framework("RR")
class RapidRetrain(_FullRetrain):
    """Rapid retraining: reduced epochs with diagonal-Fisher preconditioned
    local steps."""
    use_fisher = True
