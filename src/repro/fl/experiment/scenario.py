"""Scenario runner: one config -> simulator -> multi-stage session -> report.

``ScenarioConfig`` captures everything the paper's experiments vary — task
(image / lm), data distribution, federation scale, store kind, stage count,
and the unlearning request schedule — and ``run_scenario`` executes it
through ``FederatedSession``.  The benchmark suite (``benchmarks/common.py``)
and ``examples/quickstart.py`` build on these helpers instead of hand-rolling
model/data/simulator setup.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import (client_datasets_images, client_datasets_lm,
                        lm_examples, make_char_data, make_image_data)
from repro.fl.experiment.session import (FederatedSession, RequestSchedule,
                                         SessionReport)
from repro.fl.simulator import FLSimulator


@dataclass
class ScenarioConfig:
    """One experiment scenario (defaults = the CPU-container scale)."""
    # task / data
    task: str = "image"               # "image" | "lm"
    iid: bool = True
    seed: int = 0
    samples_per_client: int = 80
    image_size: int = 14
    noise: float = 0.25
    seq_len: int = 48
    test_n: int = 400
    # federation
    num_clients: int = 20
    clients_per_round: int = 12
    num_shards: int = 4
    local_epochs: int = 4
    global_rounds: int = 6
    retrain_ratio: float = 2.0
    # optimizer (None -> per-task default)
    opt_name: str = "sgd"
    lr: Optional[float] = None
    local_batch: Optional[int] = None
    # orchestration
    store: str = "coded"
    engine: str = "fused"                # "stage" | "fused" | "legacy"
    encode_group: Optional[int] = None
    slice_dtype: object = None
    num_stages: int = 1
    schedule: Optional[RequestSchedule] = None
    batch_requests: bool = False         # merge requests due after each stage
    strict_schedule: bool = False        # raise on never-served requests

    def fl_config(self) -> FLConfig:
        return FLConfig(num_clients=self.num_clients,
                        clients_per_round=self.clients_per_round,
                        num_shards=self.num_shards,
                        local_epochs=self.local_epochs,
                        global_rounds=self.global_rounds,
                        retrain_ratio=self.retrain_ratio)

    @classmethod
    def paper_full(cls, **overrides) -> "ScenarioConfig":
        """The paper's full setting (100 clients, G=30, L=10) — slow on CPU."""
        base = dict(num_clients=100, clients_per_round=20, num_shards=4,
                    local_epochs=10, global_rounds=30, samples_per_client=100,
                    image_size=28, seq_len=64, test_n=1000)
        base.update(overrides)
        return cls(**base)


TestData = Tuple[np.ndarray, np.ndarray]


def build_simulator(cfg: ScenarioConfig) -> Tuple[FLSimulator, TestData]:
    """Build the paper-protocol simulator + held-out test set for a scenario."""
    if cfg.task == "image":
        return _build_image(cfg)
    if cfg.task == "lm":
        return _build_lm(cfg)
    raise ValueError(f"unknown task {cfg.task!r}; use 'image' or 'lm'")


def _build_image(cfg: ScenarioConfig) -> Tuple[FLSimulator, TestData]:
    model = dataclasses.replace(get_config("cnn-paper"),
                                image_size=cfg.image_size, d_model=48,
                                cnn_channels=(8, 16))
    data = make_image_data(cfg.num_clients * cfg.samples_per_client,
                           image_size=cfg.image_size, seed=cfg.seed,
                           noise=cfg.noise)
    clients = client_datasets_images(data, cfg.num_clients, iid=cfg.iid,
                                     seed=cfg.seed)
    opt = OptimizerConfig(name=cfg.opt_name, lr=cfg.lr or 0.05, grad_clip=0.0)
    sim = FLSimulator(model, cfg.fl_config(), clients, task="image",
                      opt_cfg=opt, local_batch=cfg.local_batch or 20,
                      seed=cfg.seed)
    test = make_image_data(cfg.test_n, image_size=cfg.image_size,
                           seed=cfg.seed + 999, noise=cfg.noise)
    return sim, (test.images, test.labels)


def _build_lm(cfg: ScenarioConfig) -> Tuple[FLSimulator, TestData]:
    model = get_config("nanogpt-paper")
    stream = make_char_data(cfg.num_clients * cfg.samples_per_client
                            * cfg.seq_len + cfg.seq_len + 1,
                            vocab_size=model.vocab_size, seed=cfg.seed)
    toks, labs = lm_examples(stream, cfg.seq_len)
    clients = client_datasets_lm(toks, labs, cfg.num_clients, iid=cfg.iid,
                                 seed=cfg.seed)
    opt = OptimizerConfig(name=cfg.opt_name, lr=cfg.lr or 0.3, grad_clip=0.0)
    sim = FLSimulator(model, cfg.fl_config(), clients, task="lm",
                      opt_cfg=opt, local_batch=cfg.local_batch or 10,
                      seed=cfg.seed)
    test_stream = make_char_data(cfg.test_n * cfg.seq_len + 1,
                                 vocab_size=model.vocab_size,
                                 seed=cfg.seed + 999)
    tt, tl = lm_examples(test_stream, cfg.seq_len)
    return sim, (tt, tl)


def build_session(cfg: ScenarioConfig) -> Tuple[FederatedSession, TestData]:
    """Simulator wrapped in a session configured from the scenario."""
    sim, test = build_simulator(cfg)
    session = FederatedSession(sim, store_kind=cfg.store, engine=cfg.engine,
                               encode_group=cfg.encode_group,
                               slice_dtype=cfg.slice_dtype,
                               batch_requests=cfg.batch_requests,
                               strict_schedule=cfg.strict_schedule)
    return session, test


def run_scenario(cfg: ScenarioConfig) -> SessionReport:
    """Execute the scenario: K stages with the scheduled unlearning stream."""
    session, _test = build_session(cfg)
    return session.run(cfg.num_stages, schedule=cfg.schedule)
