"""Scenario runner: one config -> simulator -> multi-stage session -> report.

``ScenarioConfig`` captures everything the paper's experiments vary — and,
through three registries, everything they *didn't*: the task
(``TASKS``: classification / generation), the model family (``FAMILIES``:
cnn / transformer / mamba / rwkv6 / moe — the latter two training through
their Pallas kernel ops), the client partitioner (``PARTITIONERS``: iid /
primary-class / buckets / dirichlet / zipf), the store kind, stage count,
and the unlearning request schedule.  ``run_scenario`` executes it through
``FederatedSession``.  The benchmark suite (``benchmarks/common.py``) and
``examples/`` build on these helpers instead of hand-rolling
model/data/simulator setup.

Every registry key is validated in ``__post_init__`` with an actionable
error (unknown keys list the registered entries), so a typo'd name fails at
config construction instead of as a deep ``KeyError``.  The pre-registry
spellings — ``task="image" | "lm"`` and ``iid=True/False`` — keep working as
``DeprecationWarning`` shims that map onto the registries bit-identically.
"""
from __future__ import annotations

import os
import warnings
from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple, Union

import numpy as np

from repro.stores.store import STORES
from repro.configs import FLConfig, OptimizerConfig
from repro.data.federated import get_partitioner
from repro.fl.experiment.frameworks import FRAMEWORKS
from repro.fl.experiment.session import (FederatedSession, RequestSchedule,
                                         SessionReport)
from repro.fl.experiment.stage import ENGINES
from repro.fl.families import get_model_family
from repro.fl.simulator import FLSimulator
from repro.fl.tasks import get_task

DTypeLike = Union[str, np.dtype, type]

_TASK_ALIASES = {"image": "classification", "lm": "generation"}


@dataclass
class ScenarioConfig:
    """One experiment scenario (defaults = the CPU-container scale)."""
    # task / model / data — registry keys (TASKS / FAMILIES / PARTITIONERS)
    task: str = "classification"
    model: str = ""                   # "" -> the task's default family
    partitioner: str = "iid"
    partitioner_kwargs: Dict[str, Any] = field(default_factory=dict)
    iid: Optional[bool] = None        # DEPRECATED -> partitioner=
    seed: int = 0
    samples_per_client: int = 80
    image_size: int = 14
    noise: float = 0.25
    seq_len: int = 48
    test_n: int = 400
    # federation
    num_clients: int = 20
    clients_per_round: int = 12
    num_shards: int = 4
    local_epochs: int = 4
    global_rounds: int = 6
    retrain_ratio: float = 2.0
    # optimizer (None -> per-family/per-task default)
    opt_name: str = "sgd"
    lr: Optional[float] = None
    local_batch: Optional[int] = None
    # orchestration
    store: str = "coded"
    # factory-specific store knobs passed through make_store verbatim (e.g.
    # store="tiered": hot_bytes / warm_bytes / eviction / offload_dir)
    store_options: Dict[str, Any] = field(default_factory=dict)
    engine: str = "fused"                # "stage" | "fused" | "legacy"
    encode_group: Optional[int] = None
    slice_dtype: Optional[DTypeLike] = None
    num_stages: int = 1
    schedule: Optional[RequestSchedule] = None
    batch_requests: bool = False         # merge requests due after each stage
    strict_schedule: bool = False        # raise on never-served requests
    # durability (repro.durability): snapshot every N completed stages into
    # checkpoint_dir; 0 disables periodic snapshots (a dir alone implies 1)
    checkpoint_every: int = 0
    checkpoint_dir: Optional[str] = None

    # ------------------------------------------------------------ validation
    def __post_init__(self):
        self._apply_deprecated_spellings()
        task = get_task(self.task)           # raises listing TASKS
        self.task = task.name
        if not self.model:
            self.model = task.default_family
        family = get_model_family(self.model)  # raises listing FAMILIES
        self.model = family.name
        if family.task != task.kind:
            raise ValueError(
                f"model family {self.model!r} plays task {family.task!r}, "
                f"not {task.name!r}; pick a family whose task matches "
                f"(see repro.fl.families.FAMILIES)")
        # raises listing PARTITIONERS, or the accepted kwarg names on a
        # typo'd parameter (e.g. dirichlet alpha)
        get_partitioner(self.partitioner, **self.partitioner_kwargs)
        if self.store not in STORES:
            raise ValueError(f"unknown store {self.store!r}; registered: "
                             f"{sorted(STORES)}")
        if self.engine not in ENGINES:
            raise ValueError(f"unknown engine {self.engine!r}; use one of "
                             f"{ENGINES}")
        if self.schedule is not None:
            for r in self.schedule.requests:
                if r.framework not in FRAMEWORKS:
                    raise ValueError(
                        f"scheduled request uses unknown unlearning "
                        f"framework {r.framework!r}; registered: "
                        f"{sorted(FRAMEWORKS)}")
        if self.clients_per_round > self.num_clients:
            raise ValueError(
                f"clients_per_round={self.clients_per_round} exceeds "
                f"num_clients={self.num_clients}")
        if self.num_shards < 1 or self.clients_per_round % self.num_shards:
            raise ValueError(
                f"num_shards={self.num_shards} must divide the "
                f"clients_per_round={self.clients_per_round} clients sampled "
                f"per stage (each shard gets clients_per_round/num_shards "
                f"clients)")
        if self.slice_dtype is not None:
            try:
                np.dtype(self.slice_dtype)
            except TypeError:
                raise ValueError(
                    f"slice_dtype {self.slice_dtype!r} is not a dtype; use "
                    f"e.g. 'bfloat16', 'float32', or np.float16") from None
        if self.checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every={self.checkpoint_every} must be >= 0 "
                f"(0 disables periodic snapshots)")
        if self.checkpoint_every and not self.checkpoint_dir:
            raise ValueError(
                f"checkpoint_every={self.checkpoint_every} needs a "
                f"checkpoint_dir to write snapshots to")
        if self.checkpoint_dir is not None:
            parent = os.path.dirname(
                os.path.abspath(self.checkpoint_dir)) or os.sep
            probe = self.checkpoint_dir if os.path.isdir(self.checkpoint_dir) \
                else parent
            if not os.path.isdir(probe) or not os.access(probe, os.W_OK):
                raise ValueError(
                    f"checkpoint_dir {self.checkpoint_dir!r} is not writable "
                    f"(nor creatable under {parent!r}); snapshots need a "
                    f"writable directory")

    def _apply_deprecated_spellings(self):
        if self.task in _TASK_ALIASES:
            new = _TASK_ALIASES[self.task]
            warnings.warn(
                f"ScenarioConfig(task={self.task!r}) is deprecated; use "
                f"task={new!r} (optionally with model=...)",
                DeprecationWarning, stacklevel=4)
            self.task = new
        if self.iid is not None:
            iid, self.iid = self.iid, None
            warnings.warn(
                "ScenarioConfig(iid=...) is deprecated; use partitioner= "
                "('iid', 'primary-class', 'buckets', 'dirichlet', 'zipf')",
                DeprecationWarning, stacklevel=4)
            if self.partitioner != "iid":
                raise ValueError(
                    "pass either the deprecated iid= flag or partitioner=, "
                    "not both")
            if not iid:
                self.partitioner = get_task(self.task).legacy_skew

    def fl_config(self) -> FLConfig:
        return FLConfig(num_clients=self.num_clients,
                        clients_per_round=self.clients_per_round,
                        num_shards=self.num_shards,
                        local_epochs=self.local_epochs,
                        global_rounds=self.global_rounds,
                        retrain_ratio=self.retrain_ratio)

    @classmethod
    def paper_full(cls, **overrides) -> "ScenarioConfig":
        """The paper's full setting (100 clients, G=30, L=10) — slow on CPU."""
        base = dict(num_clients=100, clients_per_round=20, num_shards=4,
                    local_epochs=10, global_rounds=30, samples_per_client=100,
                    image_size=28, seq_len=64, test_n=1000)
        base.update(overrides)
        return cls(**base)


TestData = Tuple[np.ndarray, np.ndarray]


def build_simulator(cfg: ScenarioConfig) -> Tuple[FLSimulator, TestData]:
    """Build the paper-protocol simulator + held-out test set for a scenario,
    resolving the task, model family, and partitioner registries."""
    task = get_task(cfg.task)
    family = get_model_family(cfg.model)
    model_cfg = family.build(cfg)
    partition = get_partitioner(cfg.partitioner, **cfg.partitioner_kwargs)
    clients, test = task.build_data(cfg, model_cfg, partition)
    opt = OptimizerConfig(name=cfg.opt_name,
                          lr=cfg.lr or family.default_lr or task.default_lr,
                          grad_clip=0.0)
    sim = FLSimulator(model_cfg, cfg.fl_config(), clients, task=task,
                      opt_cfg=opt,
                      local_batch=(cfg.local_batch or family.default_batch
                                   or task.default_batch),
                      seed=cfg.seed)
    return sim, test


def build_session(cfg: ScenarioConfig) -> Tuple[FederatedSession, TestData]:
    """Simulator wrapped in a session configured from the scenario."""
    sim, test = build_simulator(cfg)
    session = FederatedSession(sim, store_kind=cfg.store, engine=cfg.engine,
                               encode_group=cfg.encode_group,
                               slice_dtype=cfg.slice_dtype,
                               batch_requests=cfg.batch_requests,
                               strict_schedule=cfg.strict_schedule,
                               checkpoint_every=cfg.checkpoint_every,
                               checkpoint_dir=cfg.checkpoint_dir,
                               store_options=cfg.store_options)
    return session, test


def run_scenario(cfg: ScenarioConfig) -> SessionReport:
    """Execute the scenario: K stages with the scheduled unlearning stream."""
    session, _test = build_session(cfg)
    return session.run(cfg.num_stages, schedule=cfg.schedule)
