"""Multi-stage federated session driver — the paper's cross-stage isolation
claim, end to end.

The paper divides the learning/unlearning timeline into *stages*; clients are
re-sampled and re-sharded per stage, so a client's data only ever influences
the stages it participated in.  ``FederatedSession`` runs K stages
back-to-back against one simulator and serves a stream of unlearning
requests scheduled between stages: each request is dispatched to its
registered framework on **only the impacted stages** (those whose plan
contains a requested client) and, within each, only the impacted shards
retrain.  With ``batch_requests=True`` all requests due after a stage are
grouped and served as ONE merged request per compatible option set, so each
impacted shard retrains once per batch instead of once per request (the
concurrent-request serving mode).  Per-stage wall time, store accounting,
retraining cost, and the unlearning results accumulate into a
``SessionReport`` with JSON export.
"""
from __future__ import annotations

import json
import os
import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Union

from repro.stores.store import StoreStats
from repro.fl.experiment.frameworks import run_unlearn
from repro.fl.experiment.stage import train_stage
from repro.telemetry import AuditLog, get_tracer

ClientSpec = Union[Sequence[int], Callable[[object], Sequence[int]]]


@dataclass
class UnlearnRequest:
    """One unlearning request in a session.

    ``clients`` may be concrete ids or a callable ``plan -> ids`` (resolved
    against the most recent stage when the request is served — useful for
    request patterns like ``adaptive_requests`` that need a trained plan).
    ``after_stage``: serve once stage index ``after_stage`` has completed.
    ``stages``: explicit target stage indices; default = every completed
    stage in which a requested client participated (cross-stage isolation).
    ``apply``: fold the unlearned shard models back into the stage record
    (serving semantics) instead of leaving the record untouched
    (comparison semantics, the default — matches the seed ``unlearn``).
    Requires a shard-level framework (e.g. SE) — federation-level results
    ({0: w}) cannot replace per-shard models and raise ``ValueError``.
    ``request_id``: stable idempotency key.  Scheduled requests without one
    get a deterministic id (``req-s<stage>-<i>``) when they come due, so
    journal replay and report entries key on ids, never list positions.
    """
    clients: ClientSpec
    framework: str = "SE"
    after_stage: int = 0
    stages: Optional[Sequence[int]] = None
    rounds: Optional[int] = None
    apply: bool = False
    request_id: str = ""

    def resolve_clients(self, plan) -> List[int]:
        cs = self.clients(plan) if callable(self.clients) else self.clients
        # dedupe, order-preserving: duplicate ids in one request are a
        # client-side retry, not a request to erase twice
        return list(dict.fromkeys(int(c) for c in cs))


@dataclass
class RequestSchedule:
    """A stream of requests keyed by the stage they arrive after."""
    requests: List[UnlearnRequest] = field(default_factory=list)

    def add(self, request: UnlearnRequest) -> "RequestSchedule":
        self.requests.append(request)
        return self

    def due(self, stage: int) -> List[UnlearnRequest]:
        return [r for r in self.requests if r.after_stage == stage]


@dataclass
class StageReport:
    stage: int                               # session-local index (records[])
    plan_stage: int                          # the ShardManager's global stage
    train_wall: float
    num_shards: int
    clients: List[int]
    store_stats: StoreStats                  # snapshot right after training
    unlearn: List[object] = field(default_factory=list)   # UnlearnResults

    def to_dict(self) -> dict:
        return {
            "stage": self.stage,
            "plan_stage": self.plan_stage,
            "train_wall_s": self.train_wall,
            "num_shards": self.num_shards,
            "clients": list(self.clients),
            "store_stats": self.store_stats.to_dict(),
            "unlearn": [u.to_dict() for u in self.unlearn],
        }


@dataclass
class SessionReport:
    stages: List[StageReport] = field(default_factory=list)
    store_kind: str = "coded"

    # ------------------------------------------------------------ aggregates
    @property
    def total_train_wall(self) -> float:
        return sum(s.train_wall for s in self.stages)

    @property
    def total_unlearn_wall(self) -> float:
        return sum(u.wall_time for s in self.stages for u in s.unlearn)

    @property
    def total_cost_units(self) -> float:
        return sum(u.cost_units for s in self.stages for u in s.unlearn)

    @property
    def store_stats(self) -> StoreStats:
        """Whole-session storage accounting, merged across stages."""
        total = StoreStats()
        for s in self.stages:
            total += s.store_stats
        return total

    def to_dict(self) -> dict:
        d = {
            "store_kind": self.store_kind,
            "num_stages": len(self.stages),
            "total_train_wall_s": self.total_train_wall,
            "total_unlearn_wall_s": self.total_unlearn_wall,
            "total_cost_units": self.total_cost_units,
            "store_stats": self.store_stats.to_dict(),
            "stages": [s.to_dict() for s in self.stages],
        }
        tr = get_tracer()
        if tr.enabled:
            d["telemetry"] = tr.describe()
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)


class FederatedSession:
    """Drives one simulator through K training stages with interleaved
    unlearning requests.

    >>> session = FederatedSession(sim, store_kind="coded")
    >>> schedule = RequestSchedule([UnlearnRequest([victim], after_stage=0)])
    >>> report = session.run(num_stages=3, schedule=schedule)
    """

    def __init__(self, sim, store_kind: str = "coded", engine: str = "fused",
                 encode_group: Optional[int] = None, slice_dtype=None,
                 rounds: Optional[int] = None, batch_requests: bool = False,
                 strict_schedule: bool = False, faults=None,
                 checkpoint_every: int = 0,
                 checkpoint_dir: Optional[str] = None,
                 store_options: Optional[dict] = None):
        if checkpoint_every and not checkpoint_dir:
            raise ValueError(
                f"checkpoint_every={checkpoint_every} needs a "
                f"checkpoint_dir to write snapshots to")
        if checkpoint_every < 0:
            raise ValueError(
                f"checkpoint_every must be >= 0 (0 disables periodic "
                f"snapshots), got {checkpoint_every}")
        self.sim = sim
        self.store_kind = store_kind
        self.store_options = dict(store_options or {})
        self.engine = engine
        self.encode_group = encode_group
        self.slice_dtype = slice_dtype
        self.rounds = rounds
        self.batch_requests = batch_requests
        self.strict_schedule = strict_schedule
        self.faults = faults                     # optional FaultPlan
        self.records: List[object] = []          # StageRecord per stage
        self.report = SessionReport(store_kind=store_kind)
        self.checkpoint_every = int(checkpoint_every)
        self.checkpoint_dir = checkpoint_dir
        self.checkpointer = None
        if checkpoint_dir is not None:
            from repro.durability.checkpointer import CheckpointManager
            self.checkpointer = CheckpointManager(checkpoint_dir,
                                                  faults=faults)
            if not self.checkpoint_every:
                self.checkpoint_every = 1        # dir given: snapshot per stage
        self._served: set = set()                # committed request ids
        self.last_resume_info: Optional[dict] = None
        # hash-chained audit of unlearning lifecycle events; journal-backed
        # (and crash-durable) whenever the session checkpoints
        self.audit = AuditLog(
            journal=self.checkpointer.journal
            if self.checkpointer is not None else None)

    # ---------------------------------------------------------------- stages
    def run_stage(self, rounds: Optional[int] = None):
        """Train the next stage and append its record + report entry."""
        tr = get_tracer()
        t0 = time.perf_counter()
        with tr.span("session.stage", stage=len(self.records),
                     engine=self.engine, store=self.store_kind):
            record = train_stage(self.sim, store_kind=self.store_kind,
                                 rounds=rounds or self.rounds,
                                 engine=self.engine,
                                 encode_group=self.encode_group,
                                 slice_dtype=self.slice_dtype,
                                 faults=self.faults,
                                 store_options=self.store_options)
        wall = time.perf_counter() - t0
        self.records.append(record)
        stats = record.store.stats.snapshot()
        tr.metrics.absorb_store_stats(stats, stage=len(self.records) - 1)
        self.report.stages.append(StageReport(
            stage=len(self.records) - 1, plan_stage=record.plan.stage,
            train_wall=wall, num_shards=record.plan.num_shards,
            clients=record.plan.clients,
            store_stats=stats))
        return record

    # -------------------------------------------------------------- requests
    def _target_stages(self, request: UnlearnRequest,
                       clients: Sequence[int]) -> List[int]:
        if request.stages is not None:
            bad = [i for i in request.stages
                   if not 0 <= i < len(self.records)]
            if bad:
                raise ValueError(
                    f"request targets session stage(s) {bad}; only "
                    f"{len(self.records)} stage(s) have completed")
            return sorted(request.stages)
        hit = set(clients)
        return [i for i, rec in enumerate(self.records)
                if hit & set(rec.plan.clients)]

    def resolve_request(self, request: UnlearnRequest):
        """Step-wise serving API, part 1: resolve a request against the
        completed stages.  Returns ``(clients, stage_plan)`` where
        ``stage_plan`` maps each impacted session stage index to the subset
        of ``clients`` that participated in it (cross-stage isolation: a
        stage without any requested client is simply absent)."""
        if not self.records:
            raise RuntimeError("no completed stages to unlearn from")
        clients = request.resolve_clients(self.records[-1].plan)
        stage_plan = {}
        for i in self._target_stages(request, clients):
            members = set(self.records[i].plan.clients)
            stage_clients = [c for c in clients if c in members]
            if stage_clients:
                stage_plan[i] = stage_clients
        return clients, stage_plan

    def record_result(self, stage: int, res, apply: bool = False):
        """Step-wise serving API, part 2: land one stage's ``UnlearnResult``
        in the session report (and, under serving semantics, fold the
        unlearned shard models back into the stage record).  Both the
        synchronous ``unlearn`` path and the async service ledger go
        through here."""
        record = self.records[stage]
        if apply:
            if set(res.models) != set(record.shard_models):
                raise ValueError(
                    f"apply=True needs shard-level models; framework "
                    f"{res.framework!r} returned keys "
                    f"{sorted(res.models)} for shards "
                    f"{sorted(record.shard_models)}")
            record.shard_models = dict(res.models)
        self.report.stages[stage].unlearn.append(res)
        # decode/retrieve traffic lands after the training snapshot
        self.report.stages[stage].store_stats = record.store.stats.snapshot()
        return res

    def unlearn(self, request: UnlearnRequest):
        """Serve one request: dispatch its framework on every impacted stage
        (and only those).  Returns the list of per-stage ``UnlearnResult``."""
        _clients, stage_plan = self.resolve_request(request)
        results = []
        for i, stage_clients in stage_plan.items():
            res = run_unlearn(self.sim, request.framework, self.records[i],
                              stage_clients,
                              rounds=request.rounds or self.rounds)
            res.request_id = request.request_id
            results.append(self.record_result(i, res, apply=request.apply))
        return results

    def unlearn_batch(self, requests: Sequence[UnlearnRequest]):
        """Serve a group of requests together: requests with compatible
        serving options (framework, rounds, explicit stages, apply) merge
        into ONE request over the union of their clients, so each impacted
        shard retrains once per batch instead of once per request (and the
        SE framework can vmap the impacted shards into a single
        ``calib_stage`` dispatch).

        Note the merged semantics: every produced model has ALL of the
        batch's clients removed — the concurrent-request serving mode
        (paper Fig. 4), not N independent counterfactuals.  Returns the
        flat list of per-stage ``UnlearnResult``s (one per merged group per
        impacted stage).
        """
        if not self.records:
            raise RuntimeError("no completed stages to unlearn from")
        plan = self.records[-1].plan
        groups: dict = {}
        group_ids: dict = {}
        for r in requests:
            key = (r.framework, r.rounds,
                   tuple(r.stages) if r.stages is not None else None, r.apply)
            clients = groups.setdefault(key, [])
            for c in r.resolve_clients(plan):
                if c not in clients:
                    clients.append(c)
            if r.request_id:
                group_ids.setdefault(key, []).append(r.request_id)
        results = []
        for (fw, rounds, stages, apply), clients in groups.items():
            merged = UnlearnRequest(clients, framework=fw, rounds=rounds,
                                    stages=list(stages) if stages else None,
                                    apply=apply,
                                    request_id="+".join(group_ids.get(
                                        (fw, rounds, stages, apply), [])))
            results.extend(self.unlearn(merged))
        return results

    # ------------------------------------------------------------ durability
    def _journal(self, event: dict) -> None:
        if self.checkpointer is not None:
            self.checkpointer.journal.append(event)

    def _crash_site(self, phase: str, stage: int) -> None:
        """Named process-crash site for the chaos harness (``process_kill``
        fires here; a plan without crash injectors is a no-op)."""
        if self.faults is not None and hasattr(self.faults, "crash_site"):
            self.faults.crash_site(("session", phase, stage))

    def _maybe_checkpoint(self, k: int, num_stages: int) -> None:
        if self.checkpointer is None or self.checkpoint_every <= 0:
            return
        if (k + 1) % self.checkpoint_every == 0 or k == num_stages - 1:
            from repro.durability import session_state
            path = self.checkpointer.save(
                session_state.capture_session(self), step=k)
            self._journal({"ev": "snapshot", "step": k,
                           "path": os.path.basename(path)})
            self._crash_site("after_snapshot", k)

    def resume(self, resume_from: str) -> int:
        """Restore from the newest good snapshot under ``resume_from`` and
        replay its journal.  Returns the first stage index still to run.

        Corrupt snapshots (torn writes) are skipped — recovery falls back
        to the previous good one.  Requests the journal shows dispatched
        but never committed re-dispatch exactly once: the restored report
        does not contain them, and re-serving from the restored RNG state
        reproduces the uninterrupted run bit-for-bit."""
        from repro.durability import session_state
        from repro.durability.checkpointer import CheckpointManager
        mgr = self.checkpointer
        if mgr is None or os.path.abspath(mgr.directory) != \
                os.path.abspath(resume_from):
            mgr = CheckpointManager(resume_from, faults=self.faults)
        got = mgr.load_latest()
        if got is None:
            raise FileNotFoundError(
                f"no usable snapshot under {resume_from!r}"
                + (f" ({len(mgr.skipped)} corrupt snapshot(s) skipped: "
                   f"{mgr.skipped})" if mgr.skipped else ""))
        state, step, path = got
        start = session_state.restore_session(self, state)
        if self.checkpointer is None:
            self.checkpointer = mgr
        # splice the audit chain: replay + verify the journaled chain and
        # continue appending from its head, one verifiable history
        if getattr(self.audit, "journal", None) is not mgr.journal:
            self.audit = AuditLog(journal=mgr.journal)
        # exactly-once accounting: ids dispatched but never committed in the
        # journal are re-dispatched by the resumed run (they are absent from
        # the restored report); committed ids at/before the snapshot are in
        # ``self._served`` and are never served twice
        dispatched: list = []
        committed: set = set()
        for ev in mgr.journal.events():
            if ev.get("ev") == "req_dispatch":
                dispatched.extend(ev.get("rids", []))
            elif ev.get("ev") == "req_commit":
                committed.update(ev.get("rids", []))
        inflight = sorted(set(dispatched) - committed - self._served)
        self.last_resume_info = {
            "step": step, "path": path, "start_stage": start,
            "skipped_snapshots": list(mgr.skipped), "inflight": inflight,
        }
        self._journal({"ev": "resume", "from_step": step, "start": start,
                       "skipped": [os.path.basename(p) for p in mgr.skipped],
                       "inflight": inflight})
        return start

    # ------------------------------------------------------------------- run
    def run(self, num_stages: int,
            schedule: Optional[RequestSchedule] = None,
            resume_from: Optional[str] = None) -> SessionReport:
        """K stages back-to-back; after stage k, serve every scheduled
        request with ``after_stage == k`` — one by one, or merged per batch
        when the session was built with ``batch_requests=True``.

        With ``checkpoint_dir``/``checkpoint_every`` set, a snapshot is
        committed every ``checkpoint_every`` completed stages (and after
        the last), and every stage completion / request dispatch / request
        commit is journaled first.  ``resume_from=<dir>`` restores the
        newest good snapshot and continues: completed stages are skipped,
        served requests (by ``request_id``) are never re-applied, and the
        resumed run's models, slices, and accounting are bit-identical to
        an uninterrupted run.

        A request whose ``after_stage`` falls outside ``[0, num_stages)``
        can never come due and would previously vanish without a trace;
        the run now warns about such unserved requests (or raises, when the
        session was built with ``strict_schedule=True``)."""
        start = 0
        if resume_from is not None:
            start = self.resume(resume_from)
        for k in range(start, num_stages):
            self._journal({"ev": "stage_begin", "stage": k})
            self.run_stage()
            self._crash_site("after_stage", k)
            due = schedule.due(k) if schedule is not None else []
            for i, req in enumerate(due):
                if not req.request_id:
                    req.request_id = f"req-s{k}-{i}"
            due = [r for r in due if r.request_id not in self._served]
            if due:
                rids = [r.request_id for r in due]
                for rid in rids:
                    self.audit.record("received", request_id=rid,
                                      after_stage=k)
                if self.batch_requests:
                    self._journal({"ev": "req_dispatch", "rids": rids,
                                   "stage_after": k})
                    self.unlearn_batch(due)
                    self._served.update(rids)
                    for rid in rids:
                        self.audit.record("retrained", request_id=rid,
                                          after_stage=k, batched=True)
                    self._journal({"ev": "req_commit", "rids": rids,
                                   "stage_after": k})
                    for rid in rids:
                        self.audit.record("committed", request_id=rid,
                                          after_stage=k)
                else:
                    for req in due:
                        self._journal({"ev": "req_dispatch",
                                       "rids": [req.request_id],
                                       "stage_after": k})
                        self.unlearn(req)
                        self._served.add(req.request_id)
                        self.audit.record("retrained",
                                          request_id=req.request_id,
                                          after_stage=k, batched=False)
                        self._journal({"ev": "req_commit",
                                       "rids": [req.request_id],
                                       "stage_after": k})
                        self.audit.record("committed",
                                          request_id=req.request_id,
                                          after_stage=k)
            self._crash_site("after_requests", k)
            self._maybe_checkpoint(k, num_stages)
            self._journal({"ev": "stage_commit", "stage": k})
        if schedule is not None:
            missed = [r for r in schedule.requests
                      if not 0 <= r.after_stage < num_stages]
            if missed:
                msg = (f"{len(missed)} scheduled unlearning request(s) were "
                       f"never served: after_stage "
                       f"{sorted(r.after_stage for r in missed)} outside the "
                       f"run's [0, {num_stages}) stage range")
                if self.strict_schedule:
                    raise ValueError(msg)
                warnings.warn(msg, stacklevel=2)
        return self.report
