"""Stage training — one isolated-sharding FedAvg stage against a registered
parameter store.

This is the training half of the experiment layer: ``train_stage(sim, ...)``
runs G FedAvg rounds for every shard of a freshly sampled stage and writes
each round's parameters into the store through the single
``ParameterStore.put_round(RoundPayload)`` entry point.  The store's
``wants`` attribute tells the fused engine which payload form to compute
*inside* the jitted round step ("flat" for the coded store, "stacked" for
the uncoded ones), so the store choice never forces a host round-trip.

``FLSimulator.train_stage`` is a deprecated shim over this function.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import RoundPayload
from repro.core import coding, unlearning
from repro.models import init_params


def train_stage(sim, store_kind: str = "coded", rounds: Optional[int] = None,
                engine: str = "fused", encode_group: Optional[int] = None,
                slice_dtype=None):
    """One stage: sample clients, split into shards, G FedAvg rounds per
    shard, storing intermediate params in the requested (registered) store.

    ``engine="fused"`` (default) keeps everything stacked/device-resident
    (see ``repro.fl.simulator`` module docstring); ``engine="legacy"`` is the
    seed per-client path, kept for A/B benchmarking.  ``encode_group``
    batches that many rounds per coded encode (default: all G in one).
    ``slice_dtype`` optionally stores coded slices in e.g. bf16.

    Returns a ``StageRecord``.
    """
    from repro.fl.simulator import StageRecord

    if engine == "legacy":
        if encode_group is not None or slice_dtype is not None:
            raise ValueError("encode_group/slice_dtype need engine='fused'")
        return _train_stage_legacy(sim, store_kind, rounds)
    if engine != "fused":
        raise ValueError(f"unknown engine {engine!r}; use 'fused' or 'legacy'")
    fl = sim.fl
    g_rounds = rounds or fl.global_rounds
    plan = sim.mgr.new_stage()
    rng = jax.random.key(sim.seed + plan.stage)
    w0 = init_params(sim.cfg, rng)
    store = sim._make_store(store_kind, plan,
                            group_rounds=encode_group or g_rounds,
                            slice_dtype=slice_dtype)
    # the store's preferred payload form decides what the jitted round step
    # computes on device; anything unknown degrades to stacked trees.
    kind = "flat" if getattr(store, "wants", "stacked") == "flat" else "stacked"
    step = sim._shard_round[(fl.local_epochs, kind)]
    row_spec = coding.tree_to_flat(w0)[1] if kind == "flat" else None

    # round-major loop: all shards advance one round, then the round's
    # parameters are stored together (the coded store encodes ACROSS the
    # S shards — eq. 5/6 mixes one round's shard vectors).
    shards = sorted(plan.shard_clients)
    ws = {s: w0 for s in shards}
    data = {s: sim._stack_client_data(plan.shard_clients[s]) for s in shards}
    round_globals = {s: [] for s in shards}
    norms_dev = {s: [] for s in shards}
    for g in range(g_rounds):
        payload = {}
        for s in shards:
            round_globals[s].append(ws[s])
            xs, ys = data[s]
            ws[s], payload[s], nrm = step(ws[s], xs, ys)
            norms_dev[s].append(nrm)
        if kind == "flat":
            store.put_round(RoundPayload.from_flat(
                g, plan.shard_clients, payload, row_spec))
        else:
            store.put_round(RoundPayload.from_stacked(
                g, plan.shard_clients, payload))
    store.flush()
    for s in shards:
        round_globals[s].append(ws[s])
    # ONE host sync for every stored-update norm of the stage —
    # the legacy path pulled S*G*M scalars with float(...)
    norms_host = jax.device_get({s: jnp.stack(norms_dev[s]) for s in shards})
    norms = {}
    for s in shards:
        arr = np.asarray(norms_host[s])            # (G, M)
        for g in range(g_rounds):
            for i, c in enumerate(plan.shard_clients[s]):
                norms[(s, g, c)] = float(arr[g, i])
    return StageRecord(plan, dict(ws), round_globals, store,
                       history_norms=norms)


def _train_stage_legacy(sim, store_kind: str = "coded",
                        rounds: Optional[int] = None):
    """Seed per-client round loop (unstack + per-scalar norm pulls +
    per-round tree flatten/encode) — kept for A/B comparison."""
    from repro.fl.simulator import StageRecord

    fl = sim.fl
    g_rounds = rounds or fl.global_rounds
    plan = sim.mgr.new_stage()
    rng = jax.random.key(sim.seed + plan.stage)
    w0 = init_params(sim.cfg, rng)
    store = sim._make_store(store_kind, plan)
    ws = {s: w0 for s in plan.shard_clients}
    data = {s: sim._stack_client_data(cs)
            for s, cs in plan.shard_clients.items()}
    round_globals = {s: [] for s in plan.shard_clients}
    norms = {}
    for g in range(g_rounds):
        all_params = {}
        for s, clients in plan.shard_clients.items():
            round_globals[s].append(ws[s])
            xs, ys = data[s]
            locals_ = sim._local_train[fl.local_epochs](ws[s], xs, ys)
            per_client = [jax.tree.map(lambda a, i=i: a[i], locals_)
                          for i in range(len(clients))]
            all_params.update(dict(zip(clients, per_client)))
            for i, c in enumerate(clients):
                d = unlearning.tree_sub(per_client[i], ws[s])
                norms[(s, g, c)] = float(unlearning.tree_norm(d))
            ws[s] = unlearning.tree_mean(per_client)
        store.put_round(RoundPayload.from_clients(g, plan.shard_clients,
                                                  all_params))
    for s in plan.shard_clients:
        round_globals[s].append(ws[s])
    return StageRecord(plan, dict(ws), round_globals, store,
                       history_norms=norms)
