"""Stage training — one isolated-sharding FedAvg stage against a registered
parameter store.

This is the training half of the experiment layer: ``train_stage(sim, ...)``
runs G FedAvg rounds for every shard of a freshly sampled stage and writes
each round's parameters into the store through the single
``ParameterStore.put_round(RoundPayload)`` entry point.  The store's
``wants`` attribute tells the engine which payload form to compute *inside*
the jitted round step ("flat" for the coded store, "stacked" for the uncoded
ones), so the store choice never forces a host round-trip.

Three engines (dispatch count per stage in parentheses):

* ``engine="stage"`` — the whole-stage superfusion (O(1)): shard data stacked
  to (S, M, n, ...), ``shard_round`` vmapped over shards, ``lax.scan`` over
  the G rounds, and the coded store's Lagrange encode fused into the same
  program — one dispatch produces final models, round globals, update norms,
  and the coded slices.  Ragged stages (unequal client or sample counts per
  shard) degrade gracefully to the fused per-shard path.
* ``engine="fused"`` (default) — one jitted ``shard_round`` per (shard,
  round) plus one deferred batched encode (G·S + 1).
* ``engine="legacy"`` — the seed per-client path (≫ G·S·M), kept for A/B
  benchmarking.

``FLSimulator.train_stage`` is a deprecated shim over this function.
"""
from __future__ import annotations

import warnings
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.stores.store import RoundPayload
from repro.core import coding, unlearning
from repro.models import init_params
from repro.telemetry import get_tracer

ENGINES = ("stage", "fused", "legacy")


def train_stage(sim, store_kind: str = "coded", rounds: Optional[int] = None,
                engine: str = "fused", encode_group: Optional[int] = None,
                slice_dtype=None, faults=None, store_options=None):
    """One stage: sample clients, split into shards, G FedAvg rounds per
    shard, storing intermediate params in the requested (registered) store.

    ``engine`` selects the round engine (see module docstring):
    ``"stage"`` (one dispatch per stage), ``"fused"`` (default, one per
    shard-round), or ``"legacy"`` (the seed per-client path, for A/B).
    ``encode_group`` batches that many rounds per coded encode on the fused
    engine (default: all G in one; the stage engine always encodes all G
    inside the program).  ``slice_dtype`` optionally stores coded slices in
    e.g. bf16.  ``store_options`` passes factory-specific knobs through to
    the registered store (e.g. ``store_kind="tiered"`` budgets/eviction).

    ``faults`` (a ``repro.faults.FaultPlan``) applies the plan's client
    dropout to the freshly sampled stage (clients vanish before training —
    shards may go ragged, which the stage engine tolerates by degrading to
    the per-shard fused path, recorded as a ``DegradedModeEvent`` instead of
    a warning) and attaches the plan's slice injectors to the stage's store.

    Returns a ``StageRecord``.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; use one of {ENGINES}")
    if engine == "legacy":
        if encode_group is not None or slice_dtype is not None:
            raise ValueError("encode_group/slice_dtype need engine="
                             "'fused' or 'stage'")
        if faults is not None:
            raise ValueError("fault plans need engine='fused' or 'stage'")
        with get_tracer().span("stage.train", engine=engine,
                               store=store_kind) as sp:
            rec = _train_stage_legacy(sim, store_kind, rounds)
            sp.annotate(stage=rec.plan.stage)
            return rec
    if engine == "stage" and encode_group is not None:
        raise ValueError("encode_group is a fused-engine option; the stage "
                         "engine always encodes all rounds in-program")

    fl = sim.fl
    g_rounds = rounds or fl.global_rounds
    with get_tracer().span("stage.train", engine=engine,
                           store=store_kind) as sp:
        plan = sim.mgr.new_stage()
        rng = jax.random.key(sim.seed + plan.stage)
        w0 = init_params(sim.cfg, rng)
        dropped = []
        if faults is not None:
            by_shard = faults.dropped_clients(plan.stage, plan.shard_clients)
            for s, cs in by_shard.items():
                gone = set(cs)
                plan.shard_clients[s] = [c for c in plan.shard_clients[s]
                                         if c not in gone]
                dropped.extend(cs)
            dropped.sort()
        sp.annotate(stage=plan.stage, shards=len(plan.shard_clients),
                    rounds=g_rounds, dropped=len(dropped))
        store = sim._make_store(store_kind, plan,
                                group_rounds=encode_group or g_rounds,
                                slice_dtype=slice_dtype,
                                **(store_options or {}))
        if faults is not None and hasattr(store, "attach_faults"):
            store.attach_faults(faults)
        # the store's preferred payload form decides what the jitted round
        # step computes on device; anything unknown degrades to stacked trees.
        kind = ("flat" if getattr(store, "wants", "stacked") == "flat"
                else "stacked")
        data = {s: sim._stack_client_data(cs)
                for s, cs in plan.shard_clients.items()}

        if engine == "stage":
            if _stackable(plan, data):
                return _run_stage_program(sim, plan, store, w0, data,
                                          g_rounds, kind, slice_dtype)
            sp.annotate(degraded="ragged_stage")
            if faults is not None:
                from repro.faults.events import DegradedModeEvent
                faults.ledger.record(DegradedModeEvent(
                    stage=plan.stage,
                    reason="ragged_stage", fallback="fused",
                    dropped_clients=tuple(dropped)))
            else:
                warnings.warn(
                    "ragged stage (unequal client or sample counts per "
                    "shard); stage engine degrading to per-shard fused "
                    "dispatch",
                    stacklevel=2)
        return _run_fused(sim, plan, store, w0, data, g_rounds, kind)


def _stackable(plan, data) -> bool:
    """The stage program needs one (S, M, n, ...) stack: every shard must
    hold the same number of clients with the same per-client sample count."""
    shapes = {data[s][0].shape for s in plan.shard_clients}
    return len(shapes) == 1


def _flat_row_len(w0) -> int:
    """Per-client flat parameter length P (host-side, no device work)."""
    return sum(int(np.prod(l.shape)) if l.shape else 1
               for l in jax.tree.leaves(w0))


def _run_stage_program(sim, plan, store, w0, data, g_rounds, kind,
                       slice_dtype):
    """The whole-stage superfusion: ONE jitted dispatch runs all G rounds of
    all S shards and (for the coded store) the Lagrange encode."""
    from repro.fl.simulator import StackedRoundGlobals, StageRecord

    fl = sim.fl
    shards = sorted(plan.shard_clients)
    xs = jnp.stack([data[s][0] for s in shards])      # (S, M, n, ...)
    ys = jnp.stack([data[s][1] for s in shards])
    # in-program encode only when the store can register pre-encoded slices
    encode = kind == "flat" and hasattr(store, "put_stage_encoded")
    use_kernel = bool(getattr(store, "use_kernel", False))
    prog = sim._get_stage_program(fl.local_epochs, kind, g_rounds,
                                  encode=encode, out_dtype=slice_dtype,
                                  use_kernel=use_kernel)
    row_spec = coding.tree_to_flat(w0)[1] if kind == "flat" else None
    tr = get_tracer()
    if encode:
        enc = jnp.asarray(store.scheme.encode_matrix(), jnp.float32)
        args = (w0, xs, ys, enc)
    else:
        args = (w0, xs, ys)
    with tr.span("xla.stage_program", stage=plan.stage, shards=len(shards),
                 rounds=g_rounds, encode=encode) as sp:
        if tr.annotate_costs:
            from repro.telemetry.export import hlo_cost_of
            sp.annotate(**hlo_cost_of(prog, *args))
        final, round_in, hist, norms_dev = prog(*args)
    if encode:
        store.put_stage_encoded(hist, row_spec,
                                row_len=_flat_row_len(w0))
    else:
        for g in range(g_rounds):
            if kind == "flat":
                payload = RoundPayload.from_flat(
                    g, plan.shard_clients,
                    {s: hist[g, i] for i, s in enumerate(shards)}, row_spec)
            else:
                payload = RoundPayload.from_stacked(
                    g, plan.shard_clients,
                    {s: jax.tree.map(lambda a, g=g, i=i: a[g, i], hist)
                     for i, s in enumerate(shards)})
            store.put_round(payload)
    store.flush()
    shard_models = {s: jax.tree.map(lambda a, i=i: a[i], final)
                    for i, s in enumerate(shards)}
    round_globals = {s: StackedRoundGlobals(round_in, final, i)
                     for i, s in enumerate(shards)}
    # ONE host sync for every stored-update norm of the stage
    arr = np.asarray(jax.device_get(norms_dev))        # (G, S, M)
    norms = {}
    for i, s in enumerate(shards):
        for g in range(g_rounds):
            for j, c in enumerate(plan.shard_clients[s]):
                norms[(s, g, c)] = float(arr[g, i, j])
    return StageRecord(plan, shard_models, round_globals, store,
                       history_norms=norms)


def _run_fused(sim, plan, store, w0, data, g_rounds, kind):
    """Fused per-shard engine: one jitted ``shard_round`` per (shard, round),
    everything stacked/device-resident (see ``repro.fl.simulator``)."""
    from repro.fl.simulator import StageRecord

    fl = sim.fl
    step = sim._shard_round[(fl.local_epochs, kind)]
    row_spec = coding.tree_to_flat(w0)[1] if kind == "flat" else None

    # round-major loop: all shards advance one round, then the round's
    # parameters are stored together (the coded store encodes ACROSS the
    # S shards — eq. 5/6 mixes one round's shard vectors).
    shards = sorted(plan.shard_clients)
    ws = {s: w0 for s in shards}
    round_globals = {s: [] for s in shards}
    norms_dev = {s: [] for s in shards}
    for g in range(g_rounds):
        payload = {}
        for s in shards:
            round_globals[s].append(ws[s])
            xs, ys = data[s]
            ws[s], payload[s], nrm = step(ws[s], xs, ys)
            norms_dev[s].append(nrm)
        if kind == "flat":
            store.put_round(RoundPayload.from_flat(
                g, plan.shard_clients, payload, row_spec))
        else:
            store.put_round(RoundPayload.from_stacked(
                g, plan.shard_clients, payload))
    store.flush()
    for s in shards:
        round_globals[s].append(ws[s])
    # ONE host sync for every stored-update norm of the stage —
    # the legacy path pulled S*G*M scalars with float(...)
    norms_host = jax.device_get({s: jnp.stack(norms_dev[s]) for s in shards})
    norms = {}
    for s in shards:
        arr = np.asarray(norms_host[s])            # (G, M)
        for g in range(g_rounds):
            for i, c in enumerate(plan.shard_clients[s]):
                norms[(s, g, c)] = float(arr[g, i])
    return StageRecord(plan, dict(ws), round_globals, store,
                       history_norms=norms)


def _train_stage_legacy(sim, store_kind: str = "coded",
                        rounds: Optional[int] = None):
    """Seed per-client round loop (unstack + per-scalar norm pulls +
    per-round tree flatten/encode) — kept for A/B comparison."""
    from repro.fl.simulator import StageRecord

    fl = sim.fl
    g_rounds = rounds or fl.global_rounds
    plan = sim.mgr.new_stage()
    rng = jax.random.key(sim.seed + plan.stage)
    w0 = init_params(sim.cfg, rng)
    store = sim._make_store(store_kind, plan)
    ws = {s: w0 for s in plan.shard_clients}
    data = {s: sim._stack_client_data(cs)
            for s, cs in plan.shard_clients.items()}
    round_globals = {s: [] for s in plan.shard_clients}
    norms = {}
    for g in range(g_rounds):
        all_params = {}
        for s, clients in plan.shard_clients.items():
            round_globals[s].append(ws[s])
            xs, ys = data[s]
            locals_ = sim._local_train[fl.local_epochs](ws[s], xs, ys)
            per_client = [jax.tree.map(lambda a, i=i: a[i], locals_)
                          for i in range(len(clients))]
            all_params.update(dict(zip(clients, per_client)))
            for i, c in enumerate(clients):
                d = unlearning.tree_sub(per_client[i], ws[s])
                norms[(s, g, c)] = float(unlearning.tree_norm(d))
            ws[s] = unlearning.tree_mean(per_client)
        store.put_round(RoundPayload.from_clients(g, plan.shard_clients,
                                                  all_params))
    for s in plan.shard_clients:
        round_globals[s].append(ws[s])
    return StageRecord(plan, dict(ws), round_globals, store,
                       history_norms=norms)
