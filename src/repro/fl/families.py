"""Model-family registry — adapters that unlock the model zoo for federated
scenarios.

Each ``ModelFamily`` builds a CPU-trainable ``ModelConfig`` from ``configs/``
(reduced where the source arch is production-scale), declares the task kind
it plays (``classification`` / ``generation``), and names the Pallas kernel
ops its forward routes through — the mamba adapter trains through the
``ssm_scan`` kernel (``mamba_impl="pallas"``) and the rwkv6 adapter through
the ``wkv`` kernel (``rwkv_impl="pallas"``), both in interpret mode off-TPU
with oracle-VJP backward passes.  Families register under one or more names
(``@register_model_family``), mirroring ``STORES`` / ``FRAMEWORKS`` /
``TASKS``: a new architecture reaches ``run_scenario`` → ``FederatedSession``
→ coded store → SE unlearning by subclassing + decorating, no simulator
surgery.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple, Type

from repro.configs import ModelConfig, get_config


class ModelFamily:
    """Base class for family adapters.  Subclass, implement ``build``, and
    register with ``@register_model_family(name, *aliases)``."""

    name: str = ""
    task: str = "generation"            # task kind this family plays
    kernel_ops: Tuple[str, ...] = ()    # Pallas ops the forward routes through
    default_lr: Optional[float] = None  # None -> the task's default
    default_batch: Optional[int] = None

    def build(self, cfg) -> ModelConfig:
        """Build the family's ``ModelConfig`` for one ``ScenarioConfig``."""
        raise NotImplementedError


FAMILIES: Dict[str, Type[ModelFamily]] = {}


def register_model_family(*names: str):
    """Class decorator registering a ``ModelFamily`` under ``names`` (the
    first is canonical)."""
    if not names:
        raise ValueError("register_model_family needs at least one name")

    def deco(cls: Type[ModelFamily]) -> Type[ModelFamily]:
        cls.name = names[0]
        for n in names:
            FAMILIES[n] = cls
        return cls
    return deco


def get_model_family(name: str) -> ModelFamily:
    try:
        return FAMILIES[name]()
    except KeyError:
        raise ValueError(f"unknown model family {name!r}; registered: "
                         f"{sorted(FAMILIES)}") from None


def canonical_families() -> Tuple[str, ...]:
    """The registered families, one name per class, sorted."""
    return tuple(sorted({cls.name for cls in FAMILIES.values()}))


# ---------------------------------------------------------------------------
# Built-in adapters
# ---------------------------------------------------------------------------

_TINY_LM = dict(num_layers=2, d_model=32, d_ff=64, vocab_size=109,
                param_dtype="float32", compute_dtype="float32")


@register_model_family("cnn")
class CNNFamily(ModelFamily):
    """The paper's conv classifier (Sec 5.1) at the CPU-container scale —
    bit-identical to the pre-registry ``_build_image`` model."""

    task = "classification"

    def build(self, cfg) -> ModelConfig:
        return dataclasses.replace(get_config("cnn-paper"),
                                   image_size=cfg.image_size, d_model=48,
                                   cnn_channels=(8, 16))


@register_model_family("transformer", "nanogpt")
class TransformerFamily(ModelFamily):
    """The paper's NanoGPT (4L, d=16, vocab 109) — bit-identical to the
    pre-registry ``_build_lm`` model."""

    task = "generation"

    def build(self, cfg) -> ModelConfig:
        return get_config("nanogpt-paper")


@register_model_family("mamba")
class MambaFamily(ModelFamily):
    """Selective-SSM stack (jamba-style mamba blocks) routed through the
    fused ``ssm_scan`` Pallas kernel — interpret mode on CPU, the real
    kernel on TPU."""

    task = "generation"
    kernel_ops = ("ssm_scan",)
    default_lr = 0.1

    def build(self, cfg) -> ModelConfig:
        return ModelConfig(name="mamba-fl", family="hybrid",
                           layer_pattern=("mamba",), num_heads=4,
                           num_kv_heads=4, ssm_state_dim=8, ssm_expand=2,
                           mamba_impl="pallas", norm_type="layernorm",
                           act="gelu", source="scenario zoo (mamba)",
                           **_TINY_LM)


@register_model_family("rwkv6", "rwkv")
class RWKV6Family(ModelFamily):
    """Attention-free RWKV-6 stack routed through the ``wkv`` Pallas kernel
    (interpret mode on CPU)."""

    task = "generation"
    kernel_ops = ("wkv",)
    default_lr = 0.1

    def build(self, cfg) -> ModelConfig:
        return ModelConfig(name="rwkv6-fl", family="ssm",
                           layer_pattern=("rwkv",), num_heads=2,
                           num_kv_heads=2, rwkv_head_dim=16,
                           rwkv_impl="pallas", norm_type="layernorm",
                           act="silu", source="scenario zoo (rwkv6)",
                           **_TINY_LM)


@register_model_family("moe")
class MoEFamily(ModelFamily):
    """Mixture-of-experts FFN transformer (granite-style top-k routing) —
    per-client expert specialization under label/quantity skew."""

    task = "generation"
    default_lr = 0.1

    def build(self, cfg) -> ModelConfig:
        return ModelConfig(name="moe-fl", family="moe", num_heads=4,
                           num_kv_heads=2, num_experts=4,
                           experts_per_token=2, moe_d_ff=32,
                           norm_type="rmsnorm", act="silu",
                           source="scenario zoo (moe)", **_TINY_LM)
