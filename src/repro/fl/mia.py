"""Membership-inference attack (MIA) evaluation — the paper's privacy metric.

Protocol (threshold/shadow-free variant of [Shokri et al. 2017] as used by
FedEraser): an attack classifier (logistic regression on output-derived
features: loss, max-prob, entropy) is trained to separate *member* (retained
clients' training data) from *non-member* (held-out test data) under the
target model. It is then evaluated on the *forgotten* client's data: the F1
score of the attack claiming "member" on forgotten data measures how much the
unlearned model still remembers. Lower = better unlearning; a fully retrained
model scores near the no-information rate.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from repro.fl.tasks import resolve_task


def _features(predict, models: Dict[int, object], make_batch, xs, ys,
              task, batch: int = 200) -> np.ndarray:
    """Per-example [nll, max_prob, entropy] under the (ensemble) model.

    The per-example feature shape is delegated to the task registry
    (``TaskSpec.mia_features``: classification scores each example,
    generation averages over sequence positions); ``task`` may be a
    ``TaskSpec`` instance, class, or registered name (``"image"``/``"lm"``
    still resolve as the deprecated aliases)."""
    spec = resolve_task(task)
    feats = []
    n = len(xs)
    for i in range(0, n, batch):
        x = jnp.asarray(xs[i:i + batch])
        y = jnp.asarray(ys[i:i + batch])
        logits = None
        for m in models.values():
            lg = predict(m, make_batch(x, y))
            logits = lg if logits is None else logits + lg
        logits = (logits / len(models)).astype(jnp.float32)
        feats.append(np.asarray(spec.mia_features(logits, y)))
    return np.concatenate(feats, axis=0)


def attack_f1(member_flags: np.ndarray, nonmember_flags: np.ndarray) -> float:
    """F1 of an attack claiming 'member' on forgotten data, with the false
    positives measured on an equally sized true non-member split — shared by
    the threshold attack below and the shadow-model attack in
    ``repro.verify.shadow``.  ``member_flags``: attack decisions (1 =
    'member') on the forgotten data; ``nonmember_flags``: decisions on true
    non-members."""
    n_eval = len(member_flags)
    tp = int(np.sum(member_flags))        # forgotten flagged as member
    fp = int(np.sum(nonmember_flags))     # true non-members flagged as member
    fn = n_eval - tp
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return float(2 * prec * rec / max(prec + rec, 1e-9))


def _logreg_fit(x: np.ndarray, y: np.ndarray, steps: int = 400,
                lr: float = 0.5):
    """Tiny logistic regression (numpy GD) with feature standardisation."""
    mu, sd = x.mean(0), x.std(0) + 1e-9
    xs = (x - mu) / sd
    w = np.zeros(x.shape[1])
    b = 0.0
    for _ in range(steps):
        z = xs @ w + b
        p = 1 / (1 + np.exp(-z))
        g = p - y
        w -= lr * (xs.T @ g) / len(y)
        b -= lr * g.mean()
    return (w, b, mu, sd)


def _logreg_score(model, x: np.ndarray) -> np.ndarray:
    w, b, mu, sd = model
    return ((x - mu) / sd) @ w + b


def _logreg_predict(model, x: np.ndarray, threshold: float) -> np.ndarray:
    """Balanced-threshold decision: the attacker flags the top half of its
    score distribution as 'member' (standard MIA practice — under no signal
    this yields the no-information F1 ~ 0.5 instead of degenerate 0/1)."""
    return (_logreg_score(model, x) > threshold).astype(np.int64)


def mia_f1(predict, models: Dict[int, object], make_batch, task,
           member_data, nonmember_data, forgotten_data) -> float:
    """F1 of the attack detecting *forgotten* examples as members.

    member/nonmember/forgotten: (xs, ys) tuples. Returns F1 in [0,1]; the
    paper reports this with a down arrow (lower = data better forgotten).
    """
    fx_m = _features(predict, models, make_batch, *member_data, task)
    fx_n = _features(predict, models, make_batch, *nonmember_data, task)
    x = np.concatenate([fx_m, fx_n])
    y = np.concatenate([np.ones(len(fx_m)), np.zeros(len(fx_n))])
    attack = _logreg_fit(x, y)
    threshold = float(np.median(_logreg_score(attack, x)))

    fx_f = _features(predict, models, make_batch, *forgotten_data, task)
    n_eval = min(len(fx_f), len(fx_n))
    pred_f = _logreg_predict(attack, fx_f[:n_eval], threshold)  # 1 = "member"
    pred_n = _logreg_predict(attack, fx_n[:n_eval], threshold)
    # attack's positive class = member; forgotten data SHOULD be non-member.
    return attack_f1(pred_f, pred_n)
