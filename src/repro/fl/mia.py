"""Membership-inference attack (MIA) evaluation — the paper's privacy metric.

Protocol (threshold/shadow-free variant of [Shokri et al. 2017] as used by
FedEraser): an attack classifier (logistic regression on output-derived
features: loss, max-prob, entropy) is trained to separate *member* (retained
clients' training data) from *non-member* (held-out test data) under the
target model. It is then evaluated on the *forgotten* client's data: the F1
score of the attack claiming "member" on forgotten data measures how much the
unlearned model still remembers. Lower = better unlearning; a fully retrained
model scores near the no-information rate.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def _features(predict, models: Dict[int, object], make_batch, xs, ys,
              task: str, batch: int = 200) -> np.ndarray:
    """Per-example [nll, max_prob, entropy] under the (ensemble) model."""
    feats = []
    n = len(xs)
    for i in range(0, n, batch):
        x = jnp.asarray(xs[i:i + batch])
        y = jnp.asarray(ys[i:i + batch])
        logits = None
        for m in models.values():
            lg = predict(m, make_batch(x, y))
            logits = lg if logits is None else logits + lg
        logits = (logits / len(models)).astype(jnp.float32)
        if task in ("lm", "generation"):
            # per-sequence means
            ll = jax.nn.log_softmax(logits, -1)
            gold = jnp.take_along_axis(ll, y[..., None], -1)[..., 0]
            nll = -gold.mean(-1)
            p = jnp.exp(ll)
            ent = (-(p * ll).sum(-1)).mean(-1)
            mx = p.max(-1).mean(-1)
        else:
            ll = jax.nn.log_softmax(logits, -1)
            nll = -jnp.take_along_axis(ll, y[:, None], -1)[:, 0]
            p = jnp.exp(ll)
            ent = -(p * ll).sum(-1)
            mx = p.max(-1)
        feats.append(np.stack([np.asarray(nll), np.asarray(mx),
                               np.asarray(ent)], axis=1))
    return np.concatenate(feats, axis=0)


def _logreg_fit(x: np.ndarray, y: np.ndarray, steps: int = 400,
                lr: float = 0.5) -> Tuple[np.ndarray, float]:
    """Tiny logistic regression (numpy GD) with feature standardisation."""
    mu, sd = x.mean(0), x.std(0) + 1e-9
    xs = (x - mu) / sd
    w = np.zeros(x.shape[1])
    b = 0.0
    for _ in range(steps):
        z = xs @ w + b
        p = 1 / (1 + np.exp(-z))
        g = p - y
        w -= lr * (xs.T @ g) / len(y)
        b -= lr * g.mean()
    return (w, b, mu, sd)


def _logreg_score(model, x: np.ndarray) -> np.ndarray:
    w, b, mu, sd = model
    return ((x - mu) / sd) @ w + b


def _logreg_predict(model, x: np.ndarray, threshold: float) -> np.ndarray:
    """Balanced-threshold decision: the attacker flags the top half of its
    score distribution as 'member' (standard MIA practice — under no signal
    this yields the no-information F1 ~ 0.5 instead of degenerate 0/1)."""
    return (_logreg_score(model, x) > threshold).astype(np.int64)


def mia_f1(predict, models: Dict[int, object], make_batch, task: str,
           member_data, nonmember_data, forgotten_data) -> float:
    """F1 of the attack detecting *forgotten* examples as members.

    member/nonmember/forgotten: (xs, ys) tuples. Returns F1 in [0,1]; the
    paper reports this with a down arrow (lower = data better forgotten).
    """
    fx_m = _features(predict, models, make_batch, *member_data, task)
    fx_n = _features(predict, models, make_batch, *nonmember_data, task)
    x = np.concatenate([fx_m, fx_n])
    y = np.concatenate([np.ones(len(fx_m)), np.zeros(len(fx_n))])
    attack = _logreg_fit(x, y)
    threshold = float(np.median(_logreg_score(attack, x)))

    fx_f = _features(predict, models, make_batch, *forgotten_data, task)
    n_eval = min(len(fx_f), len(fx_n))
    pred_f = _logreg_predict(attack, fx_f[:n_eval], threshold)  # 1 = "member"
    pred_n = _logreg_predict(attack, fx_n[:n_eval], threshold)
    # attack's positive class = member; forgotten data SHOULD be non-member.
    tp = pred_f.sum()                 # forgotten flagged as member
    fp = pred_n.sum()                 # true non-members flagged as member
    fn = n_eval - tp
    prec = tp / max(tp + fp, 1)
    rec = tp / max(tp + fn, 1)
    return float(2 * prec * rec / max(prec + rec, 1e-9))
