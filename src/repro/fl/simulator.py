"""CPU-scale federated learning + unlearning simulator (paper Sec 5).

Runs the paper's experimental protocol end-to-end on the paper's own models
(CNN classifier / NanoGPT): C clients, a sampled subset per stage split into S
isolated shards, FedAvg within shards, intermediate-parameter storage
(full / uncoded-shard / coded), and the four unlearning frameworks
(FR / FE / RR / SE).

Client local training is vmapped (clients in a shard train in parallel);
everything is jitted once per (model, batch-shape).

Round engine
------------
The hot loop keeps client parameters **stacked (M, ...) on device** from
local training through FedAvg, calibration, and coded encoding:

* ``shard_round`` (jitted, one dispatch per shard per round) runs the vmapped
  local training and, in the same XLA program, computes the FedAvg mean
  (``tree.map(mean(0))``), the per-client update norms as one (M,) reduction,
  and — for the coded store — the stacked (M, P) flat parameter matrix
  (``coding.tree_to_flat_stacked``). No per-client unstack, no per-scalar
  host pulls: stored-update norms are fetched ONCE per stage as arrays.
* ``CodedStore.put_round_flat`` takes the pre-flattened matrices with specs
  and padding cached per stage, and defers the Lagrange encode so G rounds
  are batched into a single (S, G*P) coded matmul.
* SE/FE calibrated retraining (eq. 3) runs through ``calib_round`` — vmapped
  retraining plus ``unlearning.calibrate_stacked`` fused in one jit — instead
  of a per-client Python loop over pytrees.

The seed per-client path is kept callable via ``train_stage(...,
engine="legacy")`` for A/B benchmarking (``benchmarks/fig6_round_engine.py``)
and numerical-equivalence tests (``tests/test_round_engine.py``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (CodedStore, FullStore, StoreStats,
                                    UncodedShardStore, tree_bytes)
from repro.configs.base import FLConfig, ModelConfig, OptimizerConfig
from repro.core import coding, unlearning
from repro.core.sharding import ShardManager, StagePlan
from repro.models import init_params, loss_fn, predict_fn
from repro.optim import make_optimizer
from repro.optim.fisher import diag_fisher, fisher_precondition


@dataclass
class StageRecord:
    plan: StagePlan
    shard_models: Dict[int, object]               # final per-shard globals
    round_globals: Dict[int, List[object]]        # shard -> [w^g inputs], len G+1
    store: object                                 # parameter store
    history_norms: Dict[Tuple[int, int, int], float] = field(default_factory=dict)
    # (shard, round, client) -> ||delta|| of the stored update


@dataclass
class UnlearnResult:
    framework: str
    models: Dict[int, object]        # shard -> unlearned model (single: {0: w})
    wall_time: float
    cost_units: float                # client-epochs of retraining
    store_stats: Optional[StoreStats]
    impacted_shards: Sequence[int]


class FLSimulator:
    def __init__(self, model_cfg: ModelConfig, fl_cfg: FLConfig,
                 client_data: Dict[int, Tuple[np.ndarray, np.ndarray]],
                 task: str, opt_cfg: Optional[OptimizerConfig] = None,
                 local_batch: int = 20, seed: int = 0):
        self.cfg = model_cfg
        self.fl = fl_cfg
        self.task = task                      # "image" | "lm"
        self.opt = opt_cfg or OptimizerConfig(name="sgdm", lr=0.05, grad_clip=0.0)
        self.client_data = client_data
        self.local_batch = local_batch
        self.seed = seed
        self.mgr = ShardManager(fl_cfg.num_clients, fl_cfg.num_shards,
                                fl_cfg.clients_per_round, seed)
        self._lf = loss_fn(model_cfg)
        self._pf = predict_fn(model_cfg)
        self._build_steps()

    # ------------------------------------------------------------------ jit
    def _build_steps(self):
        lf = self._lf
        opt_init, opt_update = make_optimizer(self.opt)

        def local_train(params, xs, ys, epochs, fisher=None):
            """Minibatch-SGD local training. xs: (n, ...), ys: (n, ...)."""
            bs = self.local_batch
            n = xs.shape[0] // bs * bs
            xb = xs[:n].reshape(-1, bs, *xs.shape[1:])
            yb = ys[:n].reshape(-1, bs, *ys.shape[1:])
            state = opt_init(params)

            def epoch_body(carry, _):
                params, state = carry

                def batch_body(carry, xy):
                    params, state = carry
                    x, y = xy
                    batch = self._make_batch(x, y)
                    grads = jax.grad(lambda p: lf(p, batch)[0])(params)
                    if fisher is not None:
                        grads = fisher_precondition(grads, fisher)
                    params, state = opt_update(params, grads, state)
                    return (params, state), None

                (params, state), _ = jax.lax.scan(batch_body, (params, state),
                                                  (xb, yb))
                return (params, state), None

            (params, _), _ = jax.lax.scan(epoch_body, (params, state), None,
                                          length=epochs)
            return params

        def vmapped_train(params, xs, ys, epochs):
            """Stacked data (M, n, ...), shared initial params -> (M, ...)."""
            return jax.vmap(lambda x, y: local_train(params, x, y, epochs)
                            )(xs, ys)

        def shard_round(params, xs, ys, epochs, payload):
            """One fused FedAvg round for one shard — everything on device:
            vmapped local training, stacked (M,) update norms, FedAvg mean,
            and (optionally) the stacked (M, P) flat parameter matrix for the
            coded store. Returns (new_global, payload, delta_norms)."""
            locals_ = vmapped_train(params, xs, ys, epochs)
            deltas = unlearning.stacked_sub(locals_, params)
            norms = unlearning.stacked_norms(deltas)
            new_global = unlearning.stacked_mean(locals_)
            if payload == "flat":
                out, _ = coding.tree_to_flat_stacked(locals_)
            else:
                out = locals_
            return new_global, out, norms

        def calib_round(params, xs, ys, stored_norms, epochs):
            """One fused SE/FE calibrated-retraining round (eq. 3): vmapped
            retraining + stacked calibration, no per-client host loop."""
            locals_ = vmapped_train(params, xs, ys, epochs)
            deltas = unlearning.stacked_sub(locals_, params)
            return unlearning.calibrate_stacked(params, deltas, stored_norms)

        # vmap over clients: stacked data (M, n, ...), shared initial params
        self._local_train = {}
        self._shard_round = {}
        self._calib_round = {}
        for ep in set([self.fl.local_epochs,
                       max(int(self.fl.local_epochs / self.fl.retrain_ratio), 1)]):
            self._local_train[ep] = jax.jit(
                jax.vmap(lambda p, x, y, e=ep: local_train(p, x, y, e),
                         in_axes=(None, 0, 0)))
            self._local_train[(ep, "fisher")] = jax.jit(
                jax.vmap(lambda p, x, y, f, e=ep: local_train(p, x, y, e, f),
                         in_axes=(None, 0, 0, None)))
            for payload in ("flat", "stacked"):
                self._shard_round[(ep, payload)] = jax.jit(
                    lambda p, x, y, e=ep, pay=payload:
                    shard_round(p, x, y, e, pay))
            self._calib_round[ep] = jax.jit(
                lambda p, x, y, n, e=ep: calib_round(p, x, y, n, e))
        self._stacked_mean = jax.jit(unlearning.stacked_mean)
        self._grad_fn = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))

    def _make_batch(self, x, y):
        if self.task == "image":
            return {"images": x, "labels": y}
        return {"tokens": x, "labels": y}

    def _stack_client_data(self, clients: Sequence[int]):
        n_min = min(self.client_data[c][0].shape[0] for c in clients)
        xs = np.stack([self.client_data[c][0][:n_min] for c in clients])
        ys = np.stack([self.client_data[c][1][:n_min] for c in clients])
        return jnp.asarray(xs), jnp.asarray(ys)

    def _make_store(self, store_kind: str, plan: StagePlan,
                    group_rounds: int = 1, slice_dtype=None):
        if store_kind == "full":
            return FullStore()
        if store_kind == "uncoded":
            return UncodedShardStore({c: s for s, cs in plan.shard_clients.items()
                                      for c in cs})
        scheme = coding.CodingScheme(num_shards=self.fl.num_shards,
                                     num_clients=self.fl.clients_per_round)
        # map slice index -> the stage's participating clients
        return CodedStore(scheme, plan.shard_clients,
                          group_rounds=group_rounds, slice_dtype=slice_dtype)

    # ------------------------------------------------------------- training
    def train_stage(self, store_kind: str = "coded",
                    rounds: Optional[int] = None, engine: str = "fused",
                    encode_group: Optional[int] = None,
                    slice_dtype=None) -> StageRecord:
        """One stage: sample clients, split into shards, G FedAvg rounds per
        shard, storing intermediate params in the requested store.

        ``engine="fused"`` (default) keeps everything stacked/device-resident
        (see module docstring); ``engine="legacy"`` is the seed per-client
        path, kept for A/B benchmarking. ``encode_group`` batches that many
        rounds per coded encode (default: all G in one). ``slice_dtype``
        optionally stores coded slices in e.g. bf16.
        """
        if engine == "legacy":
            if encode_group is not None or slice_dtype is not None:
                raise ValueError("encode_group/slice_dtype need engine='fused'")
            return self._train_stage_legacy(store_kind, rounds)
        if engine != "fused":
            raise ValueError(f"unknown engine {engine!r}; use 'fused' or 'legacy'")
        fl = self.fl
        g_rounds = rounds or fl.global_rounds
        plan = self.mgr.new_stage()
        rng = jax.random.key(self.seed + plan.stage)
        w0 = init_params(self.cfg, rng)
        store = self._make_store(store_kind, plan,
                                 group_rounds=encode_group or g_rounds,
                                 slice_dtype=slice_dtype)
        coded = isinstance(store, CodedStore)
        step = self._shard_round[(fl.local_epochs,
                                  "flat" if coded else "stacked")]
        row_spec = coding.tree_to_flat(w0)[1] if coded else None

        # round-major loop: all shards advance one round, then the round's
        # parameters are stored together (the coded store encodes ACROSS the
        # S shards — eq. 5/6 mixes one round's shard vectors).
        shards = sorted(plan.shard_clients)
        ws = {s: w0 for s in shards}
        data = {s: self._stack_client_data(plan.shard_clients[s])
                for s in shards}
        round_globals = {s: [] for s in shards}
        norms_dev = {s: [] for s in shards}
        for g in range(g_rounds):
            payload = {}
            for s in shards:
                round_globals[s].append(ws[s])
                xs, ys = data[s]
                ws[s], payload[s], nrm = step(ws[s], xs, ys)
                norms_dev[s].append(nrm)
            if coded:
                store.put_round_flat(g, payload, row_spec)
            else:
                store.put_round_stacked(
                    g, {s: (plan.shard_clients[s], payload[s])
                        for s in shards})
        if coded:
            store.flush()
        for s in shards:
            round_globals[s].append(ws[s])
        # ONE host sync for every stored-update norm of the stage —
        # the legacy path pulled S*G*M scalars with float(...)
        norms_host = jax.device_get({s: jnp.stack(norms_dev[s])
                                     for s in shards})
        norms = {}
        for s in shards:
            arr = np.asarray(norms_host[s])            # (G, M)
            for g in range(g_rounds):
                for i, c in enumerate(plan.shard_clients[s]):
                    norms[(s, g, c)] = float(arr[g, i])
        return StageRecord(plan, dict(ws), round_globals, store,
                           history_norms=norms)

    def _train_stage_legacy(self, store_kind: str = "coded",
                            rounds: Optional[int] = None) -> StageRecord:
        """Seed per-client round loop (unstack + per-scalar norm pulls +
        per-round tree flatten/encode) — kept for A/B comparison."""
        fl = self.fl
        g_rounds = rounds or fl.global_rounds
        plan = self.mgr.new_stage()
        rng = jax.random.key(self.seed + plan.stage)
        w0 = init_params(self.cfg, rng)
        store = self._make_store(store_kind, plan)
        ws = {s: w0 for s in plan.shard_clients}
        data = {s: self._stack_client_data(cs)
                for s, cs in plan.shard_clients.items()}
        round_globals = {s: [] for s in plan.shard_clients}
        norms = {}
        for g in range(g_rounds):
            all_params = {}
            for s, clients in plan.shard_clients.items():
                round_globals[s].append(ws[s])
                xs, ys = data[s]
                locals_ = self._local_train[fl.local_epochs](ws[s], xs, ys)
                per_client = [jax.tree.map(lambda a, i=i: a[i], locals_)
                              for i in range(len(clients))]
                all_params.update(dict(zip(clients, per_client)))
                for i, c in enumerate(clients):
                    d = unlearning.tree_sub(per_client[i], ws[s])
                    norms[(s, g, c)] = float(unlearning.tree_norm(d))
                ws[s] = unlearning.tree_mean(per_client)
            store.put_round(g, all_params)
        for s in plan.shard_clients:
            round_globals[s].append(ws[s])
        return StageRecord(plan, dict(ws), round_globals, store,
                           history_norms=norms)

    # ----------------------------------------------------------- unlearning
    def unlearn(self, framework: str, record: StageRecord,
                requests: Sequence[int], rounds: Optional[int] = None,
                available: Optional[Sequence[int]] = None,
                corrupt: Optional[np.ndarray] = None) -> UnlearnResult:
        fl = self.fl
        g_rounds = rounds or fl.global_rounds
        plan = record.plan
        t0 = time.perf_counter()
        cost = 0.0
        impacted = sorted(self.mgr.impacted_shards(plan, requests))
        retrain_ep = max(int(fl.local_epochs / fl.retrain_ratio), 1)

        def stored_norms(shard_of, retained, n_rounds):
            """(G', M) historical norms, moved to device once."""
            return jnp.asarray(
                [[record.history_norms[(shard_of(c), g, c)] for c in retained]
                 for g in range(n_rounds)], jnp.float32)

        if framework in ("SE", "SE-uncoded"):
            models = dict(record.shard_models)
            for s in impacted:
                retained = self.mgr.retained(plan, s, requests)
                if not retained:
                    continue
                xs, ys = self._stack_client_data(retained)
                # preparation: reconstruct stored round-0 locals, eq (2)
                stored0 = self._stored_round(record, s, 0, available, corrupt)
                w = unlearning.prepare_initial_model(
                    [stored0[c] for c in retained])
                # calibrated retraining, eq (3) — fused stacked rounds
                n_r = min(g_rounds, len(record.round_globals[s]) - 1)
                nmat = stored_norms(lambda c, s=s: s, retained, n_r)
                for g in range(n_r):
                    w = self._calib_round[retrain_ep](w, xs, ys, nmat[g])
                    cost += len(retained) * retrain_ep
                models[s] = w
            result_models = models

        elif framework == "FE":
            # FedEraser without sharding: calibrate over ALL retained clients
            retained = [c for c in plan.clients if c not in set(requests)]
            xs, ys = self._stack_client_data(retained)
            stored0 = self._all_stored_round(record, 0, available, corrupt)
            w = unlearning.prepare_initial_model([stored0[c] for c in retained])
            nmat = stored_norms(plan.shard_of, retained, g_rounds)
            for g in range(g_rounds):
                w = self._calib_round[retrain_ep](w, xs, ys, nmat[g])
                cost += len(retained) * retrain_ep
            result_models = {0: w}

        elif framework in ("FR", "RR"):
            retained = [c for c in plan.clients if c not in set(requests)]
            xs, ys = self._stack_client_data(retained)
            w = init_params(self.cfg, jax.random.key(self.seed + 777))
            fisher = None
            ep = fl.local_epochs if framework == "FR" else retrain_ep
            if framework == "RR":
                # estimate the diagonal Fisher on retained data once
                fisher = self._estimate_fisher(w, retained)
            for g in range(g_rounds):
                if framework == "RR":
                    locals_ = self._local_train[(ep, "fisher")](w, xs, ys, fisher)
                else:
                    locals_ = self._local_train[ep](w, xs, ys)
                w = self._stacked_mean(locals_)
                cost += len(retained) * ep
            result_models = {0: w}
        else:
            raise ValueError(framework)

        jax.block_until_ready(jax.tree.leaves(list(result_models.values())[0])[0])
        wall = time.perf_counter() - t0
        stats = getattr(record.store, "stats", None)
        return UnlearnResult(framework, result_models, wall, cost, stats, impacted)

    # ------------------------------------------------------------- helpers
    def _calibrate_with_norms(self, w, new_deltas, stored_norms):
        """Seed per-client calibration loop (host-synced ratio per client) —
        retained as the reference implementation for equivalence tests; the
        live path is the fused ``calib_round`` / ``calibrate_stacked``."""
        m = len(new_deltas)
        out = w
        for nd, sn in zip(new_deltas, stored_norms):
            ratio = sn / max(float(unlearning.tree_norm(nd)), 1e-12)
            out = unlearning.tree_add(out, unlearning.tree_scale(nd, ratio / m))
        return out

    def _stored_round(self, record: StageRecord, shard: int, rnd: int,
                      available=None, corrupt=None) -> Dict[int, object]:
        store = record.store
        if isinstance(store, CodedStore):
            return store.get_shard(rnd, shard, available=available,
                                   corrupt=corrupt)
        return {c: store.get(rnd, c)
                for c in record.plan.shard_clients[shard]}

    def _all_stored_round(self, record: StageRecord, rnd: int,
                          available=None, corrupt=None) -> Dict[int, object]:
        out = {}
        for s in record.plan.shard_clients:
            out.update(self._stored_round(record, s, rnd, available, corrupt))
        return out

    def _estimate_fisher(self, params, clients: Sequence[int], n_batches: int = 4):
        fisher = None
        for i, c in enumerate(clients[:n_batches]):
            x, y = self.client_data[c]
            batch = self._make_batch(jnp.asarray(x[: self.local_batch]),
                                     jnp.asarray(y[: self.local_batch]))
            g = self._grad_fn(params, batch)
            fisher = diag_fisher(fisher, g, i)
        return fisher

    # ------------------------------------------------------------- evaluate
    def evaluate(self, models: Dict[int, object], xs: np.ndarray,
                 ys: np.ndarray, batch: int = 200) -> Dict[str, float]:
        """Ensemble evaluation: mean logits across shard models (SISA-style)."""
        total, correct, loss_sum = 0, 0, 0.0
        batch = min(batch, len(xs))
        for i in range(0, len(xs) - batch + 1, batch):
            x = jnp.asarray(xs[i:i + batch])
            y = jnp.asarray(ys[i:i + batch])
            b = self._make_batch(x, y)
            logits = None
            for m in models.values():
                lg = self._pf(m, b)
                logits = lg if logits is None else logits + lg
            logits = logits / len(models)
            if self.task == "image":
                correct += int((logits.argmax(-1) == y).sum())
                ll = jax.nn.log_softmax(logits.astype(jnp.float32))
                loss_sum += float(-jnp.take_along_axis(
                    ll, y[:, None], axis=-1).sum())
                total += int(y.shape[0])
            else:
                ll = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                gold = jnp.take_along_axis(ll, y[..., None], axis=-1)[..., 0]
                loss_sum += float(-gold.sum())
                correct += int((logits.argmax(-1) == y).sum())
                total += int(np.prod(y.shape))
        return {"acc": correct / max(total, 1), "loss": loss_sum / max(total, 1)}
