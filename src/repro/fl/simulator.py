"""CPU-scale federated learning + unlearning simulator (paper Sec 5).

Runs the paper's experimental protocol end-to-end on any registered task ×
model family (``repro.fl.tasks`` / ``repro.fl.families`` — the paper's CNN
classifier and NanoGPT, plus mamba / rwkv6 / moe): C clients, a sampled
subset per stage split into S isolated shards, FedAvg within shards,
intermediate-parameter storage (full / uncoded-shard / coded), and the four
unlearning frameworks (FR / FE / RR / SE).  Task-shaped behavior (batch
construction, per-example label counts, eval metrics) is delegated to the
``TaskSpec``.

The simulator is the *engine room*: it owns the client data, the jitted
training/calibration steps, and evaluation.  Orchestration lives in
``repro.fl.experiment``:

* ``experiment.train_stage(sim, ...)`` — one stage against a registered
  parameter store (``STORES``; ``full`` / ``uncoded`` / ``coded``).
* ``experiment.run_unlearn(sim, framework, ...)`` — dispatch to a registered
  unlearning framework (``FRAMEWORKS``; ``SE`` / ``FE`` / ``FR`` / ``RR``).
* ``experiment.FederatedSession`` — K stages with a scheduled stream of
  unlearning requests (the paper's cross-stage isolation).

``FLSimulator.train_stage`` / ``FLSimulator.unlearn`` remain as deprecated
thin shims over those entry points.

Round engines
-------------
Three selectable engines cover the dispatch-count spectrum
(``train_stage(..., engine=...)``; see ``repro.fl.experiment.stage``):

* ``engine="stage"`` — the whole-stage superfusion: stage data is stacked to
  ``(S, M, n, ...)``, ``shard_round`` is ``vmap``-ed over the shard axis and
  ``lax.scan``-ed over the G rounds, so ONE jitted dispatch produces the
  entire stage — the ``(G+1, S, ...)`` round globals, the ``(G, S, M)``
  update norms, and (for the coded store) the coded slices themselves: the
  ``(C, S)`` Lagrange encode matrix is applied to the ``(G, S, M*P)`` flat
  history via einsum *inside the same XLA program*
  (``coding.encode_rounds``), eliminating the separate encode dispatch.
  Ragged stages (unequal clients or sample counts per shard) degrade
  gracefully to the per-shard fused path.
* ``engine="fused"`` — one jitted ``shard_round`` per (shard, round): vmapped
  local training, FedAvg mean, the per-client update norms as one (M,)
  reduction, and the stacked (M, P) flat parameter matrix
  (``coding.tree_to_flat_stacked``) all in one program; the coded store
  defers the Lagrange encode so G rounds batch into a single coded matmul.
  G·S + 1 dispatches per stage.
* ``engine="legacy"`` — the seed per-client path (unstack, per-scalar norm
  pulls, per-round flatten+encode), kept for A/B benchmarking
  (``benchmarks/fig6_round_engine.py``) and equivalence tests
  (``tests/test_round_engine.py``).

SE/FE calibrated retraining (eq. 3) runs through ``calib_round`` — vmapped
retraining plus ``unlearning.calibrate_stacked`` fused in one jit — and, when
several shards retrain together (batched unlearning requests), through the
``calib_stage`` program: the impacted shards vmapped together and the G'
calibration rounds scanned, one dispatch for the whole retraining pass.
"""
from __future__ import annotations

import json
import warnings
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.stores.store import StoreStats, make_store
from repro.configs.base import FLConfig, ModelConfig, OptimizerConfig
from repro.core import coding, unlearning
from repro.core.sharding import ShardManager, StagePlan
from repro.fl.tasks import resolve_task
from repro.models import loss_fn, predict_fn
from repro.optim import make_optimizer
from repro.optim.fisher import diag_fisher, fisher_precondition


class StackedRoundGlobals:
    """List-like view of one shard's per-round global models, backed by the
    stage program's stacked ``(G, S, ...)`` output — length G+1 like the
    materialized per-shard lists, but each element is sliced out of the
    stacked buffers only on access (the stage engine dispatches nothing for
    bookkeeping it never reads)."""

    def __init__(self, round_inputs, final, shard_index: int):
        self._inputs = round_inputs               # (G, S, ...) stacked tree
        self._final = final                       # (S, ...) stacked tree
        self._idx = shard_index
        self._len = int(jax.tree.leaves(round_inputs)[0].shape[0]) + 1

    def __len__(self) -> int:
        return self._len

    def __getitem__(self, g):
        if isinstance(g, slice):
            return [self[i] for i in range(*g.indices(self._len))]
        if g < 0:
            g += self._len
        if not 0 <= g < self._len:
            raise IndexError(g)
        if g == self._len - 1:
            return jax.tree.map(lambda a: a[self._idx], self._final)
        return jax.tree.map(lambda a, g=g: a[g, self._idx], self._inputs)

    def __iter__(self):
        return (self[i] for i in range(self._len))


@dataclass
class StageRecord:
    plan: StagePlan
    shard_models: Dict[int, object]               # final per-shard globals
    round_globals: Dict[int, object]              # shard -> [w^g inputs],
    # len G+1 (a list, or a lazy StackedRoundGlobals view for engine="stage")
    store: object                                 # parameter store
    history_norms: Dict[Tuple[int, int, int], float] = field(default_factory=dict)
    # (shard, round, client) -> ||delta|| of the stored update


@dataclass
class UnlearnResult:
    framework: str
    models: Dict[int, object]        # shard -> unlearned model (single: {0: w})
    wall_time: float
    cost_units: float                # client-epochs of retraining
    store_stats: Optional[StoreStats]
    impacted_shards: Sequence[int]
    request_id: str = ""             # stable id of the request that produced it

    def to_dict(self) -> dict:
        """Machine-readable summary (models excluded — they are pytrees)."""
        return {
            "request_id": self.request_id,
            "framework": self.framework,
            "wall_time_s": self.wall_time,
            "cost_units": self.cost_units,
            "impacted_shards": [int(s) for s in self.impacted_shards],
            "num_models": len(self.models),
            "store_stats": (self.store_stats.to_dict()
                            if self.store_stats is not None else None),
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)


@dataclass(frozen=True)
class PredictInterface:
    """The simulator's public evaluation surface.

    Everything an external evaluator (the MIA attack, canary probes,
    benchmarks) needs to score models without reaching into ``FLSimulator``
    internals: the pure ``predict(model, batch) -> logits`` function, the
    task's batch constructor, and the ``TaskSpec`` itself (which owns metric
    and MIA-feature shapes).  Obtained via ``FLSimulator.predict_interface``.
    """
    predict: Callable
    make_batch: Callable
    task: object                       # the simulator's TaskSpec instance

    def ensemble_logits(self, models: Dict[int, object], x, y):
        """Mean float32 logits of a model ensemble on one batch."""
        batch = self.make_batch(jnp.asarray(x), jnp.asarray(y))
        logits = None
        for m in models.values():
            lg = self.predict(m, batch)
            logits = lg if logits is None else logits + lg
        return (logits / len(models)).astype(jnp.float32)


class FLSimulator:
    def __init__(self, model_cfg: ModelConfig, fl_cfg: FLConfig,
                 client_data: Dict[int, Tuple[np.ndarray, np.ndarray]],
                 task, opt_cfg: Optional[OptimizerConfig] = None,
                 local_batch: int = 20, seed: int = 0):
        self.cfg = model_cfg
        self.fl = fl_cfg
        # a registered TaskSpec (or its name; "image"/"lm" resolve as the
        # legacy aliases of classification/generation)
        self.task_spec = resolve_task(task)
        self.task = self.task_spec.name
        self.opt = opt_cfg or OptimizerConfig(name="sgdm", lr=0.05, grad_clip=0.0)
        self.client_data = client_data
        self.local_batch = local_batch
        self.seed = seed
        self.mgr = ShardManager(fl_cfg.num_clients, fl_cfg.num_shards,
                                fl_cfg.clients_per_round, seed)
        self._lf = loss_fn(model_cfg)
        self._pf = predict_fn(model_cfg)
        self._build_steps()

    # ------------------------------------------------------------------ jit
    def _build_steps(self):
        lf = self._lf
        opt_init, opt_update = make_optimizer(self.opt)

        def local_train(params, xs, ys, epochs, fisher=None):
            """Minibatch-SGD local training. xs: (n, ...), ys: (n, ...)."""
            bs = self.local_batch
            n = xs.shape[0] // bs * bs
            xb = xs[:n].reshape(-1, bs, *xs.shape[1:])
            yb = ys[:n].reshape(-1, bs, *ys.shape[1:])
            state = opt_init(params)

            def epoch_body(carry, _):
                params, state = carry

                def batch_body(carry, xy):
                    params, state = carry
                    x, y = xy
                    batch = self._make_batch(x, y)
                    grads = jax.grad(lambda p: lf(p, batch)[0])(params)
                    if fisher is not None:
                        grads = fisher_precondition(grads, fisher)
                    params, state = opt_update(params, grads, state)
                    return (params, state), None

                (params, state), _ = jax.lax.scan(batch_body, (params, state),
                                                  (xb, yb))
                return (params, state), None

            (params, _), _ = jax.lax.scan(epoch_body, (params, state), None,
                                          length=epochs)
            return params

        def vmapped_train(params, xs, ys, epochs):
            """Stacked data (M, n, ...), shared initial params -> (M, ...)."""
            return jax.vmap(lambda x, y: local_train(params, x, y, epochs)
                            )(xs, ys)

        def shard_round(params, xs, ys, epochs, payload):
            """One fused FedAvg round for one shard — everything on device:
            vmapped local training, stacked (M,) update norms, FedAvg mean,
            and (optionally) the stacked (M, P) flat parameter matrix for the
            coded store. Returns (new_global, payload, delta_norms)."""
            locals_ = vmapped_train(params, xs, ys, epochs)
            deltas = unlearning.stacked_sub(locals_, params)
            norms = unlearning.stacked_norms(deltas)
            new_global = unlearning.stacked_mean(locals_)
            if payload == "flat":
                out, _ = coding.tree_to_flat_stacked(locals_)
            else:
                out = locals_
            return new_global, out, norms

        def calib_round(params, xs, ys, stored_norms, epochs):
            """One fused SE/FE calibrated-retraining round (eq. 3): vmapped
            retraining + stacked calibration, no per-client host loop."""
            locals_ = vmapped_train(params, xs, ys, epochs)
            deltas = unlearning.stacked_sub(locals_, params)
            return unlearning.calibrate_stacked(params, deltas, stored_norms)

        def calib_stage(ws, xs, ys, nmats, epochs):
            """The whole calibrated-retraining pass of a batch of impacted
            shards in ONE program: ``calib_round`` vmapped over the K shards,
            ``lax.scan``-ed over the G' rounds.  ws: stacked (K, ...) initial
            models; xs/ys: (K, M', n, ...); nmats: (G', K, M') stored norms."""
            def body(w, nrow):
                w2 = jax.vmap(lambda wi, x, y, n:
                              calib_round(wi, x, y, n, epochs))(w, xs, ys, nrow)
                return w2, None
            out, _ = jax.lax.scan(body, ws, nmats)
            return out

        # vmap over clients: stacked data (M, n, ...), shared initial params
        self._local_train = {}
        self._shard_round = {}
        self._calib_round = {}
        self._calib_stage = {}
        for ep in set([self.fl.local_epochs,
                       max(int(self.fl.local_epochs / self.fl.retrain_ratio), 1)]):
            self._local_train[ep] = jax.jit(
                jax.vmap(lambda p, x, y, e=ep: local_train(p, x, y, e),
                         in_axes=(None, 0, 0)))
            self._local_train[(ep, "fisher")] = jax.jit(
                jax.vmap(lambda p, x, y, f, e=ep: local_train(p, x, y, e, f),
                         in_axes=(None, 0, 0, None)))
            for payload in ("flat", "stacked"):
                self._shard_round[(ep, payload)] = jax.jit(
                    lambda p, x, y, e=ep, pay=payload:
                    shard_round(p, x, y, e, pay))
            self._calib_round[ep] = jax.jit(
                lambda p, x, y, n, e=ep: calib_round(p, x, y, n, e))
            self._calib_stage[ep] = jax.jit(
                lambda w, x, y, n, e=ep: calib_stage(w, x, y, n, e))
        self._stacked_mean = jax.jit(unlearning.stacked_mean)
        self._grad_fn = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))
        self._shard_round_fn = shard_round      # unjitted: stage-program body
        self._stage_programs = {}               # (ep, kind, G, enc?, ...) -> jit
        self._eval_stats = jax.jit(self._eval_stats_fn)

    def _get_stage_program(self, epochs: int, kind: str, g_rounds: int,
                           encode: bool, out_dtype=None,
                           use_kernel: bool = False):
        """Build (and cache) the whole-stage program for ``engine="stage"``:
        ``shard_round`` vmapped over the S shards and scanned over the G
        rounds, with the coded store's Lagrange encode fused into the same
        XLA program (``coding.encode_rounds``) when ``encode``.

        Returns a jitted ``program(w0, xs, ys[, enc])`` producing
        ``(final (S, ...), round_inputs (G, S, ...), history, norms (G, S, M))``
        where ``history`` is the coded ``(G, C, M*P)`` slices (``encode``),
        the flat ``(G, S, M, P)`` matrices (``kind == "flat"``), or the
        stacked per-round trees (``kind == "stacked"``).
        """
        key = (epochs, kind, g_rounds, encode, out_dtype, use_kernel)
        prog = self._stage_programs.get(key)
        if prog is not None:
            return prog
        shard_round = self._shard_round_fn

        def stage_body(w0, xs, ys):
            s = xs.shape[0]
            ws0 = jax.tree.map(
                lambda a: jnp.broadcast_to(a.astype(jnp.float32),
                                           (s,) + a.shape), w0)

            def body(ws, _):
                new_ws, out, norms = jax.vmap(
                    lambda p, x, y: shard_round(p, x, y, epochs, kind)
                )(ws, xs, ys)
                return new_ws, (ws, out, norms)

            final, (round_in, hist, norms) = jax.lax.scan(
                body, ws0, None, length=g_rounds)
            return final, round_in, hist, norms

        if encode:
            def program(w0, xs, ys, enc):
                final, round_in, hist, norms = stage_body(w0, xs, ys)
                g, s = hist.shape[:2]
                coded = coding.encode_rounds(enc, hist.reshape(g, s, -1),
                                             use_kernel=use_kernel,
                                             out_dtype=out_dtype)
                return final, round_in, coded, norms
        else:
            def program(w0, xs, ys):
                return stage_body(w0, xs, ys)
        prog = jax.jit(program)
        self._stage_programs[key] = prog
        return prog

    def _get_retrain_program(self, epochs: int, g_rounds: int):
        """Lean whole-stage program for from-scratch retraining (the
        exact-unlearning oracle, ``repro.verify.oracle``): the stage engine's
        ``shard_round`` body vmapped over a stacked ``(K, M, n, ...)`` shard
        batch and scanned over the G rounds, returning ONLY the final
        ``(K, ...)`` models — round history, update norms, and the store
        encode are dead outputs XLA eliminates, so the oracle pays exactly
        one dispatch and no bookkeeping memory."""
        key = ("retrain", epochs, g_rounds)
        prog = self._stage_programs.get(key)
        if prog is not None:
            return prog
        shard_round = self._shard_round_fn

        def program(w0, xs, ys):
            k = xs.shape[0]
            ws0 = jax.tree.map(
                lambda a: jnp.broadcast_to(a.astype(jnp.float32),
                                           (k,) + a.shape), w0)

            def body(ws, _):
                new_ws, _out, _norms = jax.vmap(
                    lambda p, x, y: shard_round(p, x, y, epochs, "stacked")
                )(ws, xs, ys)
                return new_ws, None

            final, _ = jax.lax.scan(body, ws0, None, length=g_rounds)
            return final

        prog = jax.jit(program)
        self._stage_programs[key] = prog
        return prog

    def _make_batch(self, x, y):
        return self.task_spec.make_batch(x, y)

    def predict_interface(self) -> PredictInterface:
        """Public evaluation surface (see ``PredictInterface``) — the stable
        API benchmarks and the verification suite evaluate through, instead
        of the private ``_pf`` / ``_make_batch`` attributes."""
        return PredictInterface(self._pf, self.task_spec.make_batch,
                                self.task_spec)

    def _stack_client_data(self, clients: Sequence[int]):
        n_min = min(self.client_data[c][0].shape[0] for c in clients)
        xs = np.stack([self.client_data[c][0][:n_min] for c in clients])
        ys = np.stack([self.client_data[c][1][:n_min] for c in clients])
        return jnp.asarray(xs), jnp.asarray(ys)

    def _make_store(self, store_kind: str, plan: StagePlan,
                    group_rounds: int = 1, slice_dtype=None, **store_options):
        """Build a registered parameter store for one stage (``STORES``).
        ``store_options`` are factory-specific knobs passed through verbatim
        (e.g. the tiered store's ``hot_bytes``/``eviction``)."""
        return make_store(store_kind, plan.shard_clients,
                          num_shards=self.fl.num_shards,
                          num_clients=self.fl.clients_per_round,
                          group_rounds=group_rounds, slice_dtype=slice_dtype,
                          **store_options)

    # --------------------------------------------------- deprecated shims
    def train_stage(self, store_kind: str = "coded",
                    rounds: Optional[int] = None, engine: str = "fused",
                    encode_group: Optional[int] = None,
                    slice_dtype=None) -> StageRecord:
        """Deprecated shim over ``repro.fl.experiment.train_stage``."""
        warnings.warn(
            "FLSimulator.train_stage is deprecated; use "
            "repro.fl.experiment.train_stage(sim, ...) or FederatedSession",
            DeprecationWarning, stacklevel=2)
        from repro.fl.experiment.stage import train_stage
        return train_stage(self, store_kind=store_kind, rounds=rounds,
                           engine=engine, encode_group=encode_group,
                           slice_dtype=slice_dtype)

    def unlearn(self, framework: str, record: StageRecord,
                requests: Sequence[int], rounds: Optional[int] = None,
                available: Optional[Sequence[int]] = None,
                corrupt: Optional[np.ndarray] = None) -> UnlearnResult:
        """Deprecated shim over ``repro.fl.experiment.run_unlearn``."""
        warnings.warn(
            "FLSimulator.unlearn is deprecated; use "
            "repro.fl.experiment.run_unlearn(sim, ...) or FederatedSession",
            DeprecationWarning, stacklevel=2)
        from repro.fl.experiment.frameworks import run_unlearn
        return run_unlearn(self, framework, record, requests, rounds=rounds,
                           available=available, corrupt=corrupt)

    # ------------------------------------------------------------- helpers
    def _calibrate_with_norms(self, w, new_deltas, stored_norms):
        """Seed per-client calibration loop (host-synced ratio per client) —
        retained as the reference implementation for equivalence tests; the
        live path is the fused ``calib_round`` / ``calibrate_stacked``."""
        m = len(new_deltas)
        out = w
        for nd, sn in zip(new_deltas, stored_norms):
            ratio = sn / max(float(unlearning.tree_norm(nd)), 1e-12)
            out = unlearning.tree_add(out, unlearning.tree_scale(nd, ratio / m))
        return out

    def _estimate_fisher(self, params, clients: Sequence[int], n_batches: int = 4):
        fisher = None
        for i, c in enumerate(clients[:n_batches]):
            x, y = self.client_data[c]
            batch = self._make_batch(jnp.asarray(x[: self.local_batch]),
                                     jnp.asarray(y[: self.local_batch]))
            g = self._grad_fn(params, batch)
            fisher = diag_fisher(fisher, g, i)
        return fisher

    # ------------------------------------------------------------- evaluate
    def _eval_stats_fn(self, stacked_models, xb, yb):
        """One jitted pass over all eval batches: ``predict_fn`` vmapped over
        the stacked (K, ...) ensemble, ``lax.scan`` over the (B, batch, ...)
        batches, correct/loss accumulated on device."""
        def body(carry, xy):
            x, y = xy
            b = self._make_batch(x, y)
            logits = jax.vmap(lambda m: self._pf(m, b))(stacked_models)
            lg = logits.astype(jnp.float32).sum(0) / logits.shape[0]
            ll = jax.nn.log_softmax(lg, -1)
            correct = (lg.argmax(-1) == y).sum()
            loss = -jnp.take_along_axis(ll, y[..., None], axis=-1).sum()
            c, l = carry
            return (c + correct, l + loss), None
        init = (jnp.zeros((), jnp.int32), jnp.zeros((), jnp.float32))
        (correct, loss), _ = jax.lax.scan(body, init, (xb, yb))
        return correct, loss

    def evaluate(self, models: Dict[int, object], xs: np.ndarray,
                 ys: np.ndarray, batch: int = 200) -> Dict[str, float]:
        """Ensemble evaluation: mean logits across shard models (SISA-style).

        The shard models are stacked to one (K, ...) tree and ``predict_fn``
        is vmapped over the ensemble inside a single jitted eval step that
        scans all batches — one host pull per eval instead of one per batch
        per model (the seed loop is kept as ``evaluate_host`` for
        equivalence testing)."""
        batch = min(batch, len(xs))
        nb = len(xs) // batch
        if nb == 0:
            return {"acc": 0.0, "loss": 0.0}
        xb = jnp.asarray(xs[:nb * batch]).reshape(nb, batch, *xs.shape[1:])
        yb = jnp.asarray(ys[:nb * batch]).reshape(nb, batch, *ys.shape[1:])
        stacked = jax.tree.map(lambda *a: jnp.stack(a), *models.values())
        correct, loss = jax.device_get(self._eval_stats(stacked, xb, yb))
        total = nb * batch * self.task_spec.labels_per_example(ys.shape)
        return self.task_spec.eval_metrics(int(correct), float(loss),
                                           max(total, 1))

    def evaluate_host(self, models: Dict[int, object], xs: np.ndarray,
                      ys: np.ndarray, batch: int = 200) -> Dict[str, float]:
        """Seed per-batch-per-model eval loop — reference implementation for
        ``evaluate`` equivalence tests."""
        total, correct, loss_sum = 0, 0, 0.0
        batch = min(batch, len(xs))
        for i in range(0, len(xs) - batch + 1, batch):
            x = jnp.asarray(xs[i:i + batch])
            y = jnp.asarray(ys[i:i + batch])
            b = self._make_batch(x, y)
            logits = None
            for m in models.values():
                lg = self._pf(m, b)
                logits = lg if logits is None else logits + lg
            logits = logits / len(models)
            ll = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            gold = jnp.take_along_axis(ll, y[..., None], axis=-1)[..., 0]
            loss_sum += float(-gold.sum())
            correct += int((logits.argmax(-1) == y).sum())
            total += y.shape[0] * self.task_spec.labels_per_example(y.shape)
        return self.task_spec.eval_metrics(correct, loss_sum, max(total, 1))
