"""CPU-scale federated learning + unlearning simulator (paper Sec 5).

Runs the paper's experimental protocol end-to-end on the paper's own models
(CNN classifier / NanoGPT): C clients, a sampled subset per stage split into S
isolated shards, FedAvg within shards, intermediate-parameter storage
(full / uncoded-shard / coded), and the four unlearning frameworks
(FR / FE / RR / SE).

Client local training is vmapped (clients in a shard train in parallel);
everything is jitted once per (model, batch-shape).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import (CodedStore, FullStore, StoreStats,
                                    UncodedShardStore, tree_bytes)
from repro.configs.base import FLConfig, ModelConfig, OptimizerConfig
from repro.core import coding, unlearning
from repro.core.sharding import ShardManager, StagePlan
from repro.models import init_params, loss_fn, predict_fn
from repro.optim import make_optimizer
from repro.optim.fisher import diag_fisher, fisher_precondition


@dataclass
class StageRecord:
    plan: StagePlan
    shard_models: Dict[int, object]               # final per-shard globals
    round_globals: Dict[int, List[object]]        # shard -> [w^g inputs], len G+1
    store: object                                 # parameter store
    history_norms: Dict[Tuple[int, int, int], float] = field(default_factory=dict)
    # (shard, round, client) -> ||delta|| of the stored update


@dataclass
class UnlearnResult:
    framework: str
    models: Dict[int, object]        # shard -> unlearned model (single: {0: w})
    wall_time: float
    cost_units: float                # client-epochs of retraining
    store_stats: Optional[StoreStats]
    impacted_shards: Sequence[int]


class FLSimulator:
    def __init__(self, model_cfg: ModelConfig, fl_cfg: FLConfig,
                 client_data: Dict[int, Tuple[np.ndarray, np.ndarray]],
                 task: str, opt_cfg: Optional[OptimizerConfig] = None,
                 local_batch: int = 20, seed: int = 0):
        self.cfg = model_cfg
        self.fl = fl_cfg
        self.task = task                      # "image" | "lm"
        self.opt = opt_cfg or OptimizerConfig(name="sgdm", lr=0.05, grad_clip=0.0)
        self.client_data = client_data
        self.local_batch = local_batch
        self.seed = seed
        self.mgr = ShardManager(fl_cfg.num_clients, fl_cfg.num_shards,
                                fl_cfg.clients_per_round, seed)
        self._lf = loss_fn(model_cfg)
        self._pf = predict_fn(model_cfg)
        self._build_steps()

    # ------------------------------------------------------------------ jit
    def _build_steps(self):
        lf = self._lf
        opt_init, opt_update = make_optimizer(self.opt)

        def local_train(params, xs, ys, epochs, fisher=None):
            """Minibatch-SGD local training. xs: (n, ...), ys: (n, ...)."""
            bs = self.local_batch
            n = xs.shape[0] // bs * bs
            xb = xs[:n].reshape(-1, bs, *xs.shape[1:])
            yb = ys[:n].reshape(-1, bs, *ys.shape[1:])
            state = opt_init(params)

            def epoch_body(carry, _):
                params, state = carry

                def batch_body(carry, xy):
                    params, state = carry
                    x, y = xy
                    batch = self._make_batch(x, y)
                    grads = jax.grad(lambda p: lf(p, batch)[0])(params)
                    if fisher is not None:
                        grads = fisher_precondition(grads, fisher)
                    params, state = opt_update(params, grads, state)
                    return (params, state), None

                (params, state), _ = jax.lax.scan(batch_body, (params, state),
                                                  (xb, yb))
                return (params, state), None

            (params, _), _ = jax.lax.scan(epoch_body, (params, state), None,
                                          length=epochs)
            return params

        # vmap over clients: stacked data (M, n, ...), shared initial params
        self._local_train = {}
        for ep in set([self.fl.local_epochs,
                       max(int(self.fl.local_epochs / self.fl.retrain_ratio), 1)]):
            self._local_train[ep] = jax.jit(
                jax.vmap(lambda p, x, y, e=ep: local_train(p, x, y, e),
                         in_axes=(None, 0, 0)))
            self._local_train[(ep, "fisher")] = jax.jit(
                jax.vmap(lambda p, x, y, f, e=ep: local_train(p, x, y, e, f),
                         in_axes=(None, 0, 0, None)))
        self._grad_fn = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))

    def _make_batch(self, x, y):
        if self.task == "image":
            return {"images": x, "labels": y}
        return {"tokens": x, "labels": y}

    def _stack_client_data(self, clients: Sequence[int]):
        n_min = min(self.client_data[c][0].shape[0] for c in clients)
        xs = np.stack([self.client_data[c][0][:n_min] for c in clients])
        ys = np.stack([self.client_data[c][1][:n_min] for c in clients])
        return jnp.asarray(xs), jnp.asarray(ys)

    # ------------------------------------------------------------- training
    def train_stage(self, store_kind: str = "coded",
                    rounds: Optional[int] = None) -> StageRecord:
        """One stage: sample clients, split into shards, G FedAvg rounds per
        shard, storing intermediate params in the requested store."""
        fl = self.fl
        g_rounds = rounds or fl.global_rounds
        plan = self.mgr.new_stage()
        rng = jax.random.key(self.seed + plan.stage)
        w0 = init_params(self.cfg, rng)

        if store_kind == "full":
            store = FullStore()
        elif store_kind == "uncoded":
            store = UncodedShardStore({c: s for s, cs in plan.shard_clients.items()
                                       for c in cs})
        else:
            scheme = coding.CodingScheme(num_shards=fl.num_shards,
                                         num_clients=fl.clients_per_round)
            # map slice index -> the stage's participating clients
            store = CodedStore(scheme, plan.shard_clients)

        # round-major loop: all shards advance one round, then the round's
        # parameters are stored together (the coded store encodes ACROSS the
        # S shards — eq. 5/6 mixes one round's shard vectors).
        ws = {s: w0 for s in plan.shard_clients}
        data = {s: self._stack_client_data(cs)
                for s, cs in plan.shard_clients.items()}
        round_globals = {s: [] for s in plan.shard_clients}
        norms = {}
        for g in range(g_rounds):
            all_params = {}
            for s, clients in plan.shard_clients.items():
                round_globals[s].append(ws[s])
                xs, ys = data[s]
                locals_ = self._local_train[fl.local_epochs](ws[s], xs, ys)
                per_client = [jax.tree.map(lambda a, i=i: a[i], locals_)
                              for i in range(len(clients))]
                all_params.update(dict(zip(clients, per_client)))
                for i, c in enumerate(clients):
                    d = unlearning.tree_sub(per_client[i], ws[s])
                    norms[(s, g, c)] = float(unlearning.tree_norm(d))
                ws[s] = unlearning.tree_mean(per_client)
            store.put_round(g, all_params)
        for s in plan.shard_clients:
            round_globals[s].append(ws[s])
        return StageRecord(plan, dict(ws), round_globals, store,
                           history_norms=norms)

    # ----------------------------------------------------------- unlearning
    def unlearn(self, framework: str, record: StageRecord,
                requests: Sequence[int], rounds: Optional[int] = None,
                available: Optional[Sequence[int]] = None,
                corrupt: Optional[np.ndarray] = None) -> UnlearnResult:
        fl = self.fl
        g_rounds = rounds or fl.global_rounds
        plan = record.plan
        t0 = time.perf_counter()
        cost = 0.0
        impacted = sorted(self.mgr.impacted_shards(plan, requests))
        retrain_ep = max(int(fl.local_epochs / fl.retrain_ratio), 1)

        if framework in ("SE", "SE-uncoded"):
            models = dict(record.shard_models)
            for s in impacted:
                retained = self.mgr.retained(plan, s, requests)
                if not retained:
                    continue
                xs, ys = self._stack_client_data(retained)
                # preparation: reconstruct stored round-0 locals, eq (2)
                stored0 = self._stored_round(record, s, 0, available, corrupt)
                w = unlearning.prepare_initial_model(
                    [stored0[c] for c in retained])
                # calibrated retraining, eq (3)
                for g in range(min(g_rounds, len(record.round_globals[s]) - 1)):
                    locals_ = self._local_train[retrain_ep](w, xs, ys)
                    new_deltas = [unlearning.tree_sub(
                        jax.tree.map(lambda a, i=i: a[i], locals_), w)
                        for i in range(len(retained))]
                    stored_norms = [record.history_norms[(s, g, c)]
                                    for c in retained]
                    w = self._calibrate_with_norms(w, new_deltas, stored_norms)
                    cost += len(retained) * retrain_ep
                models[s] = w
            result_models = models

        elif framework == "FE":
            # FedEraser without sharding: calibrate over ALL retained clients
            retained = [c for c in plan.clients if c not in set(requests)]
            xs, ys = self._stack_client_data(retained)
            stored0 = self._all_stored_round(record, 0, available, corrupt)
            w = unlearning.prepare_initial_model([stored0[c] for c in retained])
            for g in range(g_rounds):
                locals_ = self._local_train[retrain_ep](w, xs, ys)
                new_deltas = [unlearning.tree_sub(
                    jax.tree.map(lambda a, i=i: a[i], locals_), w)
                    for i in range(len(retained))]
                stored_norms = [record.history_norms[(plan.shard_of(c), g, c)]
                                for c in retained]
                w = self._calibrate_with_norms(w, new_deltas, stored_norms)
                cost += len(retained) * retrain_ep
            result_models = {0: w}

        elif framework in ("FR", "RR"):
            retained = [c for c in plan.clients if c not in set(requests)]
            xs, ys = self._stack_client_data(retained)
            w = init_params(self.cfg, jax.random.key(self.seed + 777))
            fisher = None
            ep = fl.local_epochs if framework == "FR" else retrain_ep
            if framework == "RR":
                # estimate the diagonal Fisher on retained data once
                fisher = self._estimate_fisher(w, retained)
            for g in range(g_rounds):
                if framework == "RR":
                    locals_ = self._local_train[(ep, "fisher")](w, xs, ys, fisher)
                else:
                    locals_ = self._local_train[ep](w, xs, ys)
                per_client = [jax.tree.map(lambda a, i=i: a[i], locals_)
                              for i in range(len(retained))]
                w = unlearning.tree_mean(per_client)
                cost += len(retained) * ep
            result_models = {0: w}
        else:
            raise ValueError(framework)

        jax.block_until_ready(jax.tree.leaves(list(result_models.values())[0])[0])
        wall = time.perf_counter() - t0
        stats = getattr(record.store, "stats", None)
        return UnlearnResult(framework, result_models, wall, cost, stats, impacted)

    # ------------------------------------------------------------- helpers
    def _calibrate_with_norms(self, w, new_deltas, stored_norms):
        m = len(new_deltas)
        out = w
        for nd, sn in zip(new_deltas, stored_norms):
            ratio = sn / max(float(unlearning.tree_norm(nd)), 1e-12)
            out = unlearning.tree_add(out, unlearning.tree_scale(nd, ratio / m))
        return out

    def _stored_round(self, record: StageRecord, shard: int, rnd: int,
                      available=None, corrupt=None) -> Dict[int, object]:
        store = record.store
        if isinstance(store, CodedStore):
            return store.get_shard(rnd, shard, available=available,
                                   corrupt=corrupt)
        return {c: store.get(rnd, c)
                for c in record.plan.shard_clients[shard]}

    def _all_stored_round(self, record: StageRecord, rnd: int,
                          available=None, corrupt=None) -> Dict[int, object]:
        out = {}
        for s in record.plan.shard_clients:
            out.update(self._stored_round(record, s, rnd, available, corrupt))
        return out

    def _estimate_fisher(self, params, clients: Sequence[int], n_batches: int = 4):
        fisher = None
        for i, c in enumerate(clients[:n_batches]):
            x, y = self.client_data[c]
            batch = self._make_batch(jnp.asarray(x[: self.local_batch]),
                                     jnp.asarray(y[: self.local_batch]))
            g = self._grad_fn(params, batch)
            fisher = diag_fisher(fisher, g, i)
        return fisher

    # ------------------------------------------------------------- evaluate
    def evaluate(self, models: Dict[int, object], xs: np.ndarray,
                 ys: np.ndarray, batch: int = 200) -> Dict[str, float]:
        """Ensemble evaluation: mean logits across shard models (SISA-style)."""
        total, correct, loss_sum = 0, 0, 0.0
        batch = min(batch, len(xs))
        for i in range(0, len(xs) - batch + 1, batch):
            x = jnp.asarray(xs[i:i + batch])
            y = jnp.asarray(ys[i:i + batch])
            b = self._make_batch(x, y)
            logits = None
            for m in models.values():
                lg = self._pf(m, b)
                logits = lg if logits is None else logits + lg
            logits = logits / len(models)
            if self.task == "image":
                correct += int((logits.argmax(-1) == y).sum())
                ll = jax.nn.log_softmax(logits.astype(jnp.float32))
                loss_sum += float(-jnp.take_along_axis(
                    ll, y[:, None], axis=-1).sum())
                total += int(y.shape[0])
            else:
                ll = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
                gold = jnp.take_along_axis(ll, y[..., None], axis=-1)[..., 0]
                loss_sum += float(-gold.sum())
                correct += int((logits.argmax(-1) == y).sum())
                total += int(np.prod(y.shape))
        return {"acc": correct / max(total, 1), "loss": loss_sum / max(total, 1)}
