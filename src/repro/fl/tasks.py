"""Task registry — the learning tasks a federated scenario can run.

A ``TaskSpec`` owns everything task-shaped that used to be ``if task ==
"image"`` string dispatch spread across ``scenario.py`` and ``FLSimulator``:
synthetic data + client partitioning, batch construction, per-example label
counting, and the eval metrics (accuracy for classification; perplexity /
bits-per-char for generation).  Tasks register under one or more names
(``@register_task("classification", "image")`` — the extra names are the
deprecated spellings the shims resolve), mirroring the ``STORES`` /
``FRAMEWORKS`` / ``FAMILIES`` pattern: a third-party task is one subclass +
decorator away from running through ``run_scenario`` → ``FederatedSession``
→ coded store → SE unlearning.
"""
from __future__ import annotations

import math
from typing import Dict, Tuple, Type

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.synthetic import lm_examples, make_char_data, make_image_data


class TaskSpec:
    """Base class for tasks.  Subclass, implement the hooks, and register
    with ``@register_task(name, *aliases)``."""

    name: str = ""
    kind: str = ""              # batch/metric shape family; defaults to name
    default_family: str = ""    # model family used when ScenarioConfig.model=""
    legacy_skew: str = ""       # partitioner the deprecated iid=False maps to
    default_lr: float = 0.05
    default_batch: int = 20

    # ------------------------------------------------------------------ data
    def build_data(self, cfg, model_cfg, partition) -> Tuple[Dict, Tuple]:
        """Synthesize the federation's data: returns ``(clients, test)`` where
        ``clients`` maps client id -> (x, y) arrays and ``test`` is the
        held-out ``(x, y)`` pair.  ``partition(n, labels, num_clients, seed)``
        is the scenario's registered client partitioner."""
        raise NotImplementedError

    # ----------------------------------------------------------------- batch
    def make_batch(self, x, y) -> Dict:
        raise NotImplementedError

    def labels_per_example(self, y_shape) -> int:
        """Number of supervised targets per example row (classification: 1;
        generation: one per sequence position)."""
        raise NotImplementedError

    # --------------------------------------------------------------- metrics
    def eval_metrics(self, correct: int, loss: float,
                     total: int) -> Dict[str, float]:
        return {"acc": correct / max(total, 1), "loss": loss / max(total, 1)}

    # --------------------------------------------- forgetting-verification
    def mia_features(self, logits, y):
        """Per-example membership features ``[nll, max_prob, entropy]`` from
        the (already ensemble-averaged) float32 logits — the attack-feature
        shape is task-owned (classification scores each example; generation
        averages over sequence positions).  Returns an ``(n, 3)`` array
        consumed by ``repro.fl.mia`` and the shadow attack in
        ``repro.verify``."""
        raise NotImplementedError

    def make_canaries(self, model_cfg, like_x, like_y, n: int, seed: int):
        """``n`` seeded memorization-only canary examples, shaped and dtyped
        like the ``(like_x, like_y)`` exemplars: inputs off the task's data
        manifold mapped to random targets, so a model can only score above
        the chance rate by having memorized them (``repro.verify.canary``).
        Returns ``(xs, ys, chance_rate)``."""
        raise NotImplementedError


TASKS: Dict[str, Type[TaskSpec]] = {}


def register_task(*names: str):
    """Class decorator registering a ``TaskSpec`` under ``names`` (the first
    is canonical; the rest are accepted aliases)."""
    if not names:
        raise ValueError("register_task needs at least one name")

    def deco(cls: Type[TaskSpec]) -> Type[TaskSpec]:
        cls.name = names[0]
        if not cls.kind:
            cls.kind = names[0]
        for n in names:
            TASKS[n] = cls
        return cls
    return deco


def get_task(name: str) -> TaskSpec:
    try:
        return TASKS[name]()
    except KeyError:
        raise ValueError(f"unknown task {name!r}; registered: "
                         f"{sorted(TASKS)}") from None


def resolve_task(task) -> TaskSpec:
    """Accept a ``TaskSpec`` instance, class, or registered name."""
    if isinstance(task, TaskSpec):
        return task
    if isinstance(task, type) and issubclass(task, TaskSpec):
        return task()
    return get_task(task)


def _check_parts(parts, num_clients: int, partitioner_desc: str):
    empty = [k for k, idx in enumerate(parts) if len(idx) == 0]
    if len(parts) != num_clients or empty:
        raise ValueError(
            f"partitioner {partitioner_desc} produced "
            f"{len(parts)} partitions with empty clients {empty} for "
            f"{num_clients} clients; increase samples_per_client or soften "
            f"the skew parameters")


# ---------------------------------------------------------------------------
# The paper's two tasks
# ---------------------------------------------------------------------------

@register_task("classification", "image")
class ClassificationTask(TaskSpec):
    """Image classification (the paper's CNN track): class-conditional
    synthetic images, accuracy + mean NLL metrics."""

    default_family = "cnn"
    legacy_skew = "primary-class"
    default_lr = 0.05
    default_batch = 20

    def build_data(self, cfg, model_cfg, partition):
        data = make_image_data(cfg.num_clients * cfg.samples_per_client,
                               image_size=cfg.image_size, seed=cfg.seed,
                               noise=cfg.noise)
        parts = partition(len(data.labels), data.labels, cfg.num_clients,
                          cfg.seed)
        _check_parts(parts, cfg.num_clients, cfg.partitioner)
        clients = {k: (data.images[idx], data.labels[idx])
                   for k, idx in enumerate(parts)}
        test = make_image_data(cfg.test_n, image_size=cfg.image_size,
                               seed=cfg.seed + 999, noise=cfg.noise)
        return clients, (test.images, test.labels)

    def make_batch(self, x, y):
        return {"images": x, "labels": y}

    def labels_per_example(self, y_shape) -> int:
        return 1

    def mia_features(self, logits, y):
        ll = jax.nn.log_softmax(logits, -1)
        nll = -jnp.take_along_axis(ll, y[:, None], -1)[:, 0]
        p = jnp.exp(ll)
        return jnp.stack([nll, p.max(-1), -(p * ll).sum(-1)], axis=1)

    def make_canaries(self, model_cfg, like_x, like_y, n: int, seed: int):
        # high-contrast binary noise images: maximally off the smooth
        # class-prototype manifold, random labels -> chance = 1/num_classes
        rng = np.random.default_rng(seed)
        xs = rng.integers(0, 2, (n,) + like_x.shape[1:]).astype(like_x.dtype)
        ys = rng.integers(0, model_cfg.num_classes, n).astype(like_y.dtype)
        return xs, ys, 1.0 / model_cfg.num_classes


@register_task("generation", "lm")
class GenerationTask(TaskSpec):
    """Next-token generation (the paper's NanoGPT track, now open to every
    LM family): zipfian char stream, perplexity / bits-per-char metrics."""

    default_family = "transformer"
    legacy_skew = "buckets"
    default_lr = 0.3
    default_batch = 10

    def build_data(self, cfg, model_cfg, partition):
        stream = make_char_data(cfg.num_clients * cfg.samples_per_client
                                * cfg.seq_len + cfg.seq_len + 1,
                                vocab_size=model_cfg.vocab_size, seed=cfg.seed)
        toks, labs = lm_examples(stream, cfg.seq_len)
        # generation examples carry no class label -> label-skew partitioners
        # raise their own actionable error
        parts = partition(len(toks), None, cfg.num_clients, cfg.seed)
        _check_parts(parts, cfg.num_clients, cfg.partitioner)
        clients = {k: (toks[idx], labs[idx]) for k, idx in enumerate(parts)}
        test_stream = make_char_data(cfg.test_n * cfg.seq_len + 1,
                                     vocab_size=model_cfg.vocab_size,
                                     seed=cfg.seed + 999)
        return clients, lm_examples(test_stream, cfg.seq_len)

    def make_batch(self, x, y):
        return {"tokens": x, "labels": y}

    def labels_per_example(self, y_shape) -> int:
        return int(np.prod(y_shape[1:]))

    def eval_metrics(self, correct, loss, total):
        nll = loss / max(total, 1)
        return {"acc": correct / max(total, 1), "loss": nll,
                "ppl": float(math.exp(min(nll, 30.0))),
                "bpc": nll / math.log(2.0)}

    def mia_features(self, logits, y):
        # per-sequence means over the position axis
        ll = jax.nn.log_softmax(logits, -1)
        gold = jnp.take_along_axis(ll, y[..., None], -1)[..., 0]
        p = jnp.exp(ll)
        return jnp.stack([-gold.mean(-1), p.max(-1).mean(-1),
                          (-(p * ll).sum(-1)).mean(-1)], axis=1)

    def make_canaries(self, model_cfg, like_x, like_y, n: int, seed: int):
        # random token sequences mapped to random (NOT next-token) targets:
        # no n-gram structure to generalize from, chance = 1/vocab
        rng = np.random.default_rng(seed)
        v = model_cfg.vocab_size
        xs = rng.integers(0, v, (n,) + like_x.shape[1:]).astype(like_x.dtype)
        ys = rng.integers(0, v, (n,) + like_y.shape[1:]).astype(like_y.dtype)
        return xs, ys, 1.0 / v
