"""Pallas TPU kernels for the paper's compute hot-spots.

coded_matmul — Lagrange encode/decode: coefficient matrix x shard-stacked
               parameter blocks, streamed through the MXU (paper eq. 6/7).
calibrate    — fused eq.(3) calibration: weighted delta accumulation in one
               HBM pass instead of M.
window_attn  — sliding-window flash attention with structural block skipping
               (gemma3 local layers; window variants for the dense archs'
               long_500k shape).

All kernels are TARGETED at TPU (pl.pallas_call + BlockSpec VMEM tiling) and
VALIDATED here in interpret mode against the pure-jnp oracles in ref.py.
"""


def on_tpu() -> bool:
    import jax
    return jax.default_backend() == "tpu"
