from repro.kernels.calibrate.ops import calibrate_update  # noqa: F401
