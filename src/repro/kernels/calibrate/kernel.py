"""Pallas TPU kernel: fused calibration accumulate (paper eq. 3).

Naively, w <- w + sum_m c_m * d_m is M+1 HBM passes over P-sized vectors
(398B-scale for jamba). Fused, each P-block is read once for w and once per
delta row *within a single VMEM-resident tile*, and written once:
HBM traffic = (M+1) reads + 1 write of P, with the accumulate on-chip.

The (M, block_p) delta tile and (1, block_p) w tile live in VMEM; the M
coefficients ride along as a (1, M) operand, so the accumulate is a
(1,M)x(M,block_p) MXU matvec fused with the add.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(w_ref, d_ref, c_ref, o_ref):
    acc = jax.lax.dot(c_ref[...], d_ref[...],
                      preferred_element_type=jnp.float32)     # (1, block_p)
    o_ref[...] = w_ref[...] + acc


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def calibrate_kernel(w: jnp.ndarray, deltas: jnp.ndarray, coeffs: jnp.ndarray,
                     *, block_p: int = 8192,
                     interpret: bool = False) -> jnp.ndarray:
    """w: (1, P); deltas: (M, P); coeffs: (1, M). M mult of 8, P of block_p."""
    m, p = deltas.shape
    assert w.shape == (1, p) and coeffs.shape == (1, m)
    grid = (p // block_p,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_p), lambda i: (0, i)),
            pl.BlockSpec((m, block_p), lambda i: (0, i)),
            pl.BlockSpec((1, m), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, p), jnp.float32),
        interpret=interpret,
    )(w.astype(jnp.float32), deltas.astype(jnp.float32),
      coeffs.astype(jnp.float32))
