"""Jit'd wrapper for the fused calibration kernel (+ pytree-level helper)."""
from __future__ import annotations

from typing import Sequence

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.calibrate.kernel import calibrate_kernel


def calibrate_update(w: jnp.ndarray, deltas: jnp.ndarray,
                     coeffs: jnp.ndarray, block_p: int = 8192) -> jnp.ndarray:
    """w: (P,), deltas: (M,P), coeffs: (M,) -> (P,) = w + coeffs @ deltas."""
    p = w.shape[0]
    m = deltas.shape[0]
    block_p = min(block_p, max(128, ((p + 127) // 128) * 128))
    pad_p = (-p) % block_p
    pad_m = (-m) % 8
    wp = jnp.pad(w, (0, pad_p))[None]
    dp = jnp.pad(deltas, ((0, pad_m), (0, pad_p)))
    cp = jnp.pad(coeffs, (0, pad_m))[None]
    out = calibrate_kernel(wp, dp, cp, block_p=block_p, interpret=not on_tpu())
    return out[0, :p]
