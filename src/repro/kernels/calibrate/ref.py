"""Pure-jnp oracle for the fused eq.(3) calibration update."""
import jax.numpy as jnp


def calibrate_update_ref(w: jnp.ndarray, deltas: jnp.ndarray,
                         coeffs: jnp.ndarray) -> jnp.ndarray:
    """w: (P,) current unlearned global; deltas: (M, P) retrained client
    updates; coeffs: (M,) = ||w^g_m|| / (M * ||w'^{g'}_m||) — eq. (3).

    Returns w + coeffs @ deltas.
    """
    return (w.astype(jnp.float32)
            + coeffs.astype(jnp.float32) @ deltas.astype(jnp.float32))
