from repro.kernels.coded_matmul.ops import (coded_encode_decode,  # noqa: F401
                                            coded_matmul)
