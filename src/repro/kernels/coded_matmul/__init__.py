from repro.kernels.coded_matmul.ops import coded_matmul  # noqa: F401
