"""Pallas TPU kernel: coefficient-matrix x parameter-block matmul.

The parameter dimension P (up to ~4e11 elements for jamba-398B) is tiled into
VMEM-resident blocks; the (C, S) coefficient matrix is tiny and stays resident
across the whole grid. Each grid step computes one (C, block_p) output tile on
the MXU. Blocks are 128-aligned on the lane dimension; C and S are padded to
the f32 sublane tile (8) by the ops wrapper.

VMEM working set per step = (C*S + S*block_p + C*block_p) * 4B
  e.g. C=128, S=8, block_p=4096: ~2.2 MiB — well inside the ~16 MiB/core VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(coeff_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot(
        coeff_ref[...], w_ref[...],
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def coded_matmul_kernel(coeff: jnp.ndarray, w: jnp.ndarray, *,
                        block_p: int = 4096,
                        interpret: bool = False) -> jnp.ndarray:
    """coeff: (C, S); w: (S, P) with C,S multiples of 8 and P a multiple of
    block_p (the ops wrapper pads). Returns (C, P) f32."""
    c, s = coeff.shape
    s2, p = w.shape
    assert s == s2 and p % block_p == 0, (coeff.shape, w.shape, block_p)
    grid = (p // block_p,)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, s), lambda i: (0, 0)),          # resident
            pl.BlockSpec((s, block_p), lambda i: (0, i)),    # streamed
        ],
        out_specs=pl.BlockSpec((c, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((c, p), jnp.float32),
        interpret=interpret,
    )(coeff.astype(jnp.float32), w.astype(jnp.float32))
