"""Pallas TPU kernels: coefficient-matrix x parameter-block matmuls.

``coded_matmul_kernel`` — one (C, S) coefficient matrix against shard-stacked
parameters (S, P). The grid is 2-D, ``(C_tiles, P_tiles)``: the client
dimension C is tiled as well as the parameter dimension P, so large-C codes
(C in the hundreds/thousands — the ROADMAP's large-fleet regime) keep each
output tile inside VMEM instead of materialising a (C, block_p) stripe. The
(block_c, S) coefficient tile is revisited across the P tiles; the (S,
block_p) parameter tile across the C tiles. Output may be stored as bf16
(halves the coded-slice HBM/storage footprint; decode re-accumulates in f32).

``coded_matmul_rounds_kernel`` — the same coefficient matrix against a
G-round history ``(G, S, P)`` on a 3-D ``(G, C_tiles, P_tiles)`` grid: each
round's (S, block_p) tile streams through the MXU directly from its slot in
the stacked history — no host-side concatenate of the rounds (the 2-D kernel
required a (S, G*P) copy to batch rounds).  This is the encode the
stage-program engine fuses into the training program.

``encode_decode_kernel`` — fused code round-trip ``D @ (B @ w)``: per P-tile
the (C, block_p) coded intermediate lives only in VMEM/registers, never HBM.
This is the verification path (encode then immediately re-decode to check a
round's slices) at one HBM read + one write of P instead of three passes.

VMEM working set per step (coded_matmul):
  (block_c*S + S*block_p + block_c*block_p) * 4B
  e.g. block_c=128, S=8, block_p=4096: ~2.2 MiB — well inside ~16 MiB/core.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(coeff_ref, w_ref, o_ref):
    o_ref[...] = jax.lax.dot(
        coeff_ref[...], w_ref[...],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_p", "out_dtype",
                                    "interpret"))
def coded_matmul_kernel(coeff: jnp.ndarray, w: jnp.ndarray, *,
                        block_c: int = 128,
                        block_p: int = 4096,
                        out_dtype=jnp.float32,
                        interpret: bool = False) -> jnp.ndarray:
    """coeff: (C, S); w: (S, P) with C a multiple of block_c, S a multiple of
    8 and P a multiple of block_p (the ops wrapper pads). Returns (C, P)."""
    c, s = coeff.shape
    s2, p = w.shape
    assert s == s2 and p % block_p == 0 and c % block_c == 0, \
        (coeff.shape, w.shape, block_c, block_p)
    grid = (c // block_c, p // block_p)
    return pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, s), lambda i, j: (i, 0)),   # C-tiled
            pl.BlockSpec((s, block_p), lambda i, j: (0, j)),   # P-streamed
        ],
        out_specs=pl.BlockSpec((block_c, block_p), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((c, p), out_dtype),
        interpret=interpret,
    )(coeff.astype(jnp.float32), w.astype(jnp.float32))


def _rounds_kernel(coeff_ref, w_ref, o_ref):
    o_ref[0] = jax.lax.dot(
        coeff_ref[...], w_ref[0],
        preferred_element_type=jnp.float32,
    ).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("block_c", "block_p", "out_dtype",
                                    "interpret"))
def coded_matmul_rounds_kernel(coeff: jnp.ndarray, w: jnp.ndarray, *,
                               block_c: int = 128,
                               block_p: int = 4096,
                               out_dtype=jnp.float32,
                               interpret: bool = False) -> jnp.ndarray:
    """coeff: (C, S); w: (G, S, P) with C a multiple of block_c, S of 8 and P
    of block_p (the ops wrapper pads).  Returns (G, C, P): per-round
    ``coeff @ w[g]`` on a (G, C_tiles, P_tiles) grid — the (block_c, S)
    coefficient tile is revisited across rounds and P tiles; each round's
    (S, block_p) tile is read once, straight from the stacked history."""
    c, s = coeff.shape
    g, s2, p = w.shape
    assert s == s2 and p % block_p == 0 and c % block_c == 0, \
        (coeff.shape, w.shape, block_c, block_p)
    grid = (g, c // block_c, p // block_p)
    return pl.pallas_call(
        _rounds_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_c, s), lambda r, i, j: (i, 0)),
            pl.BlockSpec((1, s, block_p), lambda r, i, j: (r, 0, j)),
        ],
        out_specs=pl.BlockSpec((1, block_c, block_p),
                               lambda r, i, j: (r, i, j)),
        out_shape=jax.ShapeDtypeStruct((g, c, p), out_dtype),
        interpret=interpret,
    )(coeff.astype(jnp.float32), w.astype(jnp.float32))


def _ed_kernel(enc_ref, dec_ref, w_ref, o_ref):
    coded = jax.lax.dot(enc_ref[...], w_ref[...],
                        preferred_element_type=jnp.float32)      # (C, blk)
    o_ref[...] = jax.lax.dot(dec_ref[...], coded,
                             preferred_element_type=jnp.float32)


@functools.partial(jax.jit, static_argnames=("block_p", "interpret"))
def encode_decode_kernel(enc: jnp.ndarray, dec: jnp.ndarray, w: jnp.ndarray,
                         *, block_p: int = 4096,
                         interpret: bool = False) -> jnp.ndarray:
    """Fused round-trip: dec @ (enc @ w) without an HBM (C, P) intermediate.

    enc: (C, S); dec: (S, C); w: (S, P). C, S multiples of 8, P of block_p.
    Returns (S, P) f32 — equals w up to code conditioning.
    """
    c, s = enc.shape
    s2, c2 = dec.shape
    s3, p = w.shape
    assert (c, s) == (c2, s2) == (c2, s3) and p % block_p == 0, \
        (enc.shape, dec.shape, w.shape)
    grid = (p // block_p,)
    return pl.pallas_call(
        _ed_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((c, s), lambda i: (0, 0)),          # resident
            pl.BlockSpec((s, c), lambda i: (0, 0)),          # resident
            pl.BlockSpec((s, block_p), lambda i: (0, i)),    # streamed
        ],
        out_specs=pl.BlockSpec((s, block_p), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((s, p), jnp.float32),
        interpret=interpret,
    )(enc.astype(jnp.float32), dec.astype(jnp.float32),
      w.astype(jnp.float32))
