"""Jit'd public wrapper: pads to TPU tile alignment, dispatches to the Pallas
kernel (interpret mode on CPU), unpads."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.coded_matmul.kernel import coded_matmul_kernel


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def coded_matmul(coeff: jnp.ndarray, w: jnp.ndarray,
                 block_p: int = 4096) -> jnp.ndarray:
    """(C,S) @ (S,P) -> (C,P) through the Pallas MXU kernel."""
    c, s = coeff.shape
    _, p = w.shape
    block_p = min(block_p, max(128, ((p + 127) // 128) * 128))
    coeff_p = _pad_to(_pad_to(coeff, 0, 8), 1, 8)
    w_p = _pad_to(_pad_to(w, 0, 8), 1, block_p)
    out = coded_matmul_kernel(coeff_p, w_p, block_p=block_p,
                              interpret=not on_tpu())
    return out[:c, :p]
