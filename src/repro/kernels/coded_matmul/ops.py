"""Jit'd public wrappers: pad to TPU tile alignment, dispatch to the Pallas
kernels (interpret mode on CPU), unpad."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.coded_matmul.kernel import (coded_matmul_kernel,
                                               coded_matmul_rounds_kernel,
                                               encode_decode_kernel)


def _pad_to(x: jnp.ndarray, axis: int, mult: int) -> jnp.ndarray:
    pad = (-x.shape[axis]) % mult
    if not pad:
        return x
    cfg = [(0, 0)] * x.ndim
    cfg[axis] = (0, pad)
    return jnp.pad(x, cfg)


def coded_matmul(coeff: jnp.ndarray, w: jnp.ndarray,
                 block_p: int = 4096, block_c: int = 128,
                 out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """(C,S) @ (S,P) -> (C,P) through the 2-D-grid Pallas MXU kernel.

    ``out_dtype``: optional storage dtype for the result (e.g. bf16 coded
    slices at half the footprint); accumulation is always f32.
    """
    c, s = coeff.shape
    _, p = w.shape
    block_p = min(block_p, max(128, ((p + 127) // 128) * 128))
    block_c = min(block_c, max(8, ((c + 7) // 8) * 8))
    coeff_p = _pad_to(_pad_to(coeff, 0, block_c), 1, 8)
    w_p = _pad_to(_pad_to(w, 0, 8), 1, block_p)
    out = coded_matmul_kernel(coeff_p, w_p, block_c=block_c, block_p=block_p,
                              out_dtype=out_dtype or jnp.float32,
                              interpret=not on_tpu())
    return out[:c, :p]


def coded_matmul_rounds(coeff: jnp.ndarray, w: jnp.ndarray,
                        block_p: int = 4096, block_c: int = 128,
                        out_dtype: Optional[jnp.dtype] = None) -> jnp.ndarray:
    """(C,S) @ (G,S,P) -> (G,C,P): all-rounds encode on a 3-D grid, no
    concatenate copy of the round history.  Accumulation is always f32."""
    c, s = coeff.shape
    _, _, p = w.shape
    block_p = min(block_p, max(128, ((p + 127) // 128) * 128))
    block_c = min(block_c, max(8, ((c + 7) // 8) * 8))
    coeff_p = _pad_to(_pad_to(coeff, 0, block_c), 1, 8)
    w_p = _pad_to(_pad_to(w, 1, 8), 2, block_p)
    out = coded_matmul_rounds_kernel(coeff_p, w_p, block_c=block_c,
                                     block_p=block_p,
                                     out_dtype=out_dtype or jnp.float32,
                                     interpret=not on_tpu())
    return out[:, :c, :p]


def coded_encode_decode(enc: jnp.ndarray, dec: jnp.ndarray, w: jnp.ndarray,
                        block_p: int = 4096) -> jnp.ndarray:
    """Fused dec @ (enc @ w) round-trip: (S,P) -> (S,P), no (C,P) in HBM."""
    c, s = enc.shape
    _, p = w.shape
    block_p = min(block_p, max(128, ((p + 127) // 128) * 128))
    enc_p = _pad_to(_pad_to(enc, 0, 8), 1, 8)
    # pad dec consistently: extra enc rows produce zero-weighted coded rows
    dec_p = _pad_to(_pad_to(dec, 0, 8), 1, 8)
    w_p = _pad_to(_pad_to(w, 0, 8), 1, block_p)
    out = encode_decode_kernel(enc_p, dec_p, w_p, block_p=block_p,
                               interpret=not on_tpu())
    return out[:s, :p]
