"""Pure-jnp oracles for the coded matmul (Lagrange encode / RS decode core)."""
import jax.numpy as jnp


def coded_matmul_ref(coeff: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """coeff: (C, S) f32 coefficient matrix; w: (S, P) shard-stacked params.

    Returns (C, P) — eq. (6) when coeff is the encode matrix, eq. (7) when it
    is the decode (re-interpolation) matrix.
    """
    return coeff.astype(jnp.float32) @ w.astype(jnp.float32)


def encode_decode_ref(enc: jnp.ndarray, dec: jnp.ndarray,
                      w: jnp.ndarray) -> jnp.ndarray:
    """Two-pass oracle for the fused round-trip: dec @ (enc @ w)."""
    coded = enc.astype(jnp.float32) @ w.astype(jnp.float32)
    return dec.astype(jnp.float32) @ coded
