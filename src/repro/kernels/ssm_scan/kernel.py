"""Pallas TPU kernel: fused selective-SSM (mamba) scan.

The §Roofline analysis (EXPERIMENTS.md pair 3) shows the pure-XLA chunked
scan is memory-bound by ~70x on jamba-398B: the (B, chunk, d_inner, n) decay/
input tensors round-trip HBM every chunk. This kernel keeps the recurrence
state AND all per-step intermediates in VMEM: HBM traffic per (batch,
d-block, chunk) grid step is just the dt/x tiles in, y tile out — the in/out
projections' traffic, ~O(n)=16x less than the XLA form.

Layout: grid (B, d_inner/BLK_D, S/CHUNK); the chunk axis iterates sequentially
(TPU grids are sequential, last dim fastest) carrying h (BLK_D, n) in VMEM
scratch. Inside a grid step, a fori_loop walks the CHUNK timesteps: each step
is (BLK_D, n) elementwise FMA + a reduction over n — VPU work on VMEM tiles.

VMEM per step: dt/x/y tiles (CHUNK x BLK_D) * 3 + b/c (CHUNK x n) + A
(BLK_D x n) + h: with CHUNK=256, BLK_D=512, n=16: ~1.6 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(dt_ref, b_ref, c_ref, x_ref, a_ref, h0_ref, y_ref, hout_ref,
            h_ref, *, chunk: int, nstate: int):
    j = pl.program_id(2)          # chunk index (sequential)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                 # (BLK_D, n)

    def step(t, _):
        dt_t = dt_ref[0, t, :].astype(jnp.float32)     # (BLK_D,)
        x_t = x_ref[0, t, :].astype(jnp.float32)
        b_t = b_ref[0, t, :].astype(jnp.float32)       # (n,)
        c_t = c_ref[0, t, :].astype(jnp.float32)
        abar = jnp.exp(dt_t[:, None] * a)              # (BLK_D, n)
        bu = (dt_t * x_t)[:, None] * b_t[None, :]
        h = abar * h_ref[...] + bu
        h_ref[...] = h
        y_ref[0, t, :] = jnp.sum(h * c_t[None, :], axis=1).astype(y_ref.dtype)
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(j == pl.num_programs(2) - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("chunk", "blk_d", "interpret"))
def ssm_scan_kernel(dt, b, c, x, a, h0, *, chunk: int = 256,
                    blk_d: int = 512, interpret: bool = False):
    """dt, x: (B,S,D); b, c: (B,S,n); a: (D,n); h0: (B,D,n).
    S % chunk == 0 and D % blk_d == 0 (ops wrapper pads).
    Returns (y (B,S,D) f32, h_last (B,D,n) f32)."""
    bsz, s, d = dt.shape
    n = b.shape[-1]
    assert s % chunk == 0 and d % blk_d == 0
    grid = (bsz, d // blk_d, s // chunk)
    kernel = functools.partial(_kernel, chunk=chunk, nstate=n)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, j: (bi, j, di)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, j: (bi, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, j: (bi, j, 0)),
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, j: (bi, j, di)),
            pl.BlockSpec((blk_d, n), lambda bi, di, j: (di, 0)),
            pl.BlockSpec((1, blk_d, n), lambda bi, di, j: (bi, di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, blk_d), lambda bi, di, j: (bi, j, di)),
            pl.BlockSpec((1, blk_d, n), lambda bi, di, j: (bi, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((blk_d, n), jnp.float32)],
        interpret=interpret,
    )(dt, b, c, x, a, h0)
