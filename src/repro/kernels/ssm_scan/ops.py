"""Jit'd wrapper: pads D to the block size and S to the chunk, dispatches to
the Pallas kernel (interpret off-TPU), unpads.

The op is differentiable: the forward pass runs the fused Pallas kernel, and
the backward pass is the VJP of the pure-jnp oracle (``ref.ssm_scan_ref``) —
the standard kernel-training recipe when the kernel itself has no hand-written
backward.  This is what lets the mamba model family *train* through the
kernel path (``ModelConfig.mamba_impl == "pallas"``) in the federated
scenario zoo instead of being serve-only.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel
from repro.kernels.ssm_scan.ref import ssm_scan_ref


def _ssm_scan_fwd_only(dt, b, c, x, a, h0, chunk, blk_d):
    bsz, s, d = dt.shape
    chunk = min(chunk, max(8, s))
    blk_d = min(blk_d, max(128, d))
    pad_s = (-s) % chunk
    pad_d = (-d) % blk_d
    if pad_s or pad_d:
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, pad_d)))
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d), (0, 0)))
    y, h_last = ssm_scan_kernel(dt, b, c, x, a, h0, chunk=chunk, blk_d=blk_d,
                                interpret=not on_tpu())
    return y[:, :s, :d], h_last[:, :d]


@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7))
def _ssm_scan(dt, b, c, x, a, h0, chunk, blk_d):
    return _ssm_scan_fwd_only(dt, b, c, x, a, h0, chunk, blk_d)


def _ssm_scan_vjp_fwd(dt, b, c, x, a, h0, chunk, blk_d):
    return _ssm_scan_fwd_only(dt, b, c, x, a, h0, chunk, blk_d), \
        (dt, b, c, x, a, h0)


def _ssm_scan_vjp_bwd(chunk, blk_d, res, cots):
    _, vjp = jax.vjp(ssm_scan_ref, *res)
    return vjp(cots)


_ssm_scan.defvjp(_ssm_scan_vjp_fwd, _ssm_scan_vjp_bwd)


def ssm_scan(dt, b, c, x, a, h0, chunk: int = 256, blk_d: int = 512):
    """Fused selective-SSM scan. Shapes as in ref.ssm_scan_ref."""
    return _ssm_scan(dt, b, c, x, a, h0, chunk, blk_d)
