"""Jit'd wrapper: pads D to the block size and S to the chunk, dispatches to
the Pallas kernel (interpret off-TPU), unpads."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.ssm_scan.kernel import ssm_scan_kernel


def ssm_scan(dt, b, c, x, a, h0, chunk: int = 256, blk_d: int = 512):
    """Fused selective-SSM scan. Shapes as in ref.ssm_scan_ref."""
    bsz, s, d = dt.shape
    n = b.shape[-1]
    chunk = min(chunk, max(8, s))
    blk_d = min(blk_d, max(128, d))
    pad_s = (-s) % chunk
    pad_d = (-d) % blk_d
    if pad_s or pad_d:
        dt = jnp.pad(dt, ((0, 0), (0, pad_s), (0, pad_d)))
        x = jnp.pad(x, ((0, 0), (0, pad_s), (0, pad_d)))
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
        a = jnp.pad(a, ((0, pad_d), (0, 0)))
        h0 = jnp.pad(h0, ((0, 0), (0, pad_d), (0, 0)))
    y, h_last = ssm_scan_kernel(dt, b, c, x, a, h0, chunk=chunk, blk_d=blk_d,
                                interpret=not on_tpu())
    return y[:, :s, :d], h_last[:, :d]
