"""Pure-jnp oracle for the fused selective-SSM scan (mamba recurrence).

Deliberately written as the straightforward O(S) time loop — independent of
the chunked production implementation in models/mamba.py — so both the Pallas
kernel and the chunked path can be validated against it.
"""
import jax
import jax.numpy as jnp


def ssm_scan_ref(dt, b, c, x, a, h0):
    """dt, x: (B,S,di); b, c: (B,S,n); a: (di,n) (negative); h0: (B,di,n).

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * b_t * x_t
    y_t = sum_n c_t[n] * h_t[:, n]

    Returns (y (B,S,di) f32, h_last (B,di,n) f32).
    """
    dt32 = dt.astype(jnp.float32)
    a32 = a.astype(jnp.float32)

    def step(h, tc):
        dt_t, b_t, c_t, x_t = tc                       # (B,di),(B,n),(B,n),(B,di)
        abar = jnp.exp(dt_t[..., None] * a32)          # (B,di,n)
        bu = dt_t[..., None] * b_t[:, None, :] * x_t[..., None].astype(jnp.float32)
        h = abar * h + bu
        y = jnp.einsum("bn,bdn->bd", c_t.astype(jnp.float32), h)
        return h, y

    xs = (dt32.transpose(1, 0, 2), b.transpose(1, 0, 2),
          c.transpose(1, 0, 2), x.transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0.astype(jnp.float32), xs)
    return ys.transpose(1, 0, 2), h_last
