from repro.kernels.window_attn.ops import window_attention  # noqa: F401
