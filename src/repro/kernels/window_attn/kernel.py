"""Pallas TPU kernel: causal sliding-window flash attention.

Structural skipping: for query block i (size BLK), only the kv blocks
[i - span + 1, i] are ever touched, where span = ceil(window/BLK) + 1. The
grid is (BH, num_q_blocks, span); the kv BlockSpec's index_map points block j
of the span at kv block (i - span + 1 + j) — negative indices clamp to 0 and
are masked out by position arithmetic inside the kernel. Compute and HBM
traffic are O(S * window) instead of O(S^2).

Online softmax state (m, l, acc) lives in VMEM scratch and persists across the
span dimension (TPU grids iterate sequentially, last axis fastest); the output
tile is written on the span's final step.

VMEM per step: q/k/v/out tiles (BLK x D) + acc — e.g. BLK=256, D=128:
4 * 256*128*4B = 512 KiB. MXU-aligned: BLK, D multiples of 128.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            blk: int, span: int, window: int, scale: float):
    i = pl.program_id(1)          # q block
    j = pl.program_id(2)          # span step

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    kv_block = i - span + 1 + j   # may be negative -> clamped read, masked
    q_pos = i * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 0)
    kv_pos = kv_block * blk + jax.lax.broadcasted_iota(jnp.int32, (blk, blk), 1)
    mask = (kv_pos >= 0) & (kv_pos <= q_pos) & (kv_pos > q_pos - window)

    s = jax.lax.dot_general(
        q_ref[0], k_ref[0], (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot(
        p.astype(v_ref.dtype), v_ref[0], preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(j == span - 1)
    def _emit():
        o_ref[0] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


@functools.partial(jax.jit, static_argnames=("window", "blk", "interpret",
                                              "scale"))
def window_attention_kernel(q, k, v, *, window: int, blk: int = 256,
                            interpret: bool = False, scale: float = None):
    """q, k, v: (BH, S, D); S multiple of blk, D multiple of 128 (wrapper
    pads). ``scale`` must be the UNPADDED head_dim's softmax scale when D was
    padded. Returns (BH, S, D) f32."""
    bh, s, d = q.shape
    assert s % blk == 0
    nq = s // blk
    span = (window + blk - 1) // blk + 1
    span = min(span, nq)
    if scale is None:
        scale = d ** -0.5
    kernel = functools.partial(_kernel, blk=blk, span=span, window=window,
                               scale=scale)
    return pl.pallas_call(
        kernel,
        grid=(bh, nq, span),
        in_specs=[
            pl.BlockSpec((1, blk, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, blk, d),
                         lambda b, i, j, span=span: (b, i - span + 1 + j, 0)),
            pl.BlockSpec((1, blk, d),
                         lambda b, i, j, span=span: (b, i - span + 1 + j, 0)),
        ],
        out_specs=pl.BlockSpec((1, blk, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((blk, d), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
            pltpu.VMEM((blk, 1), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
