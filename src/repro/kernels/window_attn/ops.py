"""Jit'd wrapper: pads (S -> blk, D -> 128), handles GQA head layout, and
dispatches to the Pallas kernel (interpret mode off-TPU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.window_attn.kernel import window_attention_kernel


def window_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     window: int, blk: int = 256) -> jnp.ndarray:
    """q: (B, S, H, hd); k, v: (B, S, KV, hd). Causal sliding-window flash
    attention; returns (B, S, H, hd) in q.dtype."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    # expand kv heads to match q heads (GQA) and fold (B, H) into one axis
    k_e = jnp.repeat(k, g, axis=2)
    v_e = jnp.repeat(v, g, axis=2)
    qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    kt = k_e.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    vt = v_e.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
    blk = min(blk, max(128, s))
    pad_s = (-s) % blk
    pad_d = (-hd) % 128
    if pad_s or pad_d:
        cfg = ((0, 0), (0, pad_s), (0, pad_d))
        qt, kt, vt = (jnp.pad(x, cfg) for x in (qt, kt, vt))
    out = window_attention_kernel(qt, kt, vt, window=window, blk=blk,
                                  interpret=not on_tpu(), scale=hd ** -0.5)
    out = out[:, :s, :hd].reshape(b, h, s, hd).transpose(0, 2, 1, 3)
    return out.astype(q.dtype)
