"""Pure-jnp oracle: causal sliding-window attention (single head layout)."""
import jax.numpy as jnp


def window_attention_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         window: int) -> jnp.ndarray:
    """q, k, v: (BH, S, D). Causal; each query attends to keys in
    (pos - window, pos]. Returns (BH, S, D) f32."""
    bh, s, d = q.shape
    scale = d ** -0.5
    logits = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    ok = (kp <= qp) & (kp > qp - window)
    logits = jnp.where(ok[None], logits, -1e30)
    p = jnp.exp(logits - logits.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
