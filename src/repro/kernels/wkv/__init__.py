from repro.kernels.wkv.ops import wkv  # noqa: F401
