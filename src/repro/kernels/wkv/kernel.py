"""Pallas TPU kernel: fused RWKV-6 WKV recurrence.

Same motivation as kernels/ssm_scan (§Perf pair 3): the chunk-parallel XLA
form materialises (B,H,c,c,N) pairwise-decay tensors in HBM every chunk
(rwkv6-3b train_4k is memory-bound ~250x at baseline). Here the (N,N) state
and all per-step intermediates stay in VMEM: HBM traffic per grid step is the
r/k/v/lw tiles in and the y tile out.

Layout: grid (B*H, S/CHUNK); chunk axis sequential, state (N,N) in VMEM
scratch; fori_loop over the CHUNK steps (each step: two (N,N) VPU FMAs + a
row reduction). u rides along as a (1, N) resident operand per head.

VMEM per step: 4 x (CHUNK x N) tiles + (N,N) state + y tile:
CHUNK=256, N=64 -> ~300 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, h0_ref, y_ref, hout_ref,
            h_ref, *, chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    u = u_ref[0].astype(jnp.float32)                   # (N,)

    def step(t, _):
        r_t = r_ref[0, t, :].astype(jnp.float32)       # (N,)
        k_t = k_ref[0, t, :].astype(jnp.float32)
        v_t = v_ref[0, t, :].astype(jnp.float32)
        w_t = jnp.exp(lw_ref[0, t, :].astype(jnp.float32))
        kv = k_t[:, None] * v_t[None, :]               # (N,N)
        y = jnp.sum((h_ref[...] + u[:, None] * kv) * r_t[:, None], axis=0)
        y_ref[0, t, :] = y.astype(y_ref.dtype)
        h_ref[...] = w_t[:, None] * h_ref[...] + kv
        return 0

    jax.lax.fori_loop(0, chunk, step, 0)

    @pl.when(j == pl.num_programs(1) - 1)
    def _emit():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def wkv_kernel(r, k, v, lw, u, h0, *, chunk: int = 256,
               interpret: bool = False):
    """r,k,v,lw: (BH, S, N); u: (BH, N) (head-broadcast by the wrapper);
    h0: (BH, N, N). S % chunk == 0. Returns (y (BH,S,N) f32, h_last)."""
    bh, s, n = r.shape
    assert s % chunk == 0
    grid = (bh, s // chunk)
    kernel = functools.partial(_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, n), lambda b, j: (b, 0)),
            pl.BlockSpec((1, n, n), lambda b, j: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, n), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, n, n), lambda b, j: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, lw, u, h0)
