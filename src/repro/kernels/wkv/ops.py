"""Jit'd wrapper: folds (B, H) into the grid axis, broadcasts u per head,
pads S to the chunk, dispatches (interpret off-TPU)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.wkv.kernel import wkv_kernel


def wkv(r, k, v, lw, u, h0, chunk: int = 256):
    """r,k,v,lw: (B,S,H,N) f32; u: (H,N); h0: (B,H,N,N).
    Returns (y (B,S,H,N) f32, h_last (B,H,N,N))."""
    b, s, h, n = r.shape
    chunk = min(chunk, max(8, s))
    pad_s = (-s) % chunk

    def fold(x):
        x = x.transpose(0, 2, 1, 3).reshape(b * h, s, n)
        if pad_s:
            x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0)))
        return x

    rf, kf, vf = fold(r), fold(k), fold(v)
    lwf = fold(lw)
    uf = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    h0f = h0.reshape(b * h, n, n)
    y, h_last = wkv_kernel(rf, kf, vf, lwf, uf, h0f, chunk=chunk,
                           interpret=not on_tpu())
    y = y[:, :s].reshape(b, h, s, n).transpose(0, 2, 1, 3)
    return y, h_last.reshape(b, h, n, n)
