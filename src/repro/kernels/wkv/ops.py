"""Jit'd wrapper: folds (B, H) into the grid axis, broadcasts u per head,
pads S to the chunk, dispatches (interpret off-TPU).

The op is differentiable: the forward pass runs the Pallas kernel, and the
backward pass is the VJP of the pure-jnp oracle (``ref.wkv_ref``, vmapped
over heads).  This lets the rwkv6 model family *train* through the kernel
path (``ModelConfig.rwkv_impl == "pallas"``) in the federated scenario zoo.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import on_tpu
from repro.kernels.wkv.kernel import wkv_kernel
from repro.kernels.wkv.ref import wkv_ref

# multi-head oracle matching the op signature: r,k,v,lw (B,S,H,N), u (H,N),
# h0 (B,H,N,N) -> (y (B,S,H,N), h_last (B,H,N,N))
_wkv_ref_mh = jax.vmap(wkv_ref, in_axes=(2, 2, 2, 2, 0, 1), out_axes=(2, 1))


def _wkv_fwd_only(r, k, v, lw, u, h0, chunk):
    b, s, h, n = r.shape
    chunk = min(chunk, max(8, s))
    pad_s = (-s) % chunk

    def fold(x):
        x = x.transpose(0, 2, 1, 3).reshape(b * h, s, n)
        if pad_s:
            x = jnp.pad(x, ((0, 0), (0, pad_s), (0, 0)))
        return x

    rf, kf, vf = fold(r), fold(k), fold(v)
    lwf = fold(lw)
    uf = jnp.broadcast_to(u[None], (b, h, n)).reshape(b * h, n)
    h0f = h0.reshape(b * h, n, n)
    y, h_last = wkv_kernel(rf, kf, vf, lwf, uf, h0f, chunk=chunk,
                           interpret=not on_tpu())
    y = y[:, :s].reshape(b, h, s, n).transpose(0, 2, 1, 3)
    return y, h_last.reshape(b, h, n, n)


@functools.partial(jax.custom_vjp, nondiff_argnums=(6,))
def _wkv(r, k, v, lw, u, h0, chunk):
    return _wkv_fwd_only(r, k, v, lw, u, h0, chunk)


def _wkv_vjp_fwd(r, k, v, lw, u, h0, chunk):
    return _wkv_fwd_only(r, k, v, lw, u, h0, chunk), (r, k, v, lw, u, h0)


def _wkv_vjp_bwd(chunk, res, cots):
    _, vjp = jax.vjp(_wkv_ref_mh, *res)
    return vjp(cots)


_wkv.defvjp(_wkv_vjp_fwd, _wkv_vjp_bwd)


def wkv(r, k, v, lw, u, h0, chunk: int = 256):
    """r,k,v,lw: (B,S,H,N) f32; u: (H,N); h0: (B,H,N,N).
    Returns (y (B,S,H,N) f32, h_last (B,H,N,N))."""
    return _wkv(r, k, v, lw, u, h0, chunk)
