"""Pure-jnp oracle for the RWKV-6 WKV recurrence (sequential form).

    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t,   w_t = exp(lw_t)

Independent of the chunk-parallel production path in models/rwkv6.py.
"""
import jax
import jax.numpy as jnp


def wkv_ref(r, k, v, lw, u, h0):
    """r,k,v,lw: (B,S,N) f32 (single head); u: (N,); h0: (B,N,N).
    Returns (y (B,S,N), h_last (B,N,N))."""

    def step(h, tc):
        r_t, k_t, v_t, lw_t = tc                       # (B,N) each
        kv = k_t[..., None] * v_t[:, None, :]          # (B,N,N)
        y = jnp.einsum("bn,bnm->bm", r_t, h + u[None, :, None] * kv)
        h = jnp.exp(lw_t)[..., None] * h + kv
        return h, y

    xs = (r.transpose(1, 0, 2), k.transpose(1, 0, 2),
          v.transpose(1, 0, 2), lw.transpose(1, 0, 2))
    h_last, ys = jax.lax.scan(step, h0, xs)
    return ys.transpose(1, 0, 2), h_last
