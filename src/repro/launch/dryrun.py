import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes, and extract the roofline terms from the compiled
artifact. No arrays are allocated — inputs are ShapeDtypeStructs.

    PYTHONPATH=src python -m repro.launch.dryrun --arch olmo-1b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results are dumped as JSON under experiments/dryrun/ for the roofline report
(EXPERIMENTS.md Sec Dry-run / Sec Roofline).

NOTE: the XLA_FLAGS line above MUST run before any jax import — jax locks the
host device count at first backend init. Smoke tests / benches import jax
normally and see 1 device.
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import (ASSIGNED_ARCHS, FLConfig, OptimizerConfig, SHAPES,
                           get_config)
from repro.launch import inputs as inp
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import (act_rules, batch_shardings,
                                    cache_shardings, needs_fsdp,
                                    opt_state_shardings, param_rules,
                                    param_shardings)
from repro.launch.train import make_fedavg_step
from repro.launch.serve import make_decode_step, make_prefill_step
from repro.models import abstract_params, init_cache
from repro.models.transformer import ShardCtx
from repro.optim import make_optimizer
from repro.roofline.analysis import analyze_compiled

OUT_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

# long_500k policy (DESIGN.md): pure full-attention archs run it only as their
# sliding-window variant; whisper skips it outright (448-token decoder).
WINDOW_VARIANT_FOR_LONG = {"olmo-1b", "yi-6b", "llama3.2-3b", "internvl2-2b"}
SKIP_LONG = {"whisper-tiny"}
LONG_WINDOW = 8192


def resolve_config(arch: str, shape_name: str, variant: str = "auto"):
    """Returns (cfg, notes) with the long-context variant policy applied."""
    cfg = get_config(arch)
    notes = []
    if shape_name == "long_500k":
        if arch in SKIP_LONG:
            return None, [f"{arch} skips long_500k (architectural decoder "
                          f"context {cfg.decoder_context})"]
        if arch in WINDOW_VARIANT_FOR_LONG or variant == "window":
            cfg = dataclasses.replace(cfg, layer_pattern=("local",),
                                      sliding_window=LONG_WINDOW)
            notes.append(f"sliding-window variant (w={LONG_WINDOW}) for "
                         "sub-quadratic long-context decode")
    return cfg, notes


def optimizer_for(cfg) -> OptimizerConfig:
    name = "adamw_bf16" if cfg.param_count() > 100e9 else "adamw"
    return OptimizerConfig(name=name, lr=3e-4)


SEQPAR_MAX_PARAMS = 8e9


def resolve_strategy(cfg, shape_kind: str, strategy: str) -> str:
    """'auto': sequence-parallel prefill for attention-only models whose head
    counts don't divide the model axis (TP there degenerates into per-block
    all-reduces — see §Perf llama3.2 log) and that fit replicated; TP
    otherwise. Recurrent stacks (rwkv/mamba) are excluded: their time scans
    cannot shard over seq, so seq-parallel replicates the recurrence."""
    if strategy != "auto":
        return strategy
    attention_only = all(k in ("global", "local") for k in cfg.layer_kinds)
    if (shape_kind == "prefill" and attention_only
            and (cfg.num_heads % 16 or cfg.num_kv_heads % 16)
            and cfg.param_count() < SEQPAR_MAX_PARAMS):
        return "seq_parallel"
    return "tp"


PROFILES = {
    # paper-faithful: masked-full attention blocks, f32 scan internals, TP
    "baseline": {"overrides": {}, "strategy": "tp"},
    # beyond-paper §Perf: triangle block skipping, bf16 ssm chunks, auto
    # sequence-parallel prefill
    "optimized": {"overrides": {"attn_block_skip": True,
                                "ssm_chunk_dtype": "bfloat16"},
                  "strategy": "auto"},
}


def build_lowered(arch: str, shape_name: str, multi_pod: bool,
                  variant: str = "auto", fl: FLConfig = None,
                  overrides: dict = None, strategy: str = "tp"):
    """Lower the right step for (arch, shape) on the production mesh.

    Returns (lowered, mesh, cfg, notes) or (None, None, None, notes) on skip.
    """
    cfg, notes = resolve_config(arch, shape_name, variant)
    if cfg is None:
        return None, None, None, notes
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = SHAPES[shape_name]
    fl = fl or FLConfig(fl_clients_per_step=4, fl_local_steps=1)
    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = resolve_strategy(cfg, shape.kind, strategy)
    if strategy == "seq_parallel":
        # q must stay a single shardable dim (scans can't shard over seq)
        cfg = dataclasses.replace(cfg, attn_block_q=0, attn_block_skip=False)
        notes.append("seq_parallel prefill (head counts don't divide TP axis)")
    prules = param_rules(cfg, shape.kind, multi_pod, strategy=strategy)
    arules = act_rules(cfg, shape.kind, multi_pod, strategy=strategy)
    ctx = ShardCtx(mesh, arules)
    p_abs = abstract_params(cfg)
    p_sh = param_shardings(cfg, mesh, prules, abstract=p_abs)

    if shape.kind == "train":
        opt = optimizer_for(cfg)
        step = make_fedavg_step(cfg, fl, opt, ctx, remat="block")
        opt_init, _ = make_optimizer(opt)
        o_abs = jax.eval_shape(opt_init, p_abs)
        o_sh = opt_state_shardings(o_abs, p_sh, mesh)
        b_abs = inp.train_batch_specs(cfg, shape, fl)
        b_sh = batch_shardings(b_abs, mesh, arules, client_leading=True)
        jitted = jax.jit(step, in_shardings=((p_sh, o_sh), b_sh))
        lowered = jitted.lower((p_abs, o_abs), b_abs)
    elif shape.kind == "prefill":
        step = make_prefill_step(cfg, ctx)
        b_abs = inp.prefill_batch_specs(cfg, shape)
        b_sh = batch_shardings(b_abs, mesh, arules)
        jitted = jax.jit(step, in_shardings=(p_sh, b_sh))
        lowered = jitted.lower(p_abs, b_abs)
    else:  # decode
        step = make_decode_step(cfg, ctx)
        cache_len, enc_len = inp.cache_len_for(cfg, shape)
        c_abs = jax.eval_shape(
            lambda: init_cache(cfg, shape.global_batch, cache_len,
                               dtype=jnp.dtype(cfg.compute_dtype),
                               enc_len=enc_len))
        c_sh = cache_shardings(c_abs, mesh, arules)
        t_abs = inp.decode_token_specs(shape)
        t_sh = batch_shardings({"tokens": t_abs}, mesh, arules)["tokens"]
        # pin the output cache to the input cache's shardings so donation
        # aliases the (large) KV buffers instead of copying them
        jitted = jax.jit(step, in_shardings=(p_sh, t_sh, c_sh),
                         out_shardings=(None, c_sh), donate_argnums=(2,))
        lowered = jitted.lower(p_abs, t_abs, c_abs)
    return lowered, mesh, cfg, notes


def run_one(arch: str, shape_name: str, multi_pod: bool,
            variant: str = "auto", save: bool = True,
            overrides: dict = None, tag: str = "",
            strategy: str = "tp") -> dict:
    from repro.telemetry import get_tracer
    # monotonic wall measurement (time.time() can jump under NTP slew) —
    # and the same interval lands in the trace as a "dryrun.compile" span
    t0 = time.perf_counter()
    mesh_name = "2x16x16" if multi_pod else "16x16"
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "status": "ok", "notes": [], "strategy": strategy}
    with get_tracer().span("dryrun.compile", arch=arch, shape=shape_name,
                           mesh=mesh_name) as sp:
        try:
            lowered, mesh, cfg, notes = build_lowered(
                arch, shape_name, multi_pod, variant, overrides=overrides,
                strategy=strategy)
            rec["notes"] = notes
            if lowered is None:
                rec["status"] = "skipped"
                sp.annotate(status="skipped")
                return _finish(rec, t0, save, tag)
            compiled = lowered.compile()
            mem = compiled.memory_analysis()
            rec["memory_analysis"] = _mem_dict(mem)
            from repro.roofline.hlo_cost import xla_cost_analysis
            rec["cost_analysis"] = {k: float(v) for k, v in
                                    xla_cost_analysis(compiled).items()
                                    if isinstance(v, (int, float))}
            rec.update(analyze_compiled(compiled, mesh, cfg,
                                        SHAPES[shape_name]))
            print(compiled.memory_analysis())
            ca = rec["cost_analysis"]
            print({k: ca[k] for k in ("flops", "bytes accessed")
                   if k in ca})
        except Exception as e:  # noqa: BLE001 — record, keep sweeping
            rec["status"] = "error"
            rec["error"] = f"{type(e).__name__}: {e}"
            rec["traceback"] = traceback.format_exc()[-2000:]
        sp.annotate(status=rec["status"])
        return _finish(rec, t0, save, tag)


def _mem_dict(mem):
    if mem is None:
        return None
    out = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    return out


def _finish(rec, t0, save, tag):
    rec["wall_s"] = round(time.perf_counter() - t0, 2)
    if save:
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        name = f"{rec['arch']}_{rec['shape']}_{rec['mesh']}{tag}.json"
        (OUT_DIR / name).write_text(json.dumps(rec, indent=1))
    status = rec["status"]
    extra = "" if status == "ok" else f" ({rec.get('error', '')[:120]})"
    print(f"[dryrun] {rec['arch']:22s} {rec['shape']:12s} {rec['mesh']:8s} "
          f"{status:7s} {rec['wall_s']:8.1f}s{extra}", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--variant", default="auto")
    ap.add_argument("--profile", default="baseline",
                    choices=list(PROFILES))
    args = ap.parse_args(argv)

    prof = PROFILES[args.profile]
    tag = "" if args.profile == "baseline" else "_opt"
    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    results = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                results.append(run_one(arch, shape, mp, args.variant,
                                       overrides=prof["overrides"],
                                       strategy=prof["strategy"], tag=tag))
    bad = [r for r in results if r["status"] == "error"]
    print(f"[dryrun] {len(results)} combos: "
          f"{sum(r['status'] == 'ok' for r in results)} ok, "
          f"{sum(r['status'] == 'skipped' for r in results)} skipped, "
          f"{len(bad)} errors")
    return 1 if bad else 0


if __name__ == "__main__":
    raise SystemExit(main())
