"""input_specs(): ShapeDtypeStruct stand-ins for every model input — weak-type
correct, shardable, no device allocation. The one allowed stub: audio frames /
vision patches arrive as precomputed embeddings of the right shape."""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, ModelConfig, ShapeConfig

AUDIO_ENC_FRAMES = 1500   # whisper 30s window after conv frontend


def train_batch_specs(cfg: ModelConfig, shape: ShapeConfig,
                      fl: FLConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    """Client-serial FedAvg layout: (n_clients, per_client_batch, ...)."""
    nc = fl.fl_clients_per_step
    bpc = shape.global_batch // nc
    assert bpc * nc == shape.global_batch
    s = shape.seq_len
    out = {
        "tokens": jax.ShapeDtypeStruct((nc, bpc, s), jnp.int32),
        "labels": jax.ShapeDtypeStruct((nc, bpc, s), jnp.int32),
    }
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct((nc, bpc, cfg.vision_tokens,
                                               cfg.d_model), cdt)
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((nc, bpc, AUDIO_ENC_FRAMES,
                                              cfg.d_model), cdt)
    return out


def prefill_batch_specs(cfg: ModelConfig,
                        shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    out = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.family == "vlm":
        out["patches"] = jax.ShapeDtypeStruct((b, cfg.vision_tokens, cfg.d_model),
                                              cdt)
    if cfg.family == "audio":
        out["frames"] = jax.ShapeDtypeStruct((b, AUDIO_ENC_FRAMES, cfg.d_model),
                                             cdt)
    return out


def decode_token_specs(shape: ShapeConfig) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)


def cache_len_for(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[int, int]:
    """(cache_len, enc_len) for the decode cache."""
    cache_len = shape.seq_len + (cfg.vision_tokens if cfg.family == "vlm" else 0)
    enc_len = AUDIO_ENC_FRAMES if cfg.family == "audio" else 0
    return cache_len, enc_len
