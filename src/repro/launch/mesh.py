"""Production mesh construction (a FUNCTION, not a module-level constant, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """TPU v5e pod mesh: (data=16, model=16) single pod (256 chips); the
    multi-pod variant adds a leading pod=2 axis (512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many real devices exist (CPU tests)."""
    return jax.make_mesh((data, model), ("data", "model"))
