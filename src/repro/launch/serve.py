"""Serving: prefill + batched decode of the (unlearned) model.

``make_prefill_step`` / ``make_decode_step`` are the units the dry-run lowers
for the prefill/decode shapes. ``serve_demo`` runs a real CPU-scale serving
loop (reduced config): prefill a batch of prompts, then decode tokens
autoregressively — this is deliverable (b)'s serving driver.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import decode_fn, init_cache, prefill_fn
from repro.models.transformer import NULL_CTX, ShardCtx


def make_prefill_step(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX,
                      max_len: int = None):
    pf = prefill_fn(cfg, ctx, max_len=max_len)

    def step(params, batch):
        return pf(params, batch)

    return step


def make_decode_step(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
    df = decode_fn(cfg, ctx)

    def step(params, tokens, cache):
        return df(params, tokens, cache)

    return step


# ---------------------------------------------------------------------------
# CPU-scale serving demo
# ---------------------------------------------------------------------------

def serve_demo(argv=None):
    import argparse
    import numpy as np
    from repro.configs import get_config, reduce_for_smoke
    from repro.models import init_params

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="rwkv6-3b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = reduce_for_smoke(get_config(args.arch))
    params = init_params(cfg, jax.random.key(0))
    rng = np.random.default_rng(0)
    toks = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                    (args.batch, args.prompt_len)), jnp.int32)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["patches"] = jnp.zeros((args.batch, cfg.vision_tokens, cfg.d_model),
                                     jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((args.batch, 64, cfg.d_model), jnp.float32)

    prefill = jax.jit(make_prefill_step(cfg, max_len=args.prompt_len + args.gen))
    decode = jax.jit(make_decode_step(cfg), donate_argnums=(2,))
    logits, cache = prefill(params, batch)
    out = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    for i in range(args.gen):
        out.append(np.asarray(tok)[:, 0])
        logits, cache = decode(params, tok, cache)
        tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    gen = np.stack(out, 1)
    print(f"arch={cfg.name} served batch={args.batch} gen={args.gen} tokens")
    print("generated token ids (first row):", gen[0].tolist())
    return gen


if __name__ == "__main__":
    serve_demo()
