"""Sharding policy: logical-axis -> mesh-axis rule tables per (arch x shape),
plus helpers that turn param/cache/batch pytrees into NamedSharding pytrees.

Policy summary (see DESIGN.md Sec 5):
  * tensor parallelism over ``model`` for mlp/heads/experts/vocab,
  * FSDP over ``data`` (x ``pod`` multi-pod) on the ``embed`` dim for models
    that need it (>2B when training, >40B always — jamba),
  * batch over ``data`` (x ``pod``),
  * long-context decode (batch=1): KV *sequence* sharded over data x model,
  * every assignment is divisibility-checked (spec_for) so odd vocabs/head
    counts degrade to replication instead of failing to lower.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import abstract_params, param_axes
from repro.models.params import spec_for

FSDP_TRAIN_THRESHOLD = 2e9
FSDP_ALWAYS_THRESHOLD = 40e9


def needs_fsdp(cfg: ModelConfig, shape_kind: str) -> bool:
    n = cfg.param_count()
    if n > FSDP_ALWAYS_THRESHOLD:
        return True
    return shape_kind == "train" and n > FSDP_TRAIN_THRESHOLD


def param_rules(cfg: ModelConfig, shape_kind: str, multi_pod: bool,
                strategy: str = "tp") -> Dict:
    fsdp = needs_fsdp(cfg, shape_kind)
    if fsdp:
        embed = (("pod", "data"), "data") if multi_pod else ("data",)
    else:
        embed = ()
    if strategy == "seq_parallel":
        # §Perf: pure data+sequence parallelism — weights replicated (vocab
        # excepted), activations sharded over (data=batch, model=seq). Removes
        # the per-block TP all-reduces that dominate when heads % model != 0.
        tensor = ()
    else:
        tensor = ("model",)
    return {
        "embed": embed,
        "vocab": ("model",),
        "mlp": tensor,
        "heads": tensor,
        "kv_heads": tensor,
        "head_dim": tensor,
        "heads_flat": tensor,
        "expert": tensor,
        "expert_router": tensor,
        "layers": (),
    }


def act_rules(cfg: ModelConfig, shape_kind: str, multi_pod: bool,
              strategy: str = "tp") -> Dict:
    batch = ((("pod", "data"), "data") if multi_pod else ("data",))
    if shape_kind == "decode":
        # KV sequence sharding: takes whatever the batch dim left free —
        # everything for long_500k (batch=1), just ``model`` for decode_32k.
        kvseq = (("data", "model"), "data", "model")
    else:
        kvseq = ("model",) if strategy == "seq_parallel" else ()
    if strategy == "seq_parallel":
        return {
            "batch": batch,
            "seq": ("model",),
            "embed": (),
            "heads": (),
            "kv_heads": (),
            "head_dim": (),
            "vocab": (),
            "kvseq": kvseq,
            "mlp": (),
            "layers": (),
            "moe_group": batch,
            "expert": (),
        }
    return {
        "batch": batch,
        "seq": (),
        "embed": (),
        "heads": ("model",),
        "kv_heads": ("model",),
        "head_dim": ("model",),
        "vocab": ("model",),
        "kvseq": kvseq,
        "mlp": ("model",),
        "layers": (),
        # MoE dispatch: token groups follow batch; experts are model-parallel
        "moe_group": batch,
        "expert": ("model",),
    }


# ---------------------------------------------------------------------------
# Param shardings
# ---------------------------------------------------------------------------

def _is_axes(x) -> bool:
    return isinstance(x, tuple) and all(isinstance(a, (str, type(None)))
                                        for a in x)


def param_shardings(cfg: ModelConfig, mesh: Mesh, rules: Dict,
                    abstract=None):
    axes = param_axes(cfg)
    abstract = abstract or abstract_params(cfg)
    return jax.tree.map(
        lambda a, s: NamedSharding(mesh, spec_for(tuple(s.shape), a, rules, mesh)),
        axes, abstract, is_leaf=_is_axes)


# ---------------------------------------------------------------------------
# Cache shardings (leaf-name driven)
# ---------------------------------------------------------------------------

_CACHE_AXES = {
    # attention KV (possibly with a leading scanned-layers dim)
    "k": ("batch", "kvseq", "kv_heads", "head_dim"),
    "v": ("batch", "kvseq", "kv_heads", "head_dim"),
    "xk": ("batch", "kvseq", "kv_heads", "head_dim"),
    "xv": ("batch", "kvseq", "kv_heads", "head_dim"),
    # mamba
    "conv": ("batch", None, "mlp"),
    "h": None,  # disambiguated by rank below (mamba (B,di,n) vs rwkv (B,H,N,N))
    # rwkv
    "tm_prev": ("batch", "embed"),
    "cm_prev": ("batch", "embed"),
    "pos": (),
}


def _cache_leaf_axes(path, leaf) -> Tuple:
    name = None
    for k in reversed(path):
        if hasattr(k, "key"):
            name = k.key
            break
    rank = len(leaf.shape)
    if name == "h":
        # mamba h: (B, di, n) rank3 / (L, B, di, n) rank4 (square only if
        # di == n, impossible for assigned configs); rwkv h: (B, H, N, N)
        # rank4 square tail / (L, B, H, N, N) rank5.
        if rank == 3 or (rank == 4 and leaf.shape[-1] != leaf.shape[-2]):
            base = ("batch", "mlp", None)
        else:
            base = ("batch", "heads", None, None)
    else:
        base = _CACHE_AXES.get(name, ())
    # account for the leading scanned-layers dim
    extra = rank - len(base)
    return ("layers",) * extra + tuple(base)


def cache_shardings(cache_abstract, mesh: Mesh, rules: Dict):
    def one(path, leaf):
        axes = _cache_leaf_axes(path, leaf)
        return NamedSharding(mesh, spec_for(tuple(leaf.shape), axes, rules, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_abstract)


# ---------------------------------------------------------------------------
# Batch shardings
# ---------------------------------------------------------------------------

_BATCH_AXES = {
    "tokens": ("batch", "seq"),
    "labels": ("batch", "seq"),
    "patches": ("batch", "seq", "embed"),
    "frames": ("batch", "seq", "embed"),
    "images": ("batch", None, None, None),
}


def batch_shardings(batch_abstract, mesh: Mesh, rules: Dict,
                    client_leading: bool = False):
    def one(path, leaf):
        name = path[-1].key if hasattr(path[-1], "key") else None
        axes = _BATCH_AXES.get(name, ())
        if client_leading:
            axes = (None,) + tuple(axes)
        axes = tuple(axes)[: len(leaf.shape)]
        axes = axes + (None,) * (len(leaf.shape) - len(axes))
        return NamedSharding(mesh, spec_for(tuple(leaf.shape), axes, rules, mesh))
    return jax.tree_util.tree_map_with_path(one, batch_abstract)


def opt_state_shardings(opt_abstract, p_shardings, mesh: Mesh):
    """Moments shard like params; scalars replicate."""
    def one(leaf, ps=None):
        return ps if ps is not None else NamedSharding(mesh, P())
    mu = (jax.tree.map(lambda s, a: s, p_shardings, opt_abstract.mu)
          if opt_abstract.mu is not None else None)
    nu = (jax.tree.map(lambda s, a: s, p_shardings, opt_abstract.nu)
          if opt_abstract.nu is not None else None)
    from repro.optim.optimizers import OptState
    return OptState(NamedSharding(mesh, P()), mu, nu)
