"""Pod-scale training step: one per-shard FedAvg(+server-optimizer) round.

This is the paper's per-shard learning unit mapped to a TPU pod (DESIGN.md
Sec 3): clients are processed CLIENT-SERIALLY (lax.scan) — each client's
L local SGD steps run data-parallel over the whole mesh with FSDP/TP-sharded
parameters, and only the parameter *delta* is carried. The shard server's
aggregation is the scan's mean-delta; the server optimizer (AdamW — FedOpt
style) applies it. Isolation holds: no collective crosses the shard boundary
because one shard owns the mesh for its stage slot.

Also provides the centralized step (the FR baseline / plain pretraining) and
the calibration round (eq. 3) used by unlearning at production scale.

Run as a module for a CPU-scale demonstration:
    PYTHONPATH=src python -m repro.launch.train --arch olmo-1b --steps 4
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import FLConfig, ModelConfig, OptimizerConfig, ShapeConfig
from repro.core.unlearning import tree_norm
from repro.models import loss_fn
from repro.models.transformer import NULL_CTX, ShardCtx
from repro.optim import make_optimizer

LOCAL_LR = 1e-2   # clients' local SGD step (FedAvg inner loop)


def make_fedavg_step(cfg: ModelConfig, fl: FLConfig, opt: OptimizerConfig,
                     ctx: ShardCtx = NULL_CTX, remat: str = "block"):
    """Returns step(state, batch) -> (state, metrics).

    batch: {"tokens": (n_clients, bpc, S), ...} — client-serial layout.
    state: (params, opt_state).
    """
    lf = loss_fn(cfg, ctx, remat=remat)
    _, opt_update = make_optimizer(opt)
    n_clients = fl.fl_clients_per_step
    local_steps = fl.fl_local_steps

    def client_round(params, cbatch):
        """One client: L local SGD steps on its local batch; returns delta."""
        def local_step(p, _):
            loss, grads = jax.value_and_grad(
                lambda q: lf(q, cbatch)[0])(p)
            p = jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - LOCAL_LR * g.astype(jnp.float32)).astype(w.dtype),
                p, grads)
            return p, loss

        p_new, losses = jax.lax.scan(local_step, params, None,
                                     length=local_steps)
        delta = jax.tree.map(lambda a, b: (a - b).astype(a.dtype), p_new, params)
        return delta, losses.mean()

    def step(state, batch):
        params, opt_state = state

        def scan_body(acc, cbatch):
            delta, loss = client_round(params, cbatch)
            acc = jax.tree.map(lambda a, d: a + d.astype(a.dtype) / n_clients,
                               acc, delta)
            return acc, loss

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        acc, losses = jax.lax.scan(scan_body, acc0, batch)
        # server update (FedOpt): pseudo-gradient = -mean delta
        pseudo_grad = jax.tree.map(lambda d: -d, acc)
        new_params, new_opt = opt_update(params, pseudo_grad, opt_state)
        metrics = {"loss": losses.mean(),
                   "delta_norm": tree_norm(acc)}
        return (new_params, new_opt), metrics

    return step


def make_central_step(cfg: ModelConfig, opt: OptimizerConfig,
                      ctx: ShardCtx = NULL_CTX, remat: str = "block"):
    """Plain data-parallel training step (FR baseline / pretraining).

    batch: {"tokens": (B, S), ...}.
    """
    lf = loss_fn(cfg, ctx, remat=remat)
    _, opt_update = make_optimizer(opt)

    def step(state, batch):
        params, opt_state = state
        (loss, mets), grads = jax.value_and_grad(lf, has_aux=True)(params, batch)
        new_params, new_opt = opt_update(params, grads, opt_state)
        return (new_params, new_opt), mets

    return step


def make_calibration_step(cfg: ModelConfig, fl: FLConfig,
                          ctx: ShardCtx = NULL_CTX, remat: str = "block"):
    """One production-scale calibrated retraining round (paper eq. 3).

    step(params, batch, stored_norms) -> (params, metrics).
    batch is client-serial; stored_norms: (n_clients,) historical ||delta||
    (retrieved via the coded store). Retained clients run L/r local steps;
    each client's delta is rescaled to its historical norm, then averaged.
    """
    lf = loss_fn(cfg, ctx, remat=remat)
    n_clients = fl.fl_clients_per_step
    local_steps = max(int(fl.fl_local_steps / fl.retrain_ratio), 1)

    def client_round(params, cbatch):
        def local_step(p, _):
            loss, grads = jax.value_and_grad(lambda q: lf(q, cbatch)[0])(p)
            p = jax.tree.map(
                lambda w, g: (w.astype(jnp.float32)
                              - LOCAL_LR * g.astype(jnp.float32)).astype(w.dtype),
                p, grads)
            return p, loss

        p_new, losses = jax.lax.scan(local_step, params, None, length=local_steps)
        delta = jax.tree.map(lambda a, b: (a - b).astype(a.dtype), p_new, params)
        return delta, losses.mean()

    def step(params, batch, stored_norms):
        def scan_body(acc, xs):
            cbatch, hist_norm = xs
            delta, loss = client_round(params, cbatch)
            ratio = hist_norm / jnp.maximum(tree_norm(delta), 1e-12)
            acc = jax.tree.map(
                lambda a, d: a + (d.astype(jnp.float32) * ratio / n_clients
                                  ).astype(a.dtype), acc, delta)
            return acc, loss

        acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, p.dtype), params)
        acc, losses = jax.lax.scan(scan_body, acc0, (batch, stored_norms))
        new_params = jax.tree.map(lambda p, a: p + a.astype(p.dtype), params, acc)
        return new_params, {"loss": losses.mean()}

    return step


# ---------------------------------------------------------------------------
# CPU-scale demo driver
# ---------------------------------------------------------------------------

def _demo(argv=None):
    import argparse
    import numpy as np
    from repro.configs import FLConfig, OptimizerConfig, get_config, reduce_for_smoke
    from repro.models import init_params
    from repro.optim import init_optimizer

    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="olmo-1b")
    ap.add_argument("--steps", type=int, default=4)
    ap.add_argument("--clients", type=int, default=2)
    ap.add_argument("--local-steps", type=int, default=2)
    args = ap.parse_args(argv)

    cfg = reduce_for_smoke(get_config(args.arch))
    fl = FLConfig(fl_clients_per_step=args.clients,
                  fl_local_steps=args.local_steps)
    opt = OptimizerConfig(name="adamw", lr=1e-3)
    params = init_params(cfg, jax.random.key(0))
    state = (params, init_optimizer(opt, params))
    step = jax.jit(make_fedavg_step(cfg, fl, opt))
    rng = np.random.default_rng(0)
    for i in range(args.steps):
        toks = rng.integers(0, cfg.vocab_size, (args.clients, 2, 64))
        batch = {"tokens": jnp.asarray(toks, jnp.int32),
                 "labels": jnp.asarray(toks, jnp.int32)}
        if cfg.family == "vlm":
            batch["patches"] = jnp.zeros((args.clients, 2, cfg.vision_tokens,
                                          cfg.d_model), jnp.float32)
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros((args.clients, 2, 64, cfg.d_model),
                                        jnp.float32)
        state, mets = step(state, batch)
        print(f"fedavg round {i}: loss={float(mets['loss']):.4f} "
              f"delta={float(mets['delta_norm']):.4f}")


if __name__ == "__main__":
    _demo()
