from repro.models.model import (  # noqa: F401
    abstract_params,
    decode_fn,
    init_cache,
    init_params,
    loss_fn,
    num_params,
    param_axes,
    predict_fn,
    prefill_fn,
)
from repro.models.transformer import ShardCtx, NULL_CTX  # noqa: F401
