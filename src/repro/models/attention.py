"""GQA attention: blockwise (flash-style) training/prefill path, direct decode
path, sliding-window structural skipping for local layers.

All paths are pure jnp/lax so GSPMD can shard them; the Pallas window-attention
kernel in ``repro.kernels.window_attn`` is a drop-in for the local path on TPU.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_rope
from repro.models.params import ParamFactory

NEG_INF = -1e30


def init_attention(fac: ParamFactory, cfg: ModelConfig, cross: bool = False):
    d, h, kv, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    name = "xattn" if cross else "attn"
    with fac.scope(name):
        return {
            "wq": fac.param("wq", (d, h, hd), ("embed", "heads", "head_dim")),
            "wk": fac.param("wk", (d, kv, hd), ("embed", "kv_heads", "head_dim")),
            "wv": fac.param("wv", (d, kv, hd), ("embed", "kv_heads", "head_dim")),
            "wo": fac.param("wo", (h, hd, d), ("heads", "head_dim", "embed"),
                            in_dims=2),
        }


def _group(q, num_kv):
    """(B,S,H,hd) -> (B,S,KV,G,hd)."""
    b, s, h, hd = q.shape
    return q.reshape(b, s, num_kv, h // num_kv, hd)


def _mask_bias(q_pos, kv_pos, causal: bool, window: int) -> jnp.ndarray:
    """(…q, …kv) -> additive bias. kv_pos < 0 marks unfilled cache slots."""
    ok = kv_pos[None, :] >= 0
    if causal:
        ok &= kv_pos[None, :] <= q_pos[:, None]
    if window and window > 0:
        ok &= kv_pos[None, :] > q_pos[:, None] - window
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def blockwise_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        q_offset: int = 0, kv_positions: Optional[jnp.ndarray] = None,
                        block_q: int = 512, block_kv: int = 512) -> jnp.ndarray:
    """Flash-style attention with online softmax.

    q: (B, Sq, H, hd); k, v: (B, Skv, KV, hd). Returns (B, Sq, H, hd).
    O(block_q x block_kv) score memory. Full-compute + mask (the Pallas kernel
    and the local path below do the structural skipping).
    """
    b, sq, h, hd = q.shape
    skv, nkv = k.shape[1], k.shape[2]
    g = h // nkv
    scale = hd ** -0.5

    bq = min(block_q, sq)
    bkv = min(block_kv, skv)
    # pad to multiples
    pq = (-sq) % bq
    pkv = (-skv) % bkv
    q_pos = q_offset + jnp.arange(sq + pq, dtype=jnp.int32)
    if kv_positions is None:
        kv_positions = jnp.arange(skv, dtype=jnp.int32)
    kv_pos = jnp.concatenate([kv_positions,
                              jnp.full((pkv,), -1, jnp.int32)]) if pkv else kv_positions
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    if pkv:
        k = jnp.pad(k, ((0, 0), (0, pkv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pkv), (0, 0), (0, 0)))

    nq, nk = (sq + pq) // bq, (skv + pkv) // bkv
    qb = q.reshape(b, nq, bq, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)   # (nq,B,bq,KV,G,hd)
    kb = k.reshape(b, nk, bkv, nkv, hd).transpose(1, 0, 2, 3, 4)        # (nk,B,bkv,KV,hd)
    vb = v.reshape(b, nk, bkv, nkv, hd).transpose(1, 0, 2, 3, 4)
    qpb = q_pos.reshape(nq, bq)
    kpb = kv_pos.reshape(nk, bkv)

    def q_block(carry, qi):
        qcur, qp = qi

        def kv_block(state, ki):
            m, l, acc = state
            kcur, vcur, kp = ki
            s = jnp.einsum("bqkgd,bskd->bkgqs", qcur.astype(jnp.float32),
                           kcur.astype(jnp.float32)) * scale
            s = s + _mask_bias(qp, kp, causal, window)[None, None, None]
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p, vcur.astype(jnp.float32))
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, nkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, nkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, nkv, g, bq, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_block, (m0, l0, a0), (kb, vb, kpb))
        out = acc / jnp.maximum(l[..., None], 1e-30)                    # (B,KV,G,bq,hd)
        return carry, out.transpose(0, 3, 1, 2, 4)                      # (B,bq,KV,G,hd)

    _, outs = jax.lax.scan(q_block, None, (qb, qpb))                    # (nq,B,bq,KV,G,hd)
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, hd)
    return out[:, :sq].astype(q.dtype)


def local_blockwise_attention(q, k, v, *, window: int, q_offset: int = 0,
                              block_q: int = 512) -> jnp.ndarray:
    """Sliding-window attention with STRUCTURAL skipping: each q block only
    attends to a dynamically-sliced kv span of length window+block_q, so the
    compute is O(S*(window+block_q)) instead of O(S^2).

    q: (B,S,H,hd); k,v: (B,S,KV,hd) (self-attention, aligned positions).
    """
    b, s, h, hd = q.shape
    nkv = k.shape[2]
    g = h // nkv
    scale = hd ** -0.5
    bq = min(block_q, s)
    pq = (-s) % bq
    if pq:
        q = jnp.pad(q, ((0, 0), (0, pq), (0, 0), (0, 0)))
    nq = (s + pq) // bq
    span = ((window + bq + bq - 1) // bq) * bq  # kv span per q block, multiple of bq
    # left-pad kv by span so every slice is in-bounds; padded slots get pos -1
    k_pad = jnp.pad(k, ((0, 0), (span, pq), (0, 0), (0, 0)))
    v_pad = jnp.pad(v, ((0, 0), (span, pq), (0, 0), (0, 0)))
    kv_pos_pad = jnp.concatenate([
        jnp.full((span,), -1, jnp.int32),
        jnp.arange(s + pq, dtype=jnp.int32),
    ])
    qb = q.reshape(b, nq, bq, nkv, g, hd).transpose(1, 0, 2, 3, 4, 5)

    def q_block(carry, xs):
        qcur, i = xs
        start = i * bq  # kv span = [start - span, start + bq) in padded coords
        kcur = jax.lax.dynamic_slice_in_dim(k_pad, start, span + bq, axis=1)
        vcur = jax.lax.dynamic_slice_in_dim(v_pad, start, span + bq, axis=1)
        kp = jax.lax.dynamic_slice_in_dim(kv_pos_pad, start, span + bq, axis=0)
        qp = q_offset + start + jnp.arange(bq, dtype=jnp.int32)
        s_ = jnp.einsum("bqkgd,bskd->bkgqs", qcur.astype(jnp.float32),
                        kcur.astype(jnp.float32)) * scale
        bias = jnp.where(
            (kp[None, :] >= 0) & (kp[None, :] <= qp[:, None])
            & (kp[None, :] > qp[:, None] - window),
            0.0, NEG_INF).astype(jnp.float32)
        s_ = s_ + bias[None, None, None]
        p = jax.nn.softmax(s_, axis=-1)
        out = jnp.einsum("bkgqs,bskd->bqkgd", p, vcur.astype(jnp.float32))
        return carry, out

    idx = jnp.arange(nq, dtype=jnp.int32)
    _, outs = jax.lax.scan(q_block, None, (qb, idx))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, nq * bq, h, hd)
    return out[:, :s].astype(q.dtype)


def decode_attention(q, k_cache, v_cache, kv_positions, *, window: int = 0) -> jnp.ndarray:
    """Single-token decode: q (B,1,H,hd) vs cache (B,S,KV,hd).

    kv_positions: (S,) or (B,S) int32 — original token position of each cache
    slot, -1 for unfilled. Works with ring-buffer (window) caches, where slot
    order is not position order.
    """
    b, sq, h, hd = q.shape
    nkv = k_cache.shape[2]
    g = h // nkv
    scale = hd ** -0.5
    qg = q.reshape(b, sq, nkv, g, hd)
    s = jnp.einsum("bqkgd,bskd->bkgqs", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if kv_positions.ndim == 1:
        kv_positions = kv_positions[None].repeat(b, axis=0)
    ok = kv_positions >= 0                                        # (B,S)
    # q position = max cache position + 1 (the token being generated attends
    # to everything already in the cache)
    bias = jnp.where(ok, 0.0, NEG_INF)[:, None, None, None, :]
    s = s + bias
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgqs,bskd->bqkgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, sq, h, hd).astype(q.dtype)


def causal_skip_attention(q, k, v, *, window: int = 0, block_q: int = 0,
                          block_kv: int = 512) -> jnp.ndarray:
    """Causal attention with STRUCTURAL block skipping (§Perf): query block i
    only touches kv blocks 0..i, so compute/HBM is the true triangle
    (~half of the masked-full baseline). The q loop is unrolled (few, large
    blocks); each q block runs an online-softmax scan over its prefix.

    q, k, v aligned self-attention: (B,S,H,hd)/(B,S,KV,hd).
    """
    b, s, h, hd = q.shape
    if block_q == 0:
        block_q = max(s // 16, 512)         # <=16 unrolled q blocks
    bq = min(block_q, s)
    if s % bq or s % block_kv:
        # fall back for ragged shapes
        return blockwise_attention(q, k, v, causal=True, window=window)
    nq = s // bq
    outs = []
    for i in range(nq):
        qi = q[:, i * bq:(i + 1) * bq]
        end = (i + 1) * bq
        o = blockwise_attention(qi, k[:, :end], v[:, :end], causal=True,
                                window=window, q_offset=i * bq,
                                block_q=bq, block_kv=block_kv)
        outs.append(o)
    return jnp.concatenate(outs, axis=1)


def attention_block(p, x, cfg: ModelConfig, *, kind: str = "global",
                    q_offset: int = 0, positions: Optional[jnp.ndarray] = None):
    """Full attention layer for train/prefill: qkv proj + rope + attention + out."""
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhe->bshe", x, p["wq"])
    k = jnp.einsum("bsd,dke->bske", x, p["wk"])
    v = jnp.einsum("bsd,dke->bske", x, p["wv"])
    if positions is None:
        positions = q_offset + jnp.arange(s, dtype=jnp.int32)[None]
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    if kind == "local" and cfg.sliding_window and s > cfg.sliding_window:
        o = local_blockwise_attention(q, k, v, window=cfg.sliding_window,
                                      q_offset=q_offset)
    else:
        window = cfg.sliding_window if kind == "local" else 0
        o = blockwise_attention(q, k, v, causal=True, window=window,
                                q_offset=q_offset)
    return jnp.einsum("bshe,hed->bsd", o, p["wo"])
