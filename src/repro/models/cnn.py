"""The paper's CNN classifier: 2 conv + 2 pool + 2 fully-connected layers
(Sec 5.1), used for the MNIST / Fashion-MNIST / CIFAR-10 experiments.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamFactory


def init_cnn(fac: ParamFactory, cfg: ModelConfig):
    c1, c2 = cfg.cnn_channels
    # after two 2x2 pools the spatial dim is image_size // 4
    flat = (cfg.image_size // 4) ** 2 * c2
    with fac.scope("cnn"):
        return {
            "conv1": fac.param("conv1", (3, 3, cfg.image_channels, c1),
                               (None, None, None, "mlp"), scale=1.4, in_dims=3),
            "b1": fac.param("b1", (c1,), ("mlp",), init="zeros"),
            "conv2": fac.param("conv2", (3, 3, c1, c2), (None, None, None, "mlp"),
                               scale=1.4, in_dims=3),
            "b2": fac.param("b2", (c2,), ("mlp",), init="zeros"),
            "fc1": fac.param("fc1", (flat, cfg.d_model), (None, "mlp")),
            "fb1": fac.param("fb1", (cfg.d_model,), ("mlp",), init="zeros"),
            "fc2": fac.param("fc2", (cfg.d_model, cfg.num_classes), ("mlp", None)),
            "fb2": fac.param("fb2", (cfg.num_classes,), (None,), init="zeros"),
        }


def _conv(x, w, b):
    y = jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return y + b


def _pool(x):
    return jax.lax.reduce_window(x, -jnp.inf, jax.lax.max,
                                 (1, 2, 2, 1), (1, 2, 2, 1), "VALID")


def cnn_forward(params, images):
    """images: (B, H, W, C) -> logits (B, num_classes)."""
    x = jax.nn.relu(_conv(images, params["conv1"], params["b1"]))
    x = _pool(x)
    x = jax.nn.relu(_conv(x, params["conv2"], params["b2"]))
    x = _pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["fb1"])
    return x @ params["fc2"] + params["fb2"]
