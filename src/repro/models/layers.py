"""Shared layers: norms, gated MLP, embeddings, RoPE."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamFactory

ACTS = {
    "silu": jax.nn.silu,
    "gelu": jax.nn.gelu,
    "relu": jax.nn.relu,
}


def pad_vocab(vocab: int, multiple: int = 512) -> int:
    """Pad vocab to a mesh/MXU-friendly multiple (production-style)."""
    return ((vocab + multiple - 1) // multiple) * multiple


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def init_norm(fac: ParamFactory, cfg: ModelConfig, name: str):
    if cfg.norm_type == "nonparametric":
        return {}
    return {"scale": fac.param(f"{name}.scale", (cfg.d_model,), ("embed",), init="ones")}


def apply_norm(p, x, cfg: ModelConfig, eps: float = 1e-6):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    if cfg.norm_type == "layernorm" or cfg.norm_type == "nonparametric":
        mu = jnp.mean(x32, axis=-1, keepdims=True)
        var = jnp.mean(jnp.square(x32 - mu), axis=-1, keepdims=True)
        y = (x32 - mu) * jax.lax.rsqrt(var + eps)
    else:  # rmsnorm
        var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
        y = x32 * jax.lax.rsqrt(var + eps)
    if p:
        y = y * p["scale"].astype(jnp.float32)
    return y.astype(dtype)


# ---------------------------------------------------------------------------
# Gated MLP
# ---------------------------------------------------------------------------

def init_mlp(fac: ParamFactory, cfg: ModelConfig, d_ff: Optional[int] = None):
    d_ff = d_ff or cfg.d_ff
    with fac.scope("mlp"):
        return {
            "wi_gate": fac.param("wi_gate", (cfg.d_model, d_ff), ("embed", "mlp")),
            "wi_up": fac.param("wi_up", (cfg.d_model, d_ff), ("embed", "mlp")),
            "wo": fac.param("wo", (d_ff, cfg.d_model), ("mlp", "embed")),
        }


def apply_mlp(p, x, cfg: ModelConfig):
    act = ACTS[cfg.act]
    h = act(x @ p["wi_gate"]) * (x @ p["wi_up"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(fac: ParamFactory, cfg: ModelConfig):
    v = pad_vocab(cfg.vocab_size)
    with fac.scope("embed"):
        p = {"table": fac.param("table", (v, cfg.d_model), ("vocab", "embed"), scale=1.0)}
        if not cfg.tie_embeddings:
            p["unembed"] = fac.param("unembed", (cfg.d_model, v), ("embed", "vocab"))
    return p


def apply_embed(p, tokens, cfg: ModelConfig):
    return jnp.take(p["table"], tokens, axis=0)


def apply_unembed(p, x, cfg: ModelConfig):
    v = pad_vocab(cfg.vocab_size)
    if cfg.tie_embeddings:
        logits = x @ p["table"].T
    else:
        logits = x @ p["unembed"]
    if cfg.logit_softcap:
        logits = jnp.tanh(logits / cfg.logit_softcap) * cfg.logit_softcap
    # mask padded vocab entries
    mask = jnp.arange(v) < cfg.vocab_size
    return jnp.where(mask, logits, -1e9)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: (..., seq, heads, head_dim); positions: (..., seq)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                      # (hd/2,)
    ang = positions[..., :, None].astype(jnp.float32) * freqs  # (..., seq, hd/2)
    cos = jnp.cos(ang)[..., None, :]                   # (..., seq, 1, hd/2)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)
