"""Mamba selective-SSM block (jamba's recurrent layer).

Diagonal-A selective scan, evaluated in time chunks: ``lax.scan`` over chunks
carrying the (B, d_inner, n) state, with an associative scan inside each chunk
(log-depth on the MXU-friendly chunk). Decode is a single recurrence step.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamFactory


def d_inner(cfg: ModelConfig) -> int:
    return cfg.ssm_expand * cfg.d_model


def dt_rank(cfg: ModelConfig) -> int:
    return max(cfg.d_model // 16, 1)


def init_mamba(fac: ParamFactory, cfg: ModelConfig):
    d, di, n, r, w = cfg.d_model, d_inner(cfg), cfg.ssm_state_dim, dt_rank(cfg), cfg.ssm_conv_width
    with fac.scope("mamba"):
        return {
            "in_proj": fac.param("in_proj", (d, 2 * di), ("embed", "mlp")),
            "conv_w": fac.param("conv_w", (w, di), (None, "mlp"), scale=0.5),
            "conv_b": fac.param("conv_b", (di,), ("mlp",), init="zeros"),
            "x_proj": fac.param("x_proj", (di, r + 2 * n), ("mlp", None)),
            "dt_proj": fac.param("dt_proj", (r, di), (None, "mlp")),
            "dt_bias": fac.param("dt_bias", (di,), ("mlp",), init="constant", scale=-2.0),
            # log(-A): A = -exp(a_log); init A ~ -[1..n]
            "a_log": fac.param("a_log", (di, n), ("mlp", None), init="uniform", scale=1.5),
            "d_skip": fac.param("d_skip", (di,), ("mlp",), init="ones"),
            "out_proj": fac.param("out_proj", (di, d), ("mlp", "embed")),
        }


def _conv1d_causal(x, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv. x: (B,S,di); conv_w: (w,di).

    conv_state: (B, w-1, di) previous inputs for decode continuity.
    Returns (y, new_state).
    """
    w = conv_w.shape[0]
    if conv_state is None:
        conv_state = jnp.zeros((x.shape[0], w - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([conv_state, x], axis=1)                  # (B, S+w-1, di)
    y = sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(w))
    new_state = xp[:, -(w - 1):] if w > 1 else conv_state
    return y + conv_b, new_state


def _ssm_params(p, x, cfg: ModelConfig):
    """x: (B,T,di) -> dt (B,T,di), B_ (B,T,n), C_ (B,T,n)."""
    n, r = cfg.ssm_state_dim, dt_rank(cfg)
    xdb = x @ p["x_proj"]
    dt_lo, b_, c_ = jnp.split(xdb, [r, r + n], axis=-1)
    dt = jax.nn.softplus(dt_lo @ p["dt_proj"] + p["dt_bias"].astype(xdb.dtype))
    return dt, b_, c_


def _chunk_scan(a, b, h0):
    """Linear recurrence h_t = a_t * h_{t-1} + b_t within a chunk.

    a, b: (B, T, di, n); h0: (B, di, n). Returns (h_all (B,T,di,n), h_last).
    """
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    a_c, b_c = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_all = b_c + a_c * h0[:, None]
    return h_all, h_all[:, -1]


def mamba_scan(p, x, cfg: ModelConfig, h0=None, chunk: int = 16):
    """Selective scan over (B,S,di) post-conv activations. Returns (y, h_last)."""
    bsz, s, di = x.shape
    n = cfg.ssm_state_dim
    a = -jnp.exp(p["a_log"].astype(jnp.float32))                   # (di, n)
    if h0 is None:
        h0 = jnp.zeros((bsz, di, n), jnp.float32)

    if cfg.mamba_impl == "pallas":
        # fused TPU kernel (see EXPERIMENTS.md §Perf pair 3): keeps the
        # (chunk, di, n) recurrence tensors in VMEM instead of HBM.
        from repro.kernels.ssm_scan.ops import ssm_scan
        dt, b_, c_ = _ssm_params(p, x, cfg)
        y, h_last = ssm_scan(dt, b_, c_, x, a, h0)
        y = y + x.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
        return y.astype(x.dtype), h_last
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // c
    xc = x.reshape(bsz, nc, c, di).transpose(1, 0, 2, 3)           # (nc,B,c,di)

    # §Perf knob: the (B,c,di,n) chunk tensors dominate HBM for hybrid models;
    # bf16 halves that traffic. The carried state h stays f32 (the recurrence
    # products are where precision matters across 32k+ steps).
    chunk_dt = jnp.dtype(cfg.ssm_chunk_dtype)

    def body(h, xcur):
        dt, b_, c_ = _ssm_params(p, xcur, cfg)                     # (B,c,di),(B,c,n)
        dt32 = dt.astype(jnp.float32)
        abar = jnp.exp(dt32[..., None] * a).astype(chunk_dt)       # (B,c,di,n)
        bu = (dt32[..., None] * b_.astype(jnp.float32)[..., None, :]
              * xcur.astype(jnp.float32)[..., None]).astype(chunk_dt)
        h_all, h_last = _chunk_scan(abar, bu, h.astype(chunk_dt))
        y = jnp.einsum("bcn,bcdn->bcd", c_.astype(chunk_dt), h_all)
        y = y.astype(jnp.float32) \
            + xcur.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
        return h_last.astype(jnp.float32), y.astype(x.dtype)

    h_last, ys = jax.lax.scan(body, h0, xc)                        # ys: (nc,B,c,di)
    y = ys.transpose(1, 0, 2, 3).reshape(bsz, s + pad, di)[:, :s]
    return y, h_last


def mamba_block(p, x, cfg: ModelConfig, state: Tuple = None):
    """Full block. x: (B,S,d). state = (conv_state, ssm_state) or None (train).

    Returns (y, new_state).
    """
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    conv_state = state[0] if state is not None else None
    h0 = state[1] if state is not None else None
    xc, new_conv = _conv1d_causal(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)
    y, h_last = mamba_scan(p, xc, cfg, h0=h0)
    y = y * jax.nn.silu(z)
    out = y @ p["out_proj"]
    return out, (new_conv, h_last)


def mamba_decode_step(p, x, cfg: ModelConfig, state):
    """x: (B,1,d); state = (conv_state (B,w-1,di), h (B,di,n))."""
    conv_state, h = state
    xz = x @ p["in_proj"]
    xin, z = jnp.split(xz, 2, axis=-1)
    xc, new_conv = _conv1d_causal(xin, p["conv_w"], p["conv_b"], conv_state)
    xc = jax.nn.silu(xc)                                           # (B,1,di)
    dt, b_, c_ = _ssm_params(p, xc, cfg)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    dt32 = dt[:, 0].astype(jnp.float32)                            # (B,di)
    abar = jnp.exp(dt32[..., None] * a)                            # (B,di,n)
    bu = dt32[..., None] * b_[:, 0].astype(jnp.float32)[:, None, :] \
        * xc[:, 0].astype(jnp.float32)[..., None]
    h_new = abar * h + bu
    y = jnp.einsum("bn,bdn->bd", c_[:, 0].astype(jnp.float32), h_new)
    y = y + xc[:, 0].astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = (y[:, None].astype(x.dtype)) * jax.nn.silu(z)
    return y @ p["out_proj"], (new_conv, h_new)
