"""Top-level model API: init / loss / serve, uniform across families.

``init_params(cfg, rng)``        -> param pytree (real arrays)
``param_axes(cfg)``              -> parallel pytree of logical-axis tuples
``abstract_params(cfg, dtype)``  -> ShapeDtypeStruct pytree (no allocation)
``loss_fn(cfg)(params, batch)``  -> (loss, metrics)  [train objective]
``prefill_fn(cfg)``, ``decode_fn(cfg)`` for serving.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import transformer as tfm
from repro.models.cnn import cnn_forward, init_cnn
from repro.models.params import AxesOnly, ParamFactory, RealInit, ShapeOnly
from repro.models.transformer import NULL_CTX, ShardCtx


def _init(cfg: ModelConfig, fac: ParamFactory):
    if cfg.family == "cnn":
        return init_cnn(fac, cfg)
    return tfm.init_lm(fac, cfg)


def init_params(cfg: ModelConfig, rng: Optional[jax.Array] = None):
    rng = rng if rng is not None else jax.random.key(0)
    return _init(cfg, RealInit(rng, jnp.dtype(cfg.param_dtype)))


def param_axes(cfg: ModelConfig):
    return _init(cfg, AxesOnly())


def abstract_params(cfg: ModelConfig, dtype=None):
    return _init(cfg, ShapeOnly(jnp.dtype(dtype or cfg.param_dtype)))


def num_params(params) -> int:
    return sum(int(jnp.size(p)) if hasattr(p, "size") else 0
               for p in jax.tree.leaves(params))


# ---------------------------------------------------------------------------
# Objectives
# ---------------------------------------------------------------------------

def _xent(logits, labels, ignore: int = -100):
    """Token cross-entropy with label masking. logits (B,S,V), labels (B,S)."""
    valid = labels != ignore
    safe = jnp.where(valid, labels, 0)
    logz = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(logits.astype(jnp.float32), safe[..., None],
                               axis=-1)[..., 0]
    nll = (logz - gold) * valid
    return nll.sum() / jnp.maximum(valid.sum(), 1)


def loss_fn(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX, remat: str = "block"):
    """Returns fn(params, batch) -> (loss, metrics)."""
    if cfg.family == "cnn":
        def cnn_loss(params, batch):
            logits = cnn_forward(params, batch["images"])
            labels = batch["labels"]
            ll = jax.nn.log_softmax(logits.astype(jnp.float32))
            loss = -jnp.take_along_axis(ll, labels[:, None], axis=-1).mean()
            acc = (logits.argmax(-1) == labels).mean()
            return loss, {"loss": loss, "acc": acc}
        return cnn_loss

    def lm_loss(params, batch):
        logits, aux = tfm.forward_train(params, cfg, batch, ctx, remat=remat)
        loss = _xent(logits, batch["labels"]) + aux
        return loss, {"loss": loss, "aux": aux}

    return lm_loss


def predict_fn(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
    """Forward producing logits (no loss) — used by prefill shape + MIA eval."""
    if cfg.family == "cnn":
        return lambda params, batch: cnn_forward(params, batch["images"])

    def fwd(params, batch):
        logits, _ = tfm.forward_train(params, cfg, batch, ctx, remat="none")
        return logits

    return fwd


def prefill_fn(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX,
               max_len: Optional[int] = None):
    return functools.partial(_prefill, cfg, ctx, max_len)


def _prefill(cfg, ctx, max_len, params, batch):
    return tfm.forward_prefill(params, cfg, batch, ctx, max_len=max_len)


def decode_fn(cfg: ModelConfig, ctx: ShardCtx = NULL_CTX):
    def step(params, tokens, cache):
        return tfm.forward_decode(params, cfg, tokens, cache, ctx)
    return step


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=None,
               enc_len: int = 0):
    return tfm.init_cache(cfg, batch, cache_len,
                          dtype=jnp.dtype(dtype or cfg.compute_dtype),
                          enc_len=enc_len)
