"""Mixture-of-Experts FFN: top-k router + capacity-based one-hot dispatch.

TPU-native design: dispatch/combine are einsums against one-hot tensors so the
whole layer is MXU matmuls; experts live on the ``expert`` logical axis
(sharded over ``model``), which makes the dispatch an explicit all-to-all in
the lowered HLO — exactly the collective the roofline wants to see.

Tokens are routed within fixed-size groups (``group_size``) so dispatch cost
is O(S * group * k) rather than O(S^2 * k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import ACTS
from repro.models.params import ParamFactory


def init_moe(fac: ParamFactory, cfg: ModelConfig):
    d, e, f = cfg.d_model, cfg.num_experts, cfg.moe_d_ff
    with fac.scope("moe"):
        return {
            "router": fac.param("router", (d, e), ("embed", "expert_router")),
            "wi_gate": fac.param("wi_gate", (e, d, f), ("expert", "embed", "mlp"),
                                 fan_in=d),
            "wi_up": fac.param("wi_up", (e, d, f), ("expert", "embed", "mlp"),
                               fan_in=d),
            "wo": fac.param("wo", (e, f, d), ("expert", "mlp", "embed"),
                            fan_in=f),
        }


def _route(p, xg, cfg: ModelConfig, cap: int):
    """Shared router: returns (gate_vals, expert_idx, pos_in_expert, keep, aux).

    pos_in_expert: (N,T,k) slot of each (token, k-choice) in its expert's
    capacity buffer (token-major priority, overflow dropped via ``keep``).
    """
    e, k = cfg.num_experts, cfg.experts_per_token
    n, g, _ = xg.shape
    logits = (xg.astype(jnp.float32) @ p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)                # (N,T,k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    onehot = jax.nn.one_hot(expert_idx, e, dtype=jnp.float32)      # (N,T,k,E)
    flat = onehot.reshape(n, g * k, e)                             # token-major
    pos = (jnp.cumsum(flat, axis=1) - 1.0).reshape(n, g, k, e)
    pos_in_expert = jnp.sum(pos * onehot, axis=-1).astype(jnp.int32)  # (N,T,k)
    keep = pos_in_expert < cap
    me = probs.mean(axis=(0, 1))
    ce = onehot.sum(axis=2).mean(axis=(0, 1)) / k
    aux = cfg.router_aux_weight * e * jnp.sum(me * ce)
    return gate_vals, expert_idx, pos_in_expert, keep, onehot, aux


def _moe_einsum(p, xg, cfg: ModelConfig, cap: int, ctx=None):
    """Paper-baseline one-hot dispatch: materialises (N,T,E,C) dispatch/
    combine tensors. §Perf-optimized from the naive form: the k dim is
    contracted INSIDE the einsum (never materialising (N,T,k,E,C)) and the
    one-hots are compute-dtype, not f32."""
    e, k = cfg.num_experts, cfg.experts_per_token
    act = ACTS[cfg.act]
    cdt = jnp.dtype(cfg.compute_dtype)
    gate_vals, expert_idx, pos, keep, onehot, aux = _route(p, xg, cfg, cap)
    pos_oh = jnp.where(keep[..., None],
                       jax.nn.one_hot(pos, cap, dtype=cdt), 0)     # (N,T,k,C)
    oh = onehot.astype(cdt)
    dispatch_t = jnp.einsum("ntke,ntkc->ntec", oh, pos_oh)         # (N,T,E,C)
    combine_t = jnp.einsum("ntke,ntkc,ntk->ntec", oh, pos_oh,
                           gate_vals.astype(cdt))
    expert_in = jnp.einsum("ntec,ntd->ecnd", dispatch_t,
                           xg.astype(cdt))                         # (E,C,N,d)
    if ctx is not None:
        expert_in = ctx.constrain(expert_in, ("expert", None, "moe_group", None))
    h = act(jnp.einsum("ecnd,edf->ecnf", expert_in, p["wi_gate"])) * \
        jnp.einsum("ecnd,edf->ecnf", expert_in, p["wi_up"])
    expert_out = jnp.einsum("ecnf,efd->ecnd", h, p["wo"])          # (E,C,N,d)
    if ctx is not None:
        expert_out = ctx.constrain(expert_out, ("expert", None, "moe_group", None))
    yg = jnp.einsum("ntec,ecnd->ntd", combine_t, expert_out)
    return yg, aux


def _moe_gather(p, xg, cfg: ModelConfig, cap: int, ctx=None):
    """Index-based dispatch (beyond-paper §Perf optimization): the one-hot
    tensors are replaced by O(T*k) integer indices + gathers, so dispatch HBM
    traffic is ~(k/E*cf) of the einsum path's. Routing identical to _route.

    Gathers stay LOCAL to each token group (indices < T), so sharding over
    the batch/group dim is preserved; the expert dim materialises sharded over
    ``model`` via the expert-weight einsum (all-to-all in HLO, as expected).
    """
    e, k = cfg.num_experts, cfg.experts_per_token
    act = ACTS[cfg.act]
    n, g, d = xg.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    gate_vals, expert_idx, pos, keep, _onehot, aux = _route(p, xg, cfg, cap)

    # slot id of each (token, k) in the flattened (E*C) buffer; dropped -> E*C
    slot = jnp.where(keep, expert_idx * cap + pos, e * cap)        # (N,T,k)
    # token id feeding each buffer slot: scatter token ids into (N, E*C+1)
    tok_ids = jnp.broadcast_to(jnp.arange(g, dtype=jnp.int32)[None, :, None],
                               slot.shape)                          # (N,T,k)
    src = jnp.full((n, e * cap + 1), g, jnp.int32)                 # g = pad row
    src = src.at[jnp.arange(n)[:, None, None], slot].set(tok_ids, mode="drop")
    buf_tok = src[:, : e * cap]                                    # (N, E*C)
    xg_pad = jnp.concatenate([xg.astype(cdt),
                              jnp.zeros((n, 1, d), cdt)], axis=1)  # pad row
    expert_in = jnp.take_along_axis(xg_pad, buf_tok[..., None],
                                    axis=1)                        # (N,E*C,d)
    expert_in = expert_in.reshape(n, e, cap, d).transpose(1, 2, 0, 3)  # (E,C,N,d)
    if ctx is not None:
        expert_in = ctx.constrain(expert_in, ("expert", None, "moe_group", None))

    h = act(jnp.einsum("ecnd,edf->ecnf", expert_in, p["wi_gate"])) * \
        jnp.einsum("ecnd,edf->ecnf", expert_in, p["wi_up"])
    expert_out = jnp.einsum("ecnf,efd->ecnd", h, p["wo"])          # (E,C,N,d)
    if ctx is not None:
        expert_out = ctx.constrain(expert_out, ("expert", None, "moe_group", None))

    # combine: gather each (token, k)'s slot output, weight by gate
    flat_out = expert_out.transpose(2, 0, 1, 3).reshape(n, e * cap, d)
    if ctx is not None:
        flat_out = ctx.constrain(flat_out, ("moe_group", None, None))
    flat_out = jnp.concatenate([flat_out, jnp.zeros((n, 1, d), flat_out.dtype)],
                               axis=1)
    slot_c = jnp.minimum(slot, e * cap)                            # dropped -> 0 row
    picked = jnp.take_along_axis(flat_out,
                                 slot_c.reshape(n, g * k)[..., None], axis=1)
    picked = picked.reshape(n, g, k, d)
    yg = jnp.einsum("ntk,ntkd->ntd", gate_vals.astype(cdt) *
                    keep.astype(cdt), picked)
    return yg, aux


def apply_moe(p, x, cfg: ModelConfig, *, group_size: int = 512,
              capacity_factor: float = None, ctx=None):
    """x: (B, S, d) -> (y, aux_loss)."""
    capacity_factor = capacity_factor or cfg.moe_capacity_factor
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    g = min(group_size, s)
    pad = (-s) % g
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
    ng = (s + pad) // g
    xg = x.reshape(b * ng, g, d)                                   # (N, T, d)
    if ctx is not None:
        xg = ctx.constrain(xg, ("moe_group", None, None))
    cap = max(int(g * k / e * capacity_factor), 4)
    impl = _moe_gather if cfg.moe_impl == "gather" else _moe_einsum
    yg, aux = impl(p, xg, cfg, cap, ctx=ctx)
    y = yg.reshape(b, s + pad, d)[:, :s].astype(x.dtype)
    return y, aux
