"""Parameter factories: one init code path, three interpretations.

``RealInit``  -> actual jnp arrays (deterministic per-path RNG folding)
``AxesOnly``  -> logical-axis tuples mirroring the param tree
``ShapeOnly`` -> jax.ShapeDtypeStruct leaves (dry-run, no allocation)

plus ``spec_for`` which maps logical axes -> a divisibility-checked
PartitionSpec under a rule table.
"""
from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


class ParamFactory:
    """Base: subclasses interpret .param() calls."""

    def param(self, name: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
              init: str = "normal", scale: float = 1.0, in_dims: int = 1):
        raise NotImplementedError

    # scoping ---------------------------------------------------------------
    def __init__(self):
        self._path = []

    def scope(self, name: str) -> "_Scope":
        return _Scope(self, name)

    @property
    def path(self) -> str:
        return "/".join(self._path)


class WrappedFactory(ParamFactory):
    """Base for factory decorators — forwards everything by default."""

    def __init__(self, fac: ParamFactory):
        self.fac = fac
        self._path = fac._path

    def param(self, name, shape, axes, init="normal", scale=1.0, in_dims=1,
              fan_in=None):
        return self.fac.param(name, shape, axes, init=init, scale=scale,
                              in_dims=in_dims, fan_in=fan_in)


class _Scope:
    def __init__(self, fac: ParamFactory, name: str):
        self.fac, self.name = fac, name

    def __enter__(self):
        self.fac._path.append(self.name)
        return self.fac

    def __exit__(self, *exc):
        self.fac._path.pop()


class RealInit(ParamFactory):
    def __init__(self, rng: jax.Array, dtype=jnp.float32):
        super().__init__()
        self.rng = rng
        self.dtype = dtype

    def param(self, name, shape, axes, init="normal", scale=1.0, in_dims=1,
              fan_in=None):
        assert len(shape) == len(axes), (self.path, name, shape, axes)
        key = jax.random.fold_in(self.rng, _stable_hash(self.path + "/" + name))
        if init == "normal":
            if fan_in is None:
                fan_in = (int(np.prod(shape[:in_dims])) if len(shape) > 1
                          else max(shape[-1], 1))
            std = scale / np.sqrt(fan_in)
            return (jax.random.normal(key, shape, jnp.float32) * std).astype(self.dtype)
        if init == "zeros":
            return jnp.zeros(shape, self.dtype)
        if init == "ones":
            return jnp.ones(shape, self.dtype)
        if init == "uniform":  # U[0, scale)
            return (jax.random.uniform(key, shape, jnp.float32) * scale).astype(self.dtype)
        if init == "constant":
            return jnp.full(shape, scale, self.dtype)
        raise ValueError(init)


class AxesOnly(ParamFactory):
    def param(self, name, shape, axes, init="normal", scale=1.0, in_dims=1,
              fan_in=None):
        assert len(shape) == len(axes)
        return tuple(axes)


class ShapeOnly(ParamFactory):
    def __init__(self, dtype=jnp.bfloat16):
        super().__init__()
        self.dtype = dtype

    def param(self, name, shape, axes, init="normal", scale=1.0, in_dims=1,
              fan_in=None):
        return jax.ShapeDtypeStruct(shape, self.dtype)


def _stable_hash(s: str) -> int:
    h = 2166136261
    for ch in s.encode():
        h = ((h ^ ch) * 16777619) & 0xFFFFFFFF
    return h


# ---------------------------------------------------------------------------
# logical axes -> PartitionSpec
# ---------------------------------------------------------------------------

def spec_for(shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
             rules: Dict[str, Tuple[str, ...]], mesh: Mesh) -> P:
    """Greedy, divisibility-checked mapping of logical axes to mesh axes.

    ``rules[logical]`` is an ordered tuple of candidates; each candidate is a
    mesh-axis name or a tuple of names (the dim shards over their product).
    The first candidate that (a) divides the dim and (b) does not reuse a mesh
    axis already taken by another dim of this param wins. Dims with no viable
    candidate stay replicated.
    """
    used = set()
    out = []
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for dim, logical in zip(shape, axes):
        assigned = None
        for cand in rules.get(logical, ()):  # type: ignore[arg-type]
            if cand is None:
                continue
            names = (cand,) if isinstance(cand, str) else tuple(cand)
            if any(n in used or n not in sizes for n in names):
                continue
            total = 1
            for n in names:
                total *= sizes[n]
            if dim % total == 0 and dim >= total:
                assigned = cand if isinstance(cand, str) else tuple(cand)
                used.update(names)
                break
        out.append(assigned)
    # trim trailing Nones for tidiness
    while out and out[-1] is None:
        out.pop()
    return P(*out)


def tree_shardings(params_axes, params_shapes, rules, mesh):
    """Build a NamedSharding pytree parallel to the param tree."""
    def one(axes, arr):
        shape = arr.shape if hasattr(arr, "shape") else arr
        return NamedSharding(mesh, spec_for(tuple(shape), axes, rules, mesh))
    return jax.tree.map(one, params_axes, params_shapes,
                        is_leaf=lambda x: isinstance(x, tuple) and all(
                            isinstance(a, (str, type(None))) for a in x))
