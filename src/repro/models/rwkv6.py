"""RWKV-6 "Finch" block: data-dependent-decay linear attention, chunked.

Recurrence (per head, key-dim N x value-dim N state S):
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)
    S_t = diag(w_t) S_{t-1} + k_t^T v_t ,   w_t = exp(-exp(base + lora(x)))

Training uses a chunk-parallel form: all decay products are expressed as
exp(non-positive log-sums), so the chunk math is overflow-free by
construction. Decode is the O(N^2) single-step recurrence (no KV cache at
all — this is why rwkv6 runs long_500k natively).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.params import ParamFactory

LORA_DIM = 64


def rwkv_heads(cfg: ModelConfig):
    n = cfg.rwkv_head_dim
    assert cfg.d_model % n == 0
    return cfg.d_model // n, n


def init_rwkv(fac: ParamFactory, cfg: ModelConfig):
    d, f = cfg.d_model, cfg.d_ff
    with fac.scope("rwkv"):
        return {
            # time-mix
            "mu": fac.param("mu", (5, d), (None, "embed"), init="uniform", scale=1.0),
            "w_base": fac.param("w_base", (d,), ("embed",), init="constant", scale=0.5),
            "w_lora_a": fac.param("w_lora_a", (d, LORA_DIM), ("embed", None), scale=0.1),
            "w_lora_b": fac.param("w_lora_b", (LORA_DIM, d), (None, "embed"), scale=0.1),
            "u": fac.param("u", (d,), ("embed",), init="uniform", scale=0.5),
            "wr": fac.param("wr", (d, d), ("embed", "heads_flat")),
            "wk": fac.param("wk", (d, d), ("embed", "heads_flat")),
            "wv": fac.param("wv", (d, d), ("embed", "heads_flat")),
            "wg": fac.param("wg", (d, d), ("embed", "heads_flat")),
            "wo": fac.param("wo", (d, d), ("heads_flat", "embed")),
            "ln_x_scale": fac.param("ln_x_scale", (d,), ("embed",), init="ones"),
            "ln_x_bias": fac.param("ln_x_bias", (d,), ("embed",), init="zeros"),
            # channel-mix
            "mu_ck": fac.param("mu_ck", (d,), ("embed",), init="uniform", scale=1.0),
            "mu_cr": fac.param("mu_cr", (d,), ("embed",), init="uniform", scale=1.0),
            "ck": fac.param("ck", (d, f), ("embed", "mlp")),
            "cv": fac.param("cv", (f, d), ("mlp", "embed")),
            "cr": fac.param("cr", (d, d), ("embed", "heads_flat")),
        }


def _shift(x, prev):
    """Token shift: x_{t-1}, with ``prev`` (B,d) as x_0 predecessor."""
    return jnp.concatenate([prev[:, None], x[:, :-1]], axis=1)


def _log_decay(p, xw):
    """-exp(base + lora(x)) — the per-channel log decay, <= 0."""
    lora = jnp.tanh(xw @ p["w_lora_a"]) @ p["w_lora_b"]
    z = p["w_base"].astype(jnp.float32) + lora.astype(jnp.float32)
    return -jnp.exp(jnp.clip(z, -10.0, 3.0))


def _wkv_chunk(r, k, v, lw, u, h_in):
    """One chunk of the WKV recurrence.

    r,k,v,lw: (B,c,H,N) f32; u: (H,N); h_in: (B,H,N,N) [key x value dims].
    Returns (y (B,c,H,N), h_out).
    """
    lp = jnp.cumsum(lw, axis=1)               # lP_t
    lpm1 = lp - lw                            # lP_{t-1}
    # intra-chunk pair contributions: E[t,i] = exp(lP_{t-1}[t] - lP[i]) (i<t)
    diff = lpm1[:, :, None] - lp[:, None, :]  # (B,t,i,H,N); <=0 on the mask
    c = r.shape[1]
    mask = (jnp.arange(c)[:, None] > jnp.arange(c)[None, :])[None, :, :, None, None]
    e = jnp.where(mask, jnp.exp(jnp.where(mask, diff, 0.0)), 0.0)
    a = jnp.einsum("bthn,bihn,btihn->bhti", r, k, e)
    diag = jnp.einsum("bthn,hn,bthn->bth", r, u, k)
    y = jnp.einsum("bhti,bihn->bthn", a, v)
    y = y + diag[..., None] * v
    # contribution of the carried state
    q = r * jnp.exp(lpm1)
    y = y + jnp.einsum("bthn,bhnm->bthm", q, h_in)
    # state update
    kk = k * jnp.exp(lp[:, -1:, :, :] - lp)   # k_i * exp(lP_T - lP_i), <=0 exp
    h_out = jnp.exp(lp[:, -1])[..., None] * h_in + jnp.einsum("bthn,bthm->bhnm", kk, v)
    return y, h_out


def wkv_scan(r, k, v, lw, u, h0, chunk: int = 16):
    """Chunked WKV over full sequences. All inputs (B,S,H,N) f32."""
    b, s, h, n = r.shape
    c = min(chunk, s)
    pad = (-s) % c
    if pad:
        z = ((0, 0), (0, pad), (0, 0), (0, 0))
        r, k, v = jnp.pad(r, z), jnp.pad(k, z), jnp.pad(v, z)
        lw = jnp.pad(lw, z)  # log-decay 0 => decay 1 for pad steps (harmless)
    nc = (s + pad) // c

    def to_chunks(x):
        return x.reshape(b, nc, c, h, n).transpose(1, 0, 2, 3, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, lw))

    def body(hcur, xs):
        rr, kk, vv, ll = xs
        y, h_new = _wkv_chunk(rr, kk, vv, ll, u, hcur)
        return h_new, y

    h_last, ys = jax.lax.scan(body, h0, (rc, kc, vc, lwc))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(b, s + pad, h, n)[:, :s]
    return y, h_last


def _headnorm(p, y, cfg: ModelConfig):
    """Per-head LayerNorm (RWKV GroupNorm with H groups)."""
    h, n = rwkv_heads(cfg)
    b, s = y.shape[:2]
    y32 = y.astype(jnp.float32)
    mu = y32.mean(-1, keepdims=True)
    var = ((y32 - mu) ** 2).mean(-1, keepdims=True)
    yn = (y32 - mu) * jax.lax.rsqrt(var + 1e-5)
    yn = yn.reshape(b, s, h * n)
    return yn * p["ln_x_scale"].astype(jnp.float32) + p["ln_x_bias"].astype(jnp.float32)


def time_mix(p, x, cfg: ModelConfig, state):
    """x: (B,S,d). state=(shift_prev (B,d), h (B,H,N,N)). Returns (y, state)."""
    h, n = rwkv_heads(cfg)
    b, s, d = x.shape
    prev, hstate = state
    xs = _shift(x, prev)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mu[i] * (xs - x) for i in range(5))
    r = (xr @ p["wr"]).reshape(b, s, h, n).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, s, h, n).astype(jnp.float32) * (n ** -0.5)
    v = (xv @ p["wv"]).reshape(b, s, h, n).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    lw = _log_decay(p, xw).reshape(b, s, h, n)
    u = p["u"].astype(jnp.float32).reshape(h, n)
    if cfg.rwkv_impl == "pallas":
        # fused WKV kernel (interpret off-TPU); backward runs the oracle VJP
        from repro.kernels.wkv.ops import wkv
        y, h_new = wkv(r, k, v, lw, u, hstate)
    else:
        y, h_new = wkv_scan(r, k, v, lw, u, hstate)
    y = _headnorm(p, y, cfg).astype(x.dtype) * g
    return y @ p["wo"], (x[:, -1], h_new)


def time_mix_step(p, x, cfg: ModelConfig, state):
    """Decode: x (B,1,d)."""
    h, n = rwkv_heads(cfg)
    b = x.shape[0]
    prev, hstate = state
    xs = prev[:, None]
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + mu[i] * (xs - x) for i in range(5))
    r = (xr @ p["wr"]).reshape(b, h, n).astype(jnp.float32)
    k = (xk @ p["wk"]).reshape(b, h, n).astype(jnp.float32) * (n ** -0.5)
    v = (xv @ p["wv"]).reshape(b, h, n).astype(jnp.float32)
    g = jax.nn.silu(xg @ p["wg"])
    w = jnp.exp(_log_decay(p, xw)).reshape(b, h, n)
    u = p["u"].astype(jnp.float32).reshape(h, n)
    # y = r (S + diag(u) k^T v)
    y = jnp.einsum("bhn,bhnm->bhm", r, hstate) \
        + jnp.einsum("bhn,hn,bhn->bh", r, u, k)[..., None] * v
    h_new = w[..., None] * hstate + jnp.einsum("bhn,bhm->bhnm", k, v)
    y = _headnorm(p, y[:, None], cfg).astype(x.dtype) * g
    return y @ p["wo"], (x[:, 0], h_new)


def channel_mix(p, x, cfg: ModelConfig, prev):
    """RWKV channel-mix (the FFN). Returns (y, new_prev)."""
    xs = _shift(x, prev)
    xk = x + p["mu_ck"].astype(x.dtype) * (xs - x)
    xr = x + p["mu_cr"].astype(x.dtype) * (xs - x)
    kk = jnp.square(jax.nn.relu(xk @ p["ck"]))
    return jax.nn.sigmoid(xr @ p["cr"]) * (kk @ p["cv"]), x[:, -1]


def rwkv_block(p, x, cfg: ModelConfig, state, norm_fn):
    """Full RWKV layer: ln -> time-mix -> residual -> ln -> channel-mix.

    state = (tm_prev, h, cm_prev). norm_fn(params_key, x) applies the right
    pre-norm (passed in by the transformer stack, which owns norm params).
    """
    tm_prev, hstate, cm_prev = state
    a, (tm_prev2, h2) = (time_mix_step if x.shape[1] == 1 else time_mix)(
        p, norm_fn(0, x), cfg, (tm_prev, hstate))
    x = x + a
    bmix, cm_prev2 = channel_mix(p, norm_fn(1, x), cfg, cm_prev)
    x = x + bmix
    return x, (tm_prev2, h2, cm_prev2)
