"""Block stacks: decoder LM (dense / MoE / hybrid / ssm), encoder-decoder
(audio), vision-prefix LM (vlm).

Layers are grouped into *superblocks* — one repetition of ``cfg.layer_pattern``
— and the full repetitions are executed under a single ``lax.scan`` over
parameter stacks (remainder layers unrolled). This keeps HLO size ~constant in
depth, which matters for 62-72 layer models compiled on the CPU dry-run host.

Three modes:
  train    -> logits over the full sequence (plus MoE aux loss)
  prefill  -> logits + a populated decode cache
  decode   -> one-token step against the cache (``serve_step``'s body)
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn
from repro.models import mamba as mb
from repro.models import rwkv6 as rw
from repro.models.layers import (apply_embed, apply_mlp, apply_norm,
                                 apply_unembed, init_embed, init_mlp, init_norm)
from repro.models.moe import apply_moe, init_moe
from repro.models.params import ParamFactory


# ---------------------------------------------------------------------------
# Sharding context (activation constraints)
# ---------------------------------------------------------------------------

class ShardCtx:
    """Applies with_sharding_constraint from logical activation axes.

    ``rules`` maps logical axis -> ordered mesh-axis candidates; divisibility
    is checked per-dim (same policy as params.spec_for). mesh=None => no-op.
    """

    def __init__(self, mesh=None, rules: Optional[Dict[str, tuple]] = None):
        self.mesh = mesh
        self.rules = rules or {}

    def constrain(self, x, axes: Tuple[Optional[str], ...]):
        if self.mesh is None or x is None:
            return x
        from jax.sharding import NamedSharding
        from repro.models.params import spec_for
        spec = spec_for(tuple(x.shape), axes, self.rules, self.mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))


NULL_CTX = ShardCtx()


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def pattern_info(cfg: ModelConfig) -> Tuple[int, int, int]:
    plen = len(cfg.layer_pattern)
    n_full = cfg.num_layers // plen
    rem = cfg.num_layers % plen
    if cfg.num_experts and n_full > 1:
        assert plen % cfg.moe_every == 0, (
            "layer_pattern length must be a multiple of moe_every so the "
            "MoE placement is identical across scanned superblocks")
    return plen, n_full, rem


class _Stacked(ParamFactory):
    """Wraps a factory, prepending a (n,) 'layers' dim to every param."""

    def __init__(self, fac: ParamFactory, n: int):
        self.fac, self.n = fac, n
        self._path = fac._path

    def param(self, name, shape, axes, init="normal", scale=1.0, in_dims=1,
              fan_in=None):
        if fan_in is None and init == "normal":
            fan_in = (int(np.prod(shape[:in_dims])) if len(shape) > 1
                      else max(shape[-1], 1))
        return self.fac.param(name, (self.n,) + tuple(shape),
                              ("layers",) + tuple(axes), init=init, scale=scale,
                              fan_in=fan_in)

    def scope(self, name):
        return self.fac.scope(name)


def _init_block(fac: ParamFactory, cfg: ModelConfig, kind: str, pat_idx: int,
                cross: bool = False):
    p: Dict[str, Any] = {}
    if kind in ("global", "local"):
        p["ln1"] = init_norm(fac, cfg, "ln1")
        p["attn"] = init_attention_wrap(fac, cfg)
        if cross:
            p["lnx"] = init_norm(fac, cfg, "lnx")
            p["xattn"] = attn.init_attention(fac, cfg, cross=True)
        p["ln2"] = init_norm(fac, cfg, "ln2")
        p["ffn"] = (init_moe(fac, cfg) if cfg.ffn_is_moe(pat_idx) else init_mlp(fac, cfg))
    elif kind == "mamba":
        p["ln1"] = init_norm(fac, cfg, "ln1")
        p["mamba"] = mb.init_mamba(fac, cfg)
        p["ln2"] = init_norm(fac, cfg, "ln2")
        p["ffn"] = (init_moe(fac, cfg) if cfg.ffn_is_moe(pat_idx) else init_mlp(fac, cfg))
    elif kind == "rwkv":
        p["ln1"] = init_norm(fac, cfg, "ln1")
        p["ln2"] = init_norm(fac, cfg, "ln2")
        p["rwkv"] = rw.init_rwkv(fac, cfg)
    else:
        raise ValueError(kind)
    return p


def init_attention_wrap(fac, cfg):
    return attn.init_attention(fac, cfg)


def init_lm(fac: ParamFactory, cfg: ModelConfig):
    """Full parameter tree for any LM family."""
    plen, n_full, rem = pattern_info(cfg)
    cross = cfg.family == "audio"
    params: Dict[str, Any] = {"embed": init_embed(fac, cfg)}
    if cfg.frontend:
        with fac.scope("frontend_proj"):
            params["frontend_proj"] = fac.param(
                "w", (cfg.d_model, cfg.d_model), ("embed", "mlp"))
    stack: Dict[str, Any] = {}
    if n_full:
        sfac = _Stacked(fac, n_full)
        for pidx, kind in enumerate(cfg.layer_pattern):
            with fac.scope(f"stack_p{pidx}"):
                stack[f"p{pidx}"] = _init_block(sfac, cfg, kind, pidx, cross)
    params["stack"] = stack
    remp = {}
    for j in range(rem):
        pidx = n_full * plen + j
        kind = cfg.layer_kinds[pidx]
        with fac.scope(f"rem{j}"):
            remp[f"r{j}"] = _init_block(fac, cfg, kind, j % plen, cross)
    params["rem"] = remp
    if cfg.family == "audio":
        enc = {}
        for j in range(cfg.encoder_layers):
            with fac.scope(f"enc{j}"):
                enc[f"e{j}"] = {
                    "ln1": init_norm(fac, cfg, "ln1"),
                    "attn": attn.init_attention(fac, cfg),
                    "ln2": init_norm(fac, cfg, "ln2"),
                    "ffn": init_mlp(fac, cfg),
                }
        params["encoder"] = enc
        params["enc_ln"] = init_norm(fac, cfg, "enc_ln")
    params["final_ln"] = init_norm(fac, cfg, "final_ln")
    return params


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _attn_cache_len(cfg: ModelConfig, kind: str, cache_len: int) -> int:
    if kind == "local" and cfg.sliding_window:
        return min(cfg.sliding_window, cache_len)
    return cache_len


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, cache_len: int,
                     dtype, lead: Tuple[int, ...] = ()):
    kvh, hd = cfg.num_kv_heads, cfg.head_dim
    if kind in ("global", "local"):
        s = _attn_cache_len(cfg, kind, cache_len)
        return {
            "k": jnp.zeros(lead + (batch, s, kvh, hd), dtype),
            "v": jnp.zeros(lead + (batch, s, kvh, hd), dtype),
        }
    if kind == "mamba":
        di = mb.d_inner(cfg)
        return {
            "conv": jnp.zeros(lead + (batch, cfg.ssm_conv_width - 1, di), dtype),
            "h": jnp.zeros(lead + (batch, di, cfg.ssm_state_dim), jnp.float32),
        }
    if kind == "rwkv":
        h, n = rw.rwkv_heads(cfg)
        return {
            "tm_prev": jnp.zeros(lead + (batch, cfg.d_model), dtype),
            "h": jnp.zeros(lead + (batch, h, n, n), jnp.float32),
            "cm_prev": jnp.zeros(lead + (batch, cfg.d_model), dtype),
        }
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, cache_len: int, dtype=jnp.bfloat16,
               enc_len: int = 0):
    """Decode cache for the whole stack."""
    plen, n_full, rem = pattern_info(cfg)
    cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    stack = {}
    for pidx, kind in enumerate(cfg.layer_pattern):
        if n_full:
            lc = init_layer_cache(cfg, kind, batch, cache_len, dtype, lead=(n_full,))
            if cfg.family == "audio":
                lc["xk"] = jnp.zeros((n_full, batch, enc_len, cfg.num_kv_heads,
                                      cfg.head_dim), dtype)
                lc["xv"] = jnp.zeros_like(lc["xk"])
            stack[f"p{pidx}"] = lc
    cache["stack"] = stack
    remc = {}
    for j in range(rem):
        kind = cfg.layer_kinds[n_full * plen + j]
        lc = init_layer_cache(cfg, kind, batch, cache_len, dtype)
        if cfg.family == "audio":
            lc["xk"] = jnp.zeros((batch, enc_len, cfg.num_kv_heads, cfg.head_dim), dtype)
            lc["xv"] = jnp.zeros_like(lc["xk"])
        remc[f"r{j}"] = lc
    cache["rem"] = remc
    return cache


def _ring_positions(cache_slots: int, pos, window: int):
    """Original position of each ring-buffer slot given current length ``pos``.

    Slot i holds the latest position p < pos with p % slots == i. -1 if empty
    or expired (p <= pos - window).
    """
    idx = jnp.arange(cache_slots, dtype=jnp.int32)
    last = pos - 1 - ((pos - 1 - idx) % cache_slots)
    valid = (last >= 0) & (last >= pos - window) & (pos > 0)
    return jnp.where(valid, last, -1)


def _full_positions(cache_slots: int, pos):
    idx = jnp.arange(cache_slots, dtype=jnp.int32)
    return jnp.where(idx < pos, idx, -1)


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------

def _apply_ffn(p, x, cfg: ModelConfig, is_moe: bool, ctx: ShardCtx):
    if is_moe:
        y, aux = apply_moe(p, x, cfg, ctx=ctx)
    else:
        y, aux = apply_mlp(p, x, cfg), jnp.zeros((), jnp.float32)
    return y, jnp.asarray(aux, jnp.float32)


def apply_block_train(p, x, cfg: ModelConfig, kind: str, pat_idx: int,
                      ctx: ShardCtx, memory=None, positions=None,
                      want_kv: bool = False):
    """Train/prefill. Returns (x, aux, kv|None)."""
    kv = None
    if kind in ("global", "local"):
        h = apply_norm(p["ln1"], x, cfg)
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dke->bske", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dke->bske", h, p["attn"]["wv"])
        if positions is None:
            positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
        q = ctx.constrain(q, ("batch", "seq", "heads", "head_dim"))
        if kind == "local" and cfg.sliding_window and x.shape[1] > cfg.sliding_window:
            o = attn.local_blockwise_attention(q, k, v, window=cfg.sliding_window)
        else:
            win = cfg.sliding_window if kind == "local" else 0
            if cfg.attn_block_skip:
                o = attn.causal_skip_attention(q, k, v, window=win)
            else:
                bq = cfg.attn_block_q or x.shape[1]
                o = attn.blockwise_attention(q, k, v, causal=True, window=win,
                                             block_q=bq)
        x = x + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
        if want_kv:
            kv = (k, v)
        if memory is not None:  # cross-attention (audio decoder)
            hx = apply_norm(p["lnx"], x, cfg)
            qx = jnp.einsum("bsd,dhe->bshe", hx, p["xattn"]["wq"])
            kx = jnp.einsum("bsd,dke->bske", memory, p["xattn"]["wk"])
            vx = jnp.einsum("bsd,dke->bske", memory, p["xattn"]["wv"])
            ox = attn.blockwise_attention(qx, kx, vx, causal=False)
            x = x + jnp.einsum("bshe,hed->bsd", ox, p["xattn"]["wo"])
        h2 = apply_norm(p["ln2"], x, cfg)
        y, aux = _apply_ffn(p["ffn"], h2, cfg, cfg.ffn_is_moe(pat_idx), ctx)
        x = x + y
        return ctx.constrain(x, ("batch", "seq", "embed")), aux, kv
    if kind == "mamba":
        h = apply_norm(p["ln1"], x, cfg)
        y, state = mb.mamba_block(p["mamba"], h, cfg)
        x = x + y
        h2 = apply_norm(p["ln2"], x, cfg)
        y, aux = _apply_ffn(p["ffn"], h2, cfg, cfg.ffn_is_moe(pat_idx), ctx)
        x = x + y
        if want_kv:  # prefill: carry final (conv, ssm) states into the cache
            kv = {"conv": state[0], "h": state[1]}
        return ctx.constrain(x, ("batch", "seq", "embed")), aux, kv
    if kind == "rwkv":
        b = x.shape[0]
        hh, nn = rw.rwkv_heads(cfg)
        zeros = (jnp.zeros((b, cfg.d_model), x.dtype),
                 jnp.zeros((b, hh, nn, nn), jnp.float32))
        a, (tm_prev, h_new) = rw.time_mix(p["rwkv"], apply_norm(p["ln1"], x, cfg),
                                          cfg, zeros)
        x = x + a
        cmz = jnp.zeros((b, cfg.d_model), x.dtype)
        y, cm_prev = rw.channel_mix(p["rwkv"], apply_norm(p["ln2"], x, cfg),
                                    cfg, cmz)
        x = x + y
        if want_kv:
            kv = {"tm_prev": tm_prev, "h": h_new, "cm_prev": cm_prev}
        return (ctx.constrain(x, ("batch", "seq", "embed")),
                jnp.zeros((), jnp.float32), kv)
    raise ValueError(kind)


def apply_block_decode(p, x, cfg: ModelConfig, kind: str, pat_idx: int,
                       cache, pos, ctx: ShardCtx):
    """One-token decode. x: (B,1,d). Returns (x, new_cache)."""
    from repro.models.layers import apply_rope
    new_cache = dict(cache)
    if kind in ("global", "local"):
        h = apply_norm(p["ln1"], x, cfg)
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dke->bske", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dke->bske", h, p["attn"]["wv"])
        posv = pos[None, None] if pos.ndim == 0 else pos[:, None]
        q = apply_rope(q, posv.astype(jnp.int32), cfg.rope_theta)
        k = apply_rope(k, posv.astype(jnp.int32), cfg.rope_theta)
        slots = cache["k"].shape[1]
        if kind == "local" and cfg.sliding_window:
            slot = jnp.mod(pos, slots)
            kv_pos = _ring_positions(slots, pos + 1, cfg.sliding_window)
        else:
            slot = jnp.minimum(pos, slots - 1)
            kv_pos = _full_positions(slots, pos + 1)
        kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype),
                                                 slot, axis=1)
        vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype),
                                                 slot, axis=1)
        kc = ctx.constrain(kc, ("batch", "kvseq", "kv_heads", "head_dim"))
        vc = ctx.constrain(vc, ("batch", "kvseq", "kv_heads", "head_dim"))
        new_cache["k"], new_cache["v"] = kc, vc
        o = attn.decode_attention(q, kc, vc, kv_pos,
                                  window=cfg.sliding_window if kind == "local" else 0)
        x = x + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
        if "xk" in cache:  # cross-attention against cached encoder KV
            hx = apply_norm(p["lnx"], x, cfg)
            qx = jnp.einsum("bsd,dhe->bshe", hx, p["xattn"]["wq"])
            enc_pos = jnp.arange(cache["xk"].shape[1], dtype=jnp.int32)
            ox = attn.decode_attention(qx, cache["xk"], cache["xv"], enc_pos)
            x = x + jnp.einsum("bshe,hed->bsd", ox, p["xattn"]["wo"])
        h2 = apply_norm(p["ln2"], x, cfg)
        y, _aux = _apply_ffn(p["ffn"], h2, cfg, cfg.ffn_is_moe(pat_idx), ctx)
        return x + y, new_cache
    if kind == "mamba":
        h = apply_norm(p["ln1"], x, cfg)
        y, (conv2, h2s) = mb.mamba_decode_step(p["mamba"], h, cfg,
                                               (cache["conv"], cache["h"]))
        new_cache["conv"], new_cache["h"] = conv2, h2s
        x = x + y
        h2 = apply_norm(p["ln2"], x, cfg)
        y, _aux = _apply_ffn(p["ffn"], h2, cfg, cfg.ffn_is_moe(pat_idx), ctx)
        return x + y, new_cache
    if kind == "rwkv":
        a, (tmp2, hs2) = rw.time_mix_step(
            p["rwkv"], apply_norm(p["ln1"], x, cfg), cfg,
            (cache["tm_prev"], cache["h"]))
        x = x + a
        y, cmp2 = rw.channel_mix(p["rwkv"], apply_norm(p["ln2"], x, cfg), cfg,
                                 cache["cm_prev"])
        new_cache.update(tm_prev=tmp2, h=hs2, cm_prev=cmp2)
        return x + y, new_cache
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full-stack forward
# ---------------------------------------------------------------------------

def _frontend_prefix(params, cfg: ModelConfig, batch) -> Optional[jnp.ndarray]:
    """VLM patch prefix (projected)."""
    if cfg.family == "vlm" and "patches" in batch:
        return batch["patches"] @ params["frontend_proj"]
    return None


def encode_audio(params, cfg: ModelConfig, frames, ctx: ShardCtx):
    """Bidirectional encoder over (stubbed) post-conv frame embeddings."""
    x = frames @ params["frontend_proj"]
    for j in range(cfg.encoder_layers):
        p = params["encoder"][f"e{j}"]
        h = apply_norm(p["ln1"], x, cfg)
        q = jnp.einsum("bsd,dhe->bshe", h, p["attn"]["wq"])
        k = jnp.einsum("bsd,dke->bske", h, p["attn"]["wk"])
        v = jnp.einsum("bsd,dke->bske", h, p["attn"]["wv"])
        o = attn.blockwise_attention(q, k, v, causal=False)
        x = x + jnp.einsum("bshe,hed->bsd", o, p["attn"]["wo"])
        x = x + apply_mlp(p["ffn"], apply_norm(p["ln2"], x, cfg), cfg)
        x = ctx.constrain(x, ("batch", "seq", "embed"))
    return apply_norm(params["enc_ln"], x, cfg)


def forward_train(params, cfg: ModelConfig, batch, ctx: ShardCtx = NULL_CTX,
                  remat: str = "block"):
    """Returns (logits, aux_loss). batch: tokens (B,S) [+ patches/frames]."""
    plen, n_full, rem = pattern_info(cfg)
    tokens = batch["tokens"]
    x = apply_embed(params["embed"], tokens, cfg).astype(jnp.dtype(cfg.compute_dtype))
    memory = None
    if cfg.family == "audio":
        memory = encode_audio(params, cfg, batch["frames"].astype(x.dtype), ctx)
    prefix = _frontend_prefix(params, cfg, batch)
    n_prefix = 0
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        n_prefix = prefix.shape[1]
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)[None]
    aux_total = 0.0

    def superblock(x, block_params):
        aux_sb = jnp.zeros((), jnp.float32)
        for pidx, kind in enumerate(cfg.layer_pattern):
            x, aux, _ = apply_block_train(block_params[f"p{pidx}"], x, cfg, kind,
                                          pidx, ctx, memory=memory,
                                          positions=positions)
            aux_sb = aux_sb + aux
        return x, aux_sb

    if n_full:
        body = superblock
        if remat in ("block", "full"):
            body = jax.checkpoint(superblock)

        def scan_body(carry, block_params):
            x, aux_acc = carry
            x, aux_sb = body(x, block_params)
            return (x, aux_acc + aux_sb), None

        (x, aux_total), _ = jax.lax.scan(
            scan_body, (x, jnp.zeros((), jnp.float32)), params["stack"])
    for j in range(rem):
        kind = cfg.layer_kinds[n_full * plen + j]
        x, aux, _ = apply_block_train(params["rem"][f"r{j}"], x, cfg, kind,
                                      j % plen, ctx, memory=memory,
                                      positions=positions)
        aux_total = aux_total + aux
    x = apply_norm(params["final_ln"], x, cfg)
    if n_prefix:
        x = x[:, n_prefix:]
    logits = apply_unembed(params["embed"], x, cfg)
    logits = ctx.constrain(logits, ("batch", "seq", "vocab"))
    return logits, aux_total


def forward_prefill(params, cfg: ModelConfig, batch, ctx: ShardCtx = NULL_CTX,
                    max_len: Optional[int] = None):
    """Prefill: full forward that also materialises the decode cache.

    Returns (last_token_logits, cache). ``max_len`` sets the cache allocation
    (>= prefill length; default exactly the prefill length) so subsequent
    decode steps have headroom. Local layers keep ring-truncated windows;
    SSM/RWKV layers store final states.
    """
    plen, n_full, rem = pattern_info(cfg)
    tokens = batch["tokens"]
    bsz, seq = tokens.shape
    cdt = jnp.dtype(cfg.compute_dtype)
    x = apply_embed(params["embed"], tokens, cfg).astype(cdt)
    memory = None
    if cfg.family == "audio":
        memory = encode_audio(params, cfg, batch["frames"].astype(x.dtype), ctx)
    prefix = _frontend_prefix(params, cfg, batch)
    n_prefix = 0
    if prefix is not None:
        x = jnp.concatenate([prefix.astype(x.dtype), x], axis=1)
        n_prefix = prefix.shape[1]
    x = ctx.constrain(x, ("batch", "seq", "embed"))
    total = x.shape[1]
    cache_len = max(max_len or total, total)
    positions = jnp.arange(total, dtype=jnp.int32)[None]
    cache = init_cache(cfg, bsz, cache_len, dtype=cdt,
                       enc_len=memory.shape[1] if memory is not None else 0)
    cache["pos"] = jnp.full((), total, jnp.int32)

    def run_block(p, x, kind, pidx, lead_cache):
        x, _aux, kv = apply_block_train(p, x, cfg, kind, pidx, ctx,
                                        memory=memory, positions=positions,
                                        want_kv=True)
        new_lc = dict(lead_cache)
        if isinstance(kv, dict):       # mamba/rwkv final states
            for name, val in kv.items():
                new_lc[name] = val.astype(lead_cache[name].dtype)
            kv = None
        if kv is not None:
            k, v = kv
            slots = lead_cache["k"].shape[1]
            if slots < total:  # local ring: keep the last ``slots`` entries
                k, v = k[:, -slots:], v[:, -slots:]
                # ring layout: entry at position p lives in slot p % slots
                roll = (total % slots)
                k = jnp.roll(k, roll, axis=1)
                v = jnp.roll(v, roll, axis=1)
            elif slots > total:  # headroom for subsequent decode steps
                pad = ((0, 0), (0, slots - total), (0, 0), (0, 0))
                k, v = jnp.pad(k, pad), jnp.pad(v, pad)
            new_lc["k"] = k.astype(lead_cache["k"].dtype)
            new_lc["v"] = v.astype(lead_cache["v"].dtype)
        if memory is not None and "xk" in lead_cache:
            new_lc["xk"] = jnp.einsum("bsd,dke->bske", memory,
                                      p["xattn"]["wk"]).astype(lead_cache["xk"].dtype)
            new_lc["xv"] = jnp.einsum("bsd,dke->bske", memory,
                                      p["xattn"]["wv"]).astype(lead_cache["xv"].dtype)
        return x, new_lc

    if n_full:
        def scan_body(x, xs):
            block_params, block_cache = xs
            new_bc = {}
            for pidx, kind in enumerate(cfg.layer_pattern):
                x, new_bc[f"p{pidx}"] = run_block(
                    block_params[f"p{pidx}"], x, kind, pidx, block_cache[f"p{pidx}"])
            return x, new_bc

        x, new_stack = jax.lax.scan(scan_body, x, (params["stack"], cache["stack"]))
        cache["stack"] = new_stack
    for j in range(rem):
        kind = cfg.layer_kinds[n_full * plen + j]
        x, cache["rem"][f"r{j}"] = run_block(params["rem"][f"r{j}"], x, kind,
                                             j % plen, cache["rem"][f"r{j}"])
    x = apply_norm(params["final_ln"], x, cfg)
    logits = apply_unembed(params["embed"], x[:, -1:], cfg)
    return logits, cache


def forward_decode(params, cfg: ModelConfig, tokens, cache,
                   ctx: ShardCtx = NULL_CTX):
    """One decode step. tokens: (B,1). Returns (logits (B,1,V), new_cache)."""
    plen, n_full, rem = pattern_info(cfg)
    pos = cache["pos"]
    x = apply_embed(params["embed"], tokens, cfg).astype(jnp.dtype(cfg.compute_dtype))
    x = ctx.constrain(x, ("batch", "seq", "embed"))

    new_cache = {"pos": pos + 1, "stack": cache["stack"], "rem": dict(cache["rem"])}
    if n_full:
        def scan_body(x, xs):
            block_params, block_cache = xs
            new_bc = {}
            for pidx, kind in enumerate(cfg.layer_pattern):
                x, new_bc[f"p{pidx}"] = apply_block_decode(
                    block_params[f"p{pidx}"], x, cfg, kind, pidx,
                    block_cache[f"p{pidx}"], pos, ctx)
            return x, new_bc

        x, new_stack = jax.lax.scan(scan_body, x, (params["stack"], cache["stack"]))
        new_cache["stack"] = new_stack
    for j in range(rem):
        kind = cfg.layer_kinds[n_full * plen + j]
        x, new_cache["rem"][f"r{j}"] = apply_block_decode(
            params["rem"][f"r{j}"], x, cfg, kind, j % plen,
            cache["rem"][f"r{j}"], pos, ctx)
    x = apply_norm(params["final_ln"], x, cfg)
    logits = apply_unembed(params["embed"], x, cfg)
    return logits, new_cache
