from repro.optim.optimizers import (  # noqa: F401
    OptState, init_optimizer, make_optimizer)
from repro.optim.fisher import diag_fisher, fisher_precondition  # noqa: F401
