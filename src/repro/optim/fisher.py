"""Diagonal empirical Fisher information (RapidRetrain's accelerator).

RapidRetrain [Liu et al. 2022] expedites retraining with a diagonal empirical
FIM second-order update: g_precond = g / (F_diag + lambda). We accumulate
F_diag as the running mean of squared per-batch gradients.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def diag_fisher(fisher, grads, count: int):
    """Online mean of squared gradients. fisher=None initialises."""
    sq = jax.tree.map(lambda g: jnp.square(g.astype(jnp.float32)), grads)
    if fisher is None:
        return sq
    t = float(count)
    return jax.tree.map(lambda f, s: f + (s - f) / (t + 1.0), fisher, sq)


def fisher_precondition(grads, fisher, damping: float = 1e-3):
    """g / (F + lambda) — the diagonal natural-gradient step."""
    if fisher is None:
        return grads
    return jax.tree.map(
        lambda g, f: (g.astype(jnp.float32) / (f + damping)).astype(g.dtype),
        grads, fisher)
