"""Optimizers as pure pytree transforms (no optax dependency).

``make_optimizer(cfg)`` -> (init_fn, update_fn):
    state = init_fn(params)
    new_params, new_state = update_fn(params, grads, state)

Supported: sgd, sgdm, adamw (f32 moments), adamw_bf16 (bf16 moments — the
memory-feasible choice for 398B-scale FSDP training, see DESIGN.md Sec 6).
"""
from __future__ import annotations

from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import OptimizerConfig


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any          # first moment (or momentum); None for sgd
    nu: Any          # second moment; None for sgd/sgdm


def _clip_by_global_norm(grads, max_norm: float):
    if not max_norm:
        return grads
    norm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(grads)))
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale).astype(g.dtype),
                        grads)


def make_optimizer(cfg: OptimizerConfig) -> Tuple[Callable, Callable]:
    name = cfg.name
    mom_dtype = jnp.bfloat16 if name == "adamw_bf16" else jnp.float32

    def init_fn(params) -> OptState:
        zeros = lambda: jax.tree.map(  # noqa: E731
            lambda p: jnp.zeros(p.shape, mom_dtype), params)
        if name == "sgd":
            return OptState(jnp.zeros((), jnp.int32), None, None)
        if name == "sgdm":
            return OptState(jnp.zeros((), jnp.int32), zeros(), None)
        if name in ("adamw", "adamw_bf16"):
            return OptState(jnp.zeros((), jnp.int32), zeros(), zeros())
        raise ValueError(name)

    def update_fn(params, grads, state: OptState):
        grads = _clip_by_global_norm(grads, cfg.grad_clip)
        step = state.step + 1
        if name == "sgd":
            new = jax.tree.map(
                lambda p, g: p - cfg.lr * g.astype(p.dtype), params, grads)
            return new, OptState(step, None, None)
        if name == "sgdm":
            mu = jax.tree.map(lambda m, g: (cfg.momentum * m.astype(jnp.float32)
                                            + g.astype(jnp.float32)).astype(m.dtype),
                              state.mu, grads)
            new = jax.tree.map(lambda p, m: p - cfg.lr * m.astype(p.dtype),
                               params, mu)
            return new, OptState(step, mu, None)
        # adamw
        b1, b2 = cfg.beta1, cfg.beta2
        t = step.astype(jnp.float32)
        mu = jax.tree.map(lambda m, g: (b1 * m.astype(jnp.float32)
                                        + (1 - b1) * g.astype(jnp.float32)
                                        ).astype(m.dtype), state.mu, grads)
        nu = jax.tree.map(lambda v, g: (b2 * v.astype(jnp.float32)
                                        + (1 - b2) * jnp.square(g.astype(jnp.float32))
                                        ).astype(v.dtype), state.nu, grads)
        bc1 = 1 - b1 ** t
        bc2 = 1 - b2 ** t

        def upd(p, m, v):
            mhat = m.astype(jnp.float32) / bc1
            vhat = v.astype(jnp.float32) / bc2
            delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
            if cfg.weight_decay:
                delta = delta + cfg.weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - cfg.lr * delta).astype(p.dtype)

        new = jax.tree.map(upd, params, mu, nu)
        return new, OptState(step, mu, nu)

    return init_fn, update_fn


def init_optimizer(cfg: OptimizerConfig, params) -> OptState:
    return make_optimizer(cfg)[0](params)
