from repro.roofline.analysis import (  # noqa: F401
    HBM_BW, LINK_BW, PEAK_FLOPS, analyze_compiled, model_flops,
    parse_collectives)
