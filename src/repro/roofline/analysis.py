"""Roofline-term extraction from the compiled dry-run artifact.

    compute term    = HLO_FLOPs / (chips * peak_FLOP/s)
    memory term     = HLO_bytes / (chips * HBM_bw)
    collective term = collective_link_bytes / (chips * link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``. Collective bytes are NOT
in cost_analysis — we parse the compiled HLO text, sum per-device tensor sizes
of every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute, convert to link bytes with the standard ring-algorithm
factors, and multiply by participant counts to get total bytes crossing links.

Hardware model (TPU v5e per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from typing import Dict, List, Optional

import numpy as np

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# "f32[128,1024]{1,0}" possibly inside a tuple "(f32[...], f32[...])"
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_LINE_RE = re.compile(
    r"=\s*(\([^)]*\)|\S+)\s+"                       # result type (maybe tuple)
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start|-done)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _participants(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))                      # [groups, group_size]
    m = _GROUPS_RE.search(line)
    if m:
        return max(len(m.group(1).split(",")), 1)
    return default


def parse_collectives(hlo_text: str, num_devices: int) -> Dict:
    """Sum per-kind link traffic from partitioned HLO.

    For each collective over n participants on per-device tensors of b bytes,
    total bytes crossing links (ring algorithms):
      all-reduce:        2 (n-1) b       (reduce-scatter + all-gather phases)
      all-gather:        (n-1) * b_out   (b_out = gathered per-device result)
      reduce-scatter:    (n-1) * b_in ~= (n-1) * n * b_out
      all-to-all:        (n-1) b
      collective-permute: n * b          (every device forwards one tensor)
    """
    per_kind: Dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    counts: Dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        m = _LINE_RE.search(line)
        if not m:
            continue
        type_str, kind = m.group(1), m.group(2)
        if "-done(" in line:
            continue  # count the -start, not the -done
        b = _type_bytes(type_str)
        n = _participants(line, num_devices)
        if kind == "all-reduce":
            link = 2 * (n - 1) * b
        elif kind == "all-gather":
            link = (n - 1) * b  # result bytes per device; each came from a peer
        elif kind == "reduce-scatter":
            link = (n - 1) * b * n  # result is 1/n of the reduced input
        elif kind == "all-to-all":
            link = (n - 1) * b
        else:  # collective-permute
            link = n * b
        # the parsed tensor is PER-DEVICE; total across the mesh counts every
        # participating group once per group member set
        groups = max(num_devices // max(n, 1), 1)
        per_kind[kind] += float(link * groups)
        counts[kind] += 1
    total = sum(per_kind.values())
    return {"collective_bytes_total": total,
            "collective_bytes_by_kind": per_kind,
            "collective_op_counts": counts}


def model_flops(cfg, shape) -> float:
    """6*N*D (dense) / 6*N_active*D (MoE) useful training FLOPs; decode/prefill
    use the forward-only 2*N*D."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    tokens = shape.global_batch * 1
    return 2.0 * n_active * tokens


def analyze_compiled(compiled, mesh, cfg, shape) -> Dict:
    """Compute the three roofline terms from a compiled executable.

    Uses the loop-aware static cost model (roofline.hlo_cost) over the
    partitioned HLO text: XLA's own cost_analysis counts each while body once,
    undercounting scanned layer stacks by their trip counts. The raw
    cost_analysis numbers are retained in the record for reference.
    """
    from repro.roofline.hlo_cost import analyze_hlo_text

    num_devices = int(np.prod(mesh.devices.shape))
    try:
        hlo = compiled.as_text()
    except Exception:
        hlo = ""
    hc = analyze_hlo_text(hlo, num_devices) if hlo else {}
    flops = float(hc.get("mxu_flops_per_device", 0.0)
                  + hc.get("vpu_flops_per_device", 0.0))
    bytes_accessed = float(hc.get("bytes_per_device", 0.0))
    coll = {
        "collective_bytes_total": hc.get("collective_bytes_total", 0.0),
        "collective_bytes_by_kind": hc.get("collective_bytes_by_kind", {}),
        "collective_op_counts": hc.get("collective_op_counts", {}),
    }

    total_flops = flops * num_devices
    total_bytes = bytes_accessed * num_devices
    compute_s = total_flops / (num_devices * PEAK_FLOPS)
    memory_s = total_bytes / (num_devices * HBM_BW)
    collective_s = coll["collective_bytes_total"] / (num_devices * LINK_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape)
    return {
        "num_devices": num_devices,
        "hlo_flops_per_device": flops,
        "hlo_mxu_flops_per_device": hc.get("mxu_flops_per_device", 0.0),
        "hlo_vpu_flops_per_device": hc.get("vpu_flops_per_device", 0.0),
        "hlo_bytes_per_device": bytes_accessed,
        "hlo_flops_total": total_flops,
        "hlo_bytes_total": total_bytes,
        **coll,
        "roofline": {**terms, "dominant": dominant},
        "model_flops": mf,
        "useful_flops_ratio": (mf / total_flops) if total_flops else None,
    }
