"""Static cost model over optimized (partitioned) HLO text.

``compiled.cost_analysis()`` counts each ``while`` body ONCE, so scanned layer
stacks / client loops / flash-attention KV loops are undercounted by their
trip counts (verified: a scanned 8-step matmul reports 1/8 the unrolled
FLOPs). This walker re-derives per-device FLOPs, HBM bytes, and collective
link-bytes by traversing the computation graph and multiplying loop bodies by
their ``known_trip_count``.

Counting rules
  * dot: 2 * prod(result dims) * prod(lhs contracting dims)   (MXU)
  * convolution: 2 * prod(result) * prod(kernel spatial+input-feature)
  * elementwise / reduce / rng: 1 flop per output (VPU; kept separate)
  * bytes: per op, operand bytes + result bytes — fusions count only their
    boundary tensors (internals stay on-chip), mirroring HloCostAnalysis.
  * collectives: ring-algorithm link bytes (see roofline.analysis), scaled by
    the enclosing loops' trip counts.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16, "token": 0,
    "opaque": 0,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_HEADER_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s+\(.*\)\s+->\s+.+\s+\{")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^()]*\)|[\w\[\]{},\s]+?)\s+"
    r"([a-z][\w\-]*)\(")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_GROUPS_RE = re.compile(r"replica_groups=\{(\{[^}]*\})")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_APPLY_RE = re.compile(r"to_apply=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple", "bitcast",
               "after-all", "partition-id", "replica-id", "iota"}
_ELEMENTWISE_FLOPS = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "log", "tanh", "sqrt", "rsqrt", "negate", "abs", "cosine",
    "sine", "select", "compare", "and", "or", "xor", "clamp", "floor", "ceil",
    "round-nearest-even", "sign", "atan2", "remainder", "expm1", "log1p",
    "logistic", "cbrt", "erf", "reduce", "reduce-window", "exponential-minus-one",
}
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")


def _shapes_of(type_str: str) -> List[Tuple[str, List[int]]]:
    out = []
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt in _DTYPE_BYTES:
            out.append((dt, [int(d) for d in dims.split(",") if d]))
    return out


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _shapes_of(type_str):
        n = 1
        for d in dims:
            n *= d
        total += n * _DTYPE_BYTES[dt]
    return total


def _split_operands(args_str: str) -> List[str]:
    """Split the operand list at top-level commas (braces/brackets nest)."""
    parts, depth, cur = [], 0, []
    for ch in args_str:
        if ch in "{[(":
            depth += 1
        elif ch in "}])":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append("".join(cur))
            cur = []
        else:
            cur.append(ch)
    if cur:
        parts.append("".join(cur))
    return parts


# coarse attribution patterns searched in metadata op_name (first match wins)
BYTE_TAGS = (
    ("attn_scores", ("bkgqs", "bkgqd", "softmax", "bqkgd")),
    ("attn_proj", ("dhe->", "dke->", "hed->", "bshe", "bske")),
    ("moe_dispatch", ("ntke", "ntec", "ntkc", "top_k", "one_hot")),
    ("moe_expert", ("ecnd", "ecnf", "efd")),
    ("mamba", ("associative_scan", "bcn,bcdn", "mamba", "conv", "bcdn")),
    ("rwkv", ("bthn", "bihn", "bhti", "bhnm")),
    ("optimizer", ("adamw", "opt_update", "sqrt", "multiply_add")),
    ("embed_logits", ("take", "gather", "unembed", "logsumexp", "exp")),
)


def tag_of(line: str) -> str:
    m = line.find('op_name="')
    seg = line[m: m + 400] if m >= 0 else line
    for tag, pats in BYTE_TAGS:
        for p in pats:
            if p in seg:
                return tag
    return "other"


@dataclass
class Cost:
    mxu_flops: float = 0.0
    vpu_flops: float = 0.0
    bytes: float = 0.0
    coll_link_bytes: float = 0.0
    coll_by_kind: Dict[str, float] = field(default_factory=dict)
    coll_counts: Dict[str, float] = field(default_factory=dict)
    bytes_by_tag: Dict[str, float] = field(default_factory=dict)

    def add(self, other: "Cost", mult: float = 1.0):
        self.mxu_flops += other.mxu_flops * mult
        self.vpu_flops += other.vpu_flops * mult
        self.bytes += other.bytes * mult
        self.coll_link_bytes += other.coll_link_bytes * mult
        for k, v in other.coll_by_kind.items():
            self.coll_by_kind[k] = self.coll_by_kind.get(k, 0.0) + v * mult
        for k, v in other.coll_counts.items():
            self.coll_counts[k] = self.coll_counts.get(k, 0.0) + v * mult
        for k, v in other.bytes_by_tag.items():
            self.bytes_by_tag[k] = self.bytes_by_tag.get(k, 0.0) + v * mult


_PARAM_RE = re.compile(r"%?([\w.\-]+):\s*((?:\([^()]*\)|[\w\[\]{},]+))")


class HloCostModel:
    def __init__(self, hlo_text: str, num_devices: int):
        self.num_devices = num_devices
        self.comps: Dict[str, List[str]] = {}
        self.types: Dict[str, Dict[str, str]] = {}   # comp -> {op name: type}
        self.entry: Optional[str] = None
        self._memo: Dict[str, Cost] = {}
        self._parse(hlo_text)

    def _parse(self, text: str):
        cur = None
        for line in text.splitlines():
            m = _HEADER_RE.match(line.strip()) if "{" in line else None
            if m and "->" in line:
                cur = m.group(2)
                self.comps[cur] = []
                self.types[cur] = {}
                if m.group(1):
                    self.entry = cur
                # header params: "(p0: f32[...], p1: (f32[...], s32[]))"
                hdr = line.strip()
                args = hdr.split("(", 1)[1].rsplit(") ->", 1)[0]
                for nm, ty in _PARAM_RE.findall(args):
                    self.types[cur][nm] = ty
                continue
            if cur is not None:
                if line.strip() == "}":
                    cur = None
                    continue
                self.comps[cur].append(line)
                om = _OP_RE.match(line)
                if om:
                    self.types[cur][om.group(1)] = om.group(2)

    def _operand_type(self, comp: str, operand: str) -> str:
        """Resolve an operand reference to its type string. Operands may be
        inline-typed ('f32[8] %x') or bare references ('%x')."""
        operand = operand.strip()
        if "[" in operand and ("%" not in operand or operand.index("[")
                               < operand.index("%")):
            return operand  # inline type
        name = operand.lstrip("%").split(" ")[0]
        # strip get-tuple-element style suffixes are not needed; direct lookup
        t = self.types.get(comp, {}).get(name)
        return t or ""

    # -- per-op costs -------------------------------------------------------
    def _op_cost(self, comp: str, line: str, cost: Cost):
        m = _OP_RE.match(line)
        if not m:
            return None
        _, result_type, opcode = m.groups()
        # operand segment: inside the first top-level parens after opcode
        try:
            args = line.split(opcode + "(", 1)[1]
            depth, end = 1, 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            args = args[:end]
        except IndexError:
            args = ""
        operands = _split_operands(args)

        if opcode == "dot":
            out_elems = 1
            for _, dims in _shapes_of(result_type):
                for d in dims:
                    out_elems *= d
            lhs_t = self._operand_type(comp, operands[0]) if operands else ""
            lhs_shapes = _shapes_of(lhs_t)
            lhs = lhs_shapes[0][1] if lhs_shapes else []
            cm = _LHS_CONTRACT_RE.search(line)
            k = 1
            if cm and lhs:
                for idx in cm.group(1).split(","):
                    if idx and int(idx) < len(lhs):
                        k *= lhs[int(idx)]
            cost.mxu_flops += 2.0 * out_elems * k
        elif opcode == "convolution":
            out_elems = 1
            for _, dims in _shapes_of(result_type):
                for d in dims:
                    out_elems *= d
            ker_t = (self._operand_type(comp, operands[1])
                     if len(operands) > 1 else "")
            ker = _shapes_of(ker_t)
            if ker:
                kelems = 1
                for _, dims in ker:
                    for d in dims:
                        kelems *= d
                # 2 * out * (kernel elems / out_features): approximate
                rs = _shapes_of(result_type)
                of = rs[0][1][-1] if rs and rs[0][1] else 1
                cost.mxu_flops += 2.0 * out_elems * max(kelems // max(of, 1), 1)
        elif opcode in _ELEMENTWISE_FLOPS:
            out_elems = 1
            for _, dims in _shapes_of(result_type):
                for d in dims:
                    out_elems *= d
            cost.vpu_flops += float(out_elems)

        if opcode not in _SKIP_BYTES and opcode != "fusion":
            if opcode == "dynamic-slice":
                # reads only the slice; result is the slice
                b = 2 * _type_bytes(result_type)
            elif opcode == "dynamic-update-slice":
                # in-place: reads + writes only the update slice
                upd = (self._operand_type(comp, operands[1])
                       if len(operands) > 1 else "")
                b = 2 * _type_bytes(upd)
            else:
                b = _type_bytes(result_type)
                for o in operands:
                    b += _type_bytes(self._operand_type(comp, o))
            cost.bytes += b
            t = tag_of(line)
            cost.bytes_by_tag[t] = cost.bytes_by_tag.get(t, 0.0) + b

        if opcode.rstrip("-start").rstrip("-done") in _COLLECTIVES or \
                opcode in _COLLECTIVES or opcode.replace("-start", "") in _COLLECTIVES:
            kind = opcode.replace("-start", "").replace("-done", "")
            if kind in _COLLECTIVES and not opcode.endswith("-done"):
                n = self._participants(line)
                b = _type_bytes(result_type)
                if kind == "all-reduce":
                    link = 2 * (n - 1) * b
                elif kind == "all-gather":
                    link = (n - 1) * b
                elif kind == "reduce-scatter":
                    link = (n - 1) * b * n
                elif kind == "all-to-all":
                    link = (n - 1) * b
                else:
                    link = n * b
                groups = max(self.num_devices // max(n, 1), 1)
                cost.coll_link_bytes += float(link * groups)
                cost.coll_by_kind[kind] = cost.coll_by_kind.get(kind, 0.0) \
                    + float(link * groups)
                cost.coll_counts[kind] = cost.coll_counts.get(kind, 0.0) + 1

        return opcode, line

    def _participants(self, line: str) -> int:
        m = _GROUPS_V2_RE.search(line)
        if m:
            return max(int(m.group(2)), 1)
        m = _GROUPS_RE.search(line)
        if m:
            return max(len(m.group(1).split(",")), 1)
        return self.num_devices

    def _root_of(self, callee: str) -> Optional[Tuple[str, str]]:
        """(opcode, line) of a computation's ROOT op."""
        for line in reversed(self.comps.get(callee, ())):
            if "ROOT" in line:
                m = _OP_RE.match(line)
                if m:
                    return m.group(3), line
        return None

    def _fusion_bytes(self, comp: str, line: str, callee: Optional[str]) -> float:
        """HBM traffic of a fusion op — boundary tensors, with in-place
        slice-update fusions counted at their UPDATE size (not the full
        aliased buffer: a scan's ys-stacking DUS writes one slice/iter)."""
        m = _OP_RE.match(line)
        result_type = m.group(2)
        try:
            args = line.split(m.group(3) + "(", 1)[1]
            depth, end = 1, 0
            for i, ch in enumerate(args):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        end = i
                        break
            operands = _split_operands(args[:end])
        except IndexError:
            operands = []
        root = self._root_of(callee) if callee else None
        res_b = _type_bytes(result_type)
        op_bytes = [_type_bytes(self._operand_type(comp, o)) for o in operands]
        # in-place slice-update fusions (any DUS in the callee writing a
        # buffer of the fusion's result type): count the UPDATE, not the
        # aliased accumulator — a scan's ys-stacking writes one slice/iter.
        dus_upd = self._dus_update_bytes(callee, res_b) if callee else None
        if dus_upd is not None:
            small = sum(b for b in op_bytes if b < res_b)
            return float(2 * dus_upd + small)
        if root and root[0] == "dynamic-slice":
            small = sum(b for b in op_bytes if b < res_b)
            return float(2 * res_b + small)
        return float(res_b + sum(op_bytes))

    def _dus_update_bytes(self, callee: str, res_b: int) -> Optional[float]:
        """If the callee contains a dynamic-update-slice whose target is as
        large as the fusion result, return the update operand's bytes."""
        for line in self.comps.get(callee, ()):
            if "dynamic-update-slice(" not in line:
                continue
            m = _OP_RE.match(line)
            if not m:
                continue
            if _type_bytes(m.group(2)) < res_b:
                continue  # small internal DUS, not the accumulator
            rargs = line.split("dynamic-update-slice(", 1)[-1]
            rops = _split_operands(rargs.split("), ")[0].rstrip(") "))
            if len(rops) > 1:
                upd = self._operand_type(callee, rops[1])
                return float(_type_bytes(upd))
        return None

    # -- per-computation ----------------------------------------------------
    def comp_cost(self, name: str) -> Cost:
        if name in self._memo:
            return self._memo[name]
        self._memo[name] = Cost()  # cycle guard
        cost = Cost()
        for line in self.comps.get(name, ()):
            parsed = self._op_cost(name, line, cost)
            if parsed is None:
                continue
            opcode, full = parsed
            if opcode == "fusion":
                cm = _CALLS_RE.search(full)
                callee = cm.group(1) if cm else None
                if callee in self.comps:
                    sub = self.comp_cost(callee)
                    # fusions: inherit compute, NOT bytes (on-chip internals)
                    cost.mxu_flops += sub.mxu_flops
                    cost.vpu_flops += sub.vpu_flops
                    cost.coll_link_bytes += sub.coll_link_bytes
                b = self._fusion_bytes(name, full, callee)
                cost.bytes += b
                t = tag_of(full)
                cost.bytes_by_tag[t] = cost.bytes_by_tag.get(t, 0.0) + b
            elif opcode == "while":
                bm = _BODY_RE.search(full)
                tm = _TRIP_RE.search(full)
                trips = float(tm.group(1)) if tm else 1.0
                if bm and bm.group(1) in self.comps:
                    cost.add(self.comp_cost(bm.group(1)), trips)
            elif opcode == "conditional":
                bm = _BRANCH_RE.search(full)
                if bm:
                    for cname in bm.group(1).split(","):
                        cname = cname.strip().lstrip("%")
                        if cname in self.comps:
                            cost.add(self.comp_cost(cname), 1.0)
            elif opcode in ("call", "async-start"):
                cm = _APPLY_RE.search(full) or _CALLS_RE.search(full)
                if cm and cm.group(1) in self.comps:
                    cost.add(self.comp_cost(cm.group(1)), 1.0)
            # reduce/sort/map to_apply bodies: per-element scalar computations,
            # approximated by the vpu count above; skip descending.
        self._memo[name] = cost
        return cost

    def entry_cost(self) -> Cost:
        assert self.entry, "no ENTRY computation found"
        return self.comp_cost(self.entry)


def normalize_cost_analysis(ca) -> Dict[str, float]:
    """Normalize ``Compiled.cost_analysis()`` across JAX versions.

    Older releases return a single dict, some return a one-element list of
    per-device dicts, newer ones return a flat dict again; ``None`` shows up
    for trivially-empty programs. Always returns one {property: value} dict.
    """
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def xla_cost_analysis(compiled) -> Dict[str, float]:
    """``compiled.cost_analysis()`` as one dict, version-independent."""
    return normalize_cost_analysis(compiled.cost_analysis())


def analyze_hlo_text(hlo_text: str, num_devices: int) -> Dict:
    model = HloCostModel(hlo_text, num_devices)
    c = model.entry_cost()
    return {
        "mxu_flops_per_device": c.mxu_flops,
        "vpu_flops_per_device": c.vpu_flops,
        "bytes_per_device": c.bytes,
        "bytes_by_tag": c.bytes_by_tag,
        "collective_bytes_total": c.coll_link_bytes,
        "collective_bytes_by_kind": c.coll_by_kind,
        "collective_op_counts": {k: int(v) for k, v in c.coll_counts.items()},
    }
