"""Render EXPERIMENTS.md roofline tables from experiments/dryrun/*.json.

    PYTHONPATH=src python -m repro.roofline.report
"""
from __future__ import annotations

import json
from pathlib import Path

DRYRUN_DIR = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"

SHAPE_ORDER = ("train_4k", "prefill_32k", "decode_32k", "long_500k")
ARCH_ORDER = ("granite-moe-1b-a400m", "internvl2-2b", "granite-moe-3b-a800m",
              "jamba-1.5-large-398b", "gemma3-27b", "whisper-tiny", "olmo-1b",
              "yi-6b", "llama3.2-3b", "rwkv6-3b")


def load(mesh: str = "16x16", tag: str = "") -> dict:
    out = {}
    for f in sorted(DRYRUN_DIR.glob(f"*_{mesh}{tag}.json")):
        if not f.stem.endswith(f"_{mesh}{tag}"):
            continue  # e.g. *_16x16 glob also matches *_2x16x16
        rec = json.loads(f.read_text())
        out[(rec["arch"], rec["shape"])] = rec
    return out


def _fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x * 1e3:.1f}ms"
    return f"{x * 1e6:.0f}us"


def roofline_table(recs: dict) -> str:
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "MODEL_FLOPS/HLO | status |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None:
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | missing |")
                continue
            if r["status"] != "ok":
                note = (r.get("notes") or [r.get("error", "")])[0][:50]
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | "
                             f"{r['status']}: {note} |")
                continue
            rf = r["roofline"]
            ratio = r.get("useful_flops_ratio")
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(rf['compute_s'])} | "
                f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
                f"{rf['dominant'].replace('_s', '')} | "
                f"{ratio:.2f} | ok |" if ratio is not None else
                f"| {arch} | {shape} | {_fmt_s(rf['compute_s'])} | "
                f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
                f"{rf['dominant'].replace('_s', '')} | - | ok |")
    return "\n".join(lines)


def dryrun_table(recs: dict) -> str:
    lines = [
        "| arch | shape | args/dev | temp/dev | HLO GFLOPs/dev | "
        "HLO GB/dev | coll GB total | top collectives |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            r = recs.get((arch, shape))
            if r is None or r["status"] != "ok":
                status = "missing" if r is None else r["status"]
                lines.append(f"| {arch} | {shape} | - | - | - | - | - | {status} |")
                continue
            mem = r.get("memory_analysis") or {}
            args = mem.get("argument_size_in_bytes", 0) / 2**30
            temp = mem.get("temp_size_in_bytes", 0) / 2**30
            gf = r.get("hlo_flops_per_device", 0) / 1e9
            gb = r.get("hlo_bytes_per_device", 0) / 2**30
            cb = r.get("collective_bytes_total", 0) / 2**30
            counts = r.get("collective_op_counts", {})
            top = ",".join(f"{k.split('-')[1] if '-' in k else k}:{v}"
                           for k, v in sorted(counts.items(),
                                              key=lambda kv: -kv[1]) if v)[:48]
            lines.append(f"| {arch} | {shape} | {args:.2f}G | {temp:.2f}G | "
                         f"{gf:,.0f} | {gb:.1f} | {cb:,.0f} | {top} |")
    return "\n".join(lines)


def multipod_status(recs_sp: dict, recs_mp: dict) -> str:
    lines = ["| arch | shape | 16x16 | 2x16x16 |", "|---|---|---|---|"]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            a = recs_sp.get((arch, shape))
            b = recs_mp.get((arch, shape))
            sa = a["status"] if a else "missing"
            sb = b["status"] if b else "missing"
            lines.append(f"| {arch} | {shape} | {sa} | {sb} |")
    return "\n".join(lines)


def delta_table(base: dict, opt: dict) -> str:
    """Baseline vs optimized, per (arch, shape) where both exist."""
    lines = [
        "| arch | shape | term | baseline | optimized | delta |",
        "|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            b, o = base.get((arch, shape)), opt.get((arch, shape))
            if not b or not o or b["status"] != "ok" or o["status"] != "ok":
                continue
            br, orr = b["roofline"], o["roofline"]
            for term in ("compute_s", "memory_s", "collective_s"):
                if br[term] <= 0:
                    continue
                d = (orr[term] - br[term]) / br[term]
                if abs(d) < 0.02 and term != br["dominant"]:
                    continue
                mark = " **dom**" if term == br["dominant"] else ""
                lines.append(
                    f"| {arch} | {shape} | {term.replace('_s','')}{mark} | "
                    f"{_fmt_s(br[term])} | {_fmt_s(orr[term])} | {d:+.1%} |")
    return "\n".join(lines)


def main():
    sp = load("16x16")
    opt = load("16x16", tag="_opt")
    mp = load("2x16x16")
    print("## Single-pod roofline — BASELINE (paper-faithful) (16x16)\n")
    print(roofline_table(sp))
    if opt:
        print("\n## Single-pod roofline — OPTIMIZED (§Perf profile) (16x16)\n")
        print(roofline_table(opt))
        print("\n## Baseline -> optimized deltas (changed terms)\n")
        print(delta_table(sp, opt))
    print("\n## Dry-run detail (16x16, baseline)\n")
    print(dryrun_table(sp))
    print("\n## Multi-pod lowering status\n")
    print(multipod_status(sp, mp))


if __name__ == "__main__":
    main()
