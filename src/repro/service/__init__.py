"""Online unlearning service — event-driven request scheduling with async
multi-device dispatch and SLA-measured serving.

The batch-replay ``FederatedSession`` serves a *fixed* schedule between
training stages; this package serves an *online stream*: seeded workload
generators produce arrival traces on a virtual clock (``workload``),
pluggable scheduling policies decide when and how requests coalesce
(``policy``: ``fifo`` / ``window`` / ``sla``), a ``DevicePlacement`` spreads
the independent shard-retraining programs across ``jax.devices()`` with
asynchronous dispatch (``placement``), and the engine's ledger measures
per-request latency (queue wait, batch wait, retrain wall), p50/p95/p99,
throughput, and SLA hit rate (``engine``).

    trace = poisson_trace(plan.clients, n=16, rate=8.0, seed=0)
    service = UnlearningService(session, policy="window",
                                policy_opts={"width": 0.5})
    report = service.serve(trace)
    print(report.p95, report.throughput)
"""
from repro.service.engine import (LedgerEntry, RetryPolicy,  # noqa: F401
                                  ServiceReport, UnlearningService)
from repro.service.placement import (DevicePlacement,  # noqa: F401
                                     single_device_placement)
from repro.service.policy import (POLICIES, BatchWindowPolicy,  # noqa: F401
                                  FIFOPolicy, Pending, SLAPolicy,
                                  SchedulingPolicy, make_policy,
                                  register_policy)
from repro.service.workload import (ServiceRequest, VirtualClock,  # noqa: F401
                                    bursty_trace, client_sampler,
                                    iter_poisson_trace, iter_trace,
                                    load_trace, poisson_trace, save_trace,
                                    save_trace_jsonl, sequenced_trace,
                                    service_request_id)
