"""The online unlearning service engine: event loop, async dispatch, SLA
ledger.

``UnlearningService`` turns a trained ``FederatedSession`` into a server for
a *stream* of unlearning requests:

1. **Schedule** (deterministic, virtual time): arrivals from the workload
   trace are admitted to a queue as the discrete-event clock advances; the
   scheduling policy (``repro.service.policy``) decides when queued requests
   dispatch and which coalesce into one batch.  Nothing here reads the wall
   clock, so the dispatch plan is a pure function of (trace, policy,
   session) — reproducible run-to-run.
2. **Dispatch** (asynchronous, measured): each batch's requests merge per
   compatible serving options (the session's union-of-clients semantics);
   every impacted (stage, shard) becomes an independent shard-retraining
   job placed on a device by ``DevicePlacement`` and dispatched without
   blocking.  ``block_until_ready`` happens only at the request-completion
   ledger, inside the worker that ran the job.
3. **Ledger**: per request — queue wait (virtual), batch wait (measured
   executor delay), retrain wall (measured), end-to-end latency, SLA
   verdict — aggregated into a ``ServiceReport`` with p50/p95/p99 latency
   and throughput, exported via ``to_json`` into the BENCH trajectory.

Serving runs in **throughput mode**: batches are dispatched back-to-back
as fast as the placement accepts them, not paced to the virtual timeline
(virtual seconds are not wall seconds).  On a multi-batch trace a later
batch's measured ``batch_wait`` can therefore include capacity contention
from earlier batches that, on the virtual timeline, would already have
drained during its (separately charged) ``queue_wait`` — latencies and SLA
verdicts are *conservative upper bounds*: a paced real-time server would
see equal or lower latency, and ``sla_met=True`` here is always true
there.

The sequential baseline (``policy="fifo"`` + ``single_device_placement()``)
takes the exact same code path as ``FederatedSession.run`` serving the same
trace — single-victim serves are bit-identical (the service-layer test
asserts it).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.fl.experiment.frameworks import (FRAMEWORKS, UnlearnContext,
                                            get_framework, run_prepared_job)
from repro.fl.experiment.session import UnlearnRequest
from repro.fl.simulator import UnlearnResult
from repro.service.placement import DevicePlacement
from repro.service.policy import Pending, SchedulingPolicy, make_policy
from repro.service.workload import ServiceRequest, VirtualClock


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------

@dataclass
class LedgerEntry:
    """One served request's latency decomposition.

    ``queue_wait`` is virtual (arrival -> policy release, deterministic);
    ``batch_wait`` and ``retrain_wall`` are measured — dispatch -> first job
    start (waiting for a free device/worker), and first job start -> last
    job blocked (the retraining itself).  ``latency`` =
    ``queue_wait + batch_wait + retrain_wall`` — the end-to-end figure the
    SLA verdict uses.
    """
    rid: int
    arrival: float
    clients: Tuple[int, ...]
    framework: str
    batch_id: int
    queue_wait: float = 0.0
    batch_wait: float = 0.0
    retrain_wall: float = 0.0
    latency: float = 0.0
    n_jobs: int = 0
    devices: List[int] = field(default_factory=list)
    impacted: List[Tuple[int, int]] = field(default_factory=list)
    cost_units: float = 0.0
    deadline: Optional[float] = None
    sla_met: Optional[bool] = None

    def to_dict(self) -> dict:
        return {
            "rid": self.rid, "arrival_s": self.arrival,
            "clients": list(self.clients), "framework": self.framework,
            "batch_id": self.batch_id, "queue_wait_s": self.queue_wait,
            "batch_wait_s": self.batch_wait,
            "retrain_wall_s": self.retrain_wall, "latency_s": self.latency,
            "n_jobs": self.n_jobs, "devices": list(self.devices),
            "impacted": [list(p) for p in self.impacted],
            "cost_units": self.cost_units, "deadline_s": self.deadline,
            "sla_met": self.sla_met,
        }


@dataclass
class ServiceReport:
    """Per-request ledger plus the serving aggregates the paper's SLA story
    needs: latency percentiles, throughput, batching/placement effect."""
    entries: List[LedgerEntry] = field(default_factory=list)
    policy: dict = field(default_factory=dict)
    placement: dict = field(default_factory=dict)
    serve_wall: float = 0.0
    num_batches: int = 0

    # ------------------------------------------------------------ aggregates
    @property
    def latencies(self) -> np.ndarray:
        return np.asarray([e.latency for e in self.entries], np.float64)

    def percentile(self, q: float) -> float:
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else 0.0

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def throughput(self) -> float:
        """Requests served per measured serving second."""
        return len(self.entries) / self.serve_wall if self.serve_wall else 0.0

    @property
    def sla_hit_rate(self) -> Optional[float]:
        verdicts = [e.sla_met for e in self.entries if e.sla_met is not None]
        if not verdicts:
            return None
        return sum(verdicts) / len(verdicts)

    @property
    def total_retrain_wall(self) -> float:
        return sum(e.retrain_wall for e in self.entries)

    def to_dict(self) -> dict:
        return {
            "policy": self.policy,
            "placement": self.placement,
            "num_requests": len(self.entries),
            "num_batches": self.num_batches,
            "serve_wall_s": self.serve_wall,
            "throughput_rps": self.throughput,
            "latency_p50_s": self.p50,
            "latency_p95_s": self.p95,
            "latency_p99_s": self.p99,
            "sla_hit_rate": self.sla_hit_rate,
            "requests": [e.to_dict() for e in self.entries],
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)


# ---------------------------------------------------------------------------
# Internal dispatch records
# ---------------------------------------------------------------------------

@dataclass
class _Batch:
    bid: int
    time: float                       # virtual release time
    pendings: List[Pending]


@dataclass
class _Serve:
    """One merged request-group in flight: its per-stage job futures plus
    everything the gather pass needs to assemble ``UnlearnResult``s and
    ledger entries."""
    batch: _Batch
    requests: List[Pending]
    framework: str
    rounds: Optional[int]
    apply: bool
    clients: List[int]
    stage_ctxs: Dict[int, UnlearnContext] = field(default_factory=dict)
    stage_jobs: Dict[int, list] = field(default_factory=dict)  # futures
    dispatch_off: float = 0.0          # wall offset at dispatch


class UnlearningService:
    """Event-driven serving of unlearning requests against a trained
    ``FederatedSession``.

    >>> service = UnlearningService(session, policy="window",
    ...                             policy_opts={"width": 0.5})
    >>> report = service.serve(poisson_trace(plan.clients, n=16, rate=8.0))
    >>> print(report.p95, report.throughput)
    """

    def __init__(self, session, policy="fifo",
                 policy_opts: Optional[dict] = None,
                 placement: Optional[DevicePlacement] = None):
        self.session = session
        self.policy: SchedulingPolicy = (
            make_policy(policy, **(policy_opts or {}))
            if isinstance(policy, str) else policy)
        self.placement = placement or DevicePlacement()

    # ----------------------------------------------------------- scheduling
    def _impact_of(self, req: ServiceRequest) -> frozenset:
        """What the request's framework reports it would retrain — the
        (stage, shard) pairs the scheduler merges and places by."""
        fw_cls = FRAMEWORKS.get(req.framework)
        if fw_cls is None:
            raise ValueError(f"unknown unlearning framework "
                             f"{req.framework!r} in request {req.rid}")
        out = set()
        for i, rec in enumerate(self.session.records):
            stage_clients = [c for c in req.clients
                             if c in set(rec.plan.clients)]
            if not stage_clients:
                continue
            for s in fw_cls.impacted_shards(rec.plan, stage_clients):
                out.add((i, s))
        return frozenset(out)

    def plan_schedule(self, trace: Sequence[ServiceRequest]) -> List[_Batch]:
        """The deterministic half: run the discrete-event loop over the
        trace and return the dispatch plan (who batches with whom, when).
        Pure virtual time — no wall clock, no device work."""
        arrivals = sorted(trace, key=lambda r: (r.t, r.rid))
        clock = VirtualClock()
        queue: List[Pending] = []
        batches: List[_Batch] = []
        i = 0
        while i < len(arrivals) or queue:
            candidates = []
            if i < len(arrivals):
                candidates.append(arrivals[i].t)
            t_policy = self.policy.next_event(queue, clock.now)
            if t_policy is not None:
                candidates.append(t_policy)
            final = not candidates
            if candidates:
                clock.advance_to(min(candidates))
            while i < len(arrivals) and arrivals[i].t <= clock.now:
                req = arrivals[i]
                queue.append(Pending(req, impacted=self._impact_of(req)))
                i += 1
            for group in self.policy.release(queue, clock.now, final=final):
                batches.append(_Batch(len(batches), clock.now, group))
            if final and queue:
                # a policy that neither timed out nor drained would hang the
                # loop — force the remainder out as one final batch
                batches.append(_Batch(len(batches), clock.now, list(queue)))
                queue.clear()
        return batches

    # ------------------------------------------------------------- dispatch
    def _merge_groups(self, batch: _Batch) -> List[_Serve]:
        """Union-of-clients merge per compatible serving options — the same
        grouping rule as ``FederatedSession.unlearn_batch``."""
        groups: Dict[tuple, _Serve] = {}
        for p in batch.pendings:
            key = (p.req.framework, p.req.rounds, p.req.apply)
            serve = groups.get(key)
            if serve is None:
                serve = groups[key] = _Serve(
                    batch=batch, requests=[], framework=p.req.framework,
                    rounds=p.req.rounds, apply=p.req.apply, clients=[])
            serve.requests.append(p)
            for c in p.req.clients:
                if c not in serve.clients:
                    serve.clients.append(c)
        return list(groups.values())

    def _job_shard(self, serve: _Serve, stage: int, shard: int,
                   dev_idx: int, t0: float):
        """Worker body for one shard-level retraining job: prepare from the
        (lock-protected) store, commit to the assigned device, dispatch the
        G' calibration rounds asynchronously, and block only on this job's
        own outputs — the completion ledger."""
        ctx = serve.stage_ctxs[stage]
        fw = get_framework(serve.framework)
        start = time.perf_counter() - t0
        job = fw.prepare_shard_job(ctx, shard)
        if job is None:
            return {"models": {}, "cost": 0.0, "start": start,
                    "done": time.perf_counter() - t0, "device": dev_idx}
        device = self.placement.device_of(dev_idx)
        s, w, cost = run_prepared_job(ctx, job, device=device)
        jax.block_until_ready(w)
        return {"models": {s: w}, "cost": cost, "start": start,
                "done": time.perf_counter() - t0, "device": dev_idx}

    def _job_federation(self, serve: _Serve, stage: int, dev_idx: int,
                        t0: float):
        """Worker body for a federation-level framework (FE/FR/RR): one job
        retraining everything — still dispatched asynchronously so it
        overlaps with other in-flight serves."""
        ctx = serve.stage_ctxs[stage]
        fw = get_framework(serve.framework)
        start = time.perf_counter() - t0
        models, cost = fw.run(ctx)
        jax.block_until_ready(list(models.values()))
        return {"models": models, "cost": cost, "start": start,
                "done": time.perf_counter() - t0, "device": dev_idx}

    def _dispatch(self, serves: List[_Serve], t0: float):
        for serve in serves:
            serve.dispatch_off = time.perf_counter() - t0
            sim = self.session.sim
            # resolve against completed stages (session step-wise API)
            request = UnlearnRequest(serve.clients,
                                     framework=serve.framework,
                                     rounds=serve.rounds, apply=serve.apply)
            _clients, stage_plan = self.session.resolve_request(request)
            fw_cls = FRAMEWORKS[serve.framework]
            rounds = (serve.rounds or self.session.rounds
                      or sim.fl.global_rounds)
            for i, stage_clients in stage_plan.items():
                record = self.session.records[i]
                ctx = UnlearnContext(sim, record, list(stage_clients), rounds)
                serve.stage_ctxs[i] = ctx
                futures = []
                if fw_cls.shard_level:
                    for shard in ctx.impacted:
                        dev = self.placement.assign()
                        futures.append(self.placement.submit(
                            self._job_shard, serve, i, shard, dev, t0))
                else:
                    dev = self.placement.assign()
                    futures.append(self.placement.submit(
                        self._job_federation, serve, i, dev, t0))
                serve.stage_jobs[i] = futures

    # --------------------------------------------------------------- gather
    def _gather(self, serves: List[_Serve], report: ServiceReport, t0: float):
        for serve in serves:
            outs = {i: [f.result() for f in futs]
                    for i, futs in serve.stage_jobs.items()}
            starts = [o["start"] for os_ in outs.values() for o in os_]
            dones = [o["done"] for os_ in outs.values() for o in os_]
            devices = sorted({o["device"] for os_ in outs.values()
                              for o in os_})
            done_off = max(dones, default=serve.dispatch_off)
            # land per-stage UnlearnResults through the session report
            total_cost = 0.0
            for i, os_ in sorted(outs.items()):
                ctx = serve.stage_ctxs[i]
                record = self.session.records[i]
                fw_cls = FRAMEWORKS[serve.framework]
                if fw_cls.shard_level:
                    models = dict(record.shard_models)
                else:
                    models = {}
                cost = 0.0
                for o in os_:
                    models.update(o["models"])
                    cost += o["cost"]
                total_cost += cost
                stage_dones = [o["done"] for o in os_]
                res = UnlearnResult(
                    serve.framework, models,
                    max(stage_dones, default=serve.dispatch_off)
                    - serve.dispatch_off,
                    cost, getattr(record.store, "stats", None), ctx.impacted)
                self.session.record_result(i, res, apply=serve.apply)
            # one ledger entry per ORIGINAL request in the merged group
            start_off = min(starts) if starts else serve.dispatch_off
            batch_wait = start_off - serve.dispatch_off
            retrain_wall = done_off - start_off
            for p in serve.requests:
                queue_wait = serve.batch.time - p.req.t
                latency = queue_wait + batch_wait + retrain_wall
                entry = LedgerEntry(
                    rid=p.req.rid, arrival=p.req.t, clients=p.req.clients,
                    framework=serve.framework, batch_id=serve.batch.bid,
                    queue_wait=queue_wait, batch_wait=batch_wait,
                    retrain_wall=retrain_wall, latency=latency,
                    n_jobs=sum(len(v) for v in outs.values()),
                    devices=devices, impacted=sorted(p.impacted),
                    cost_units=total_cost / max(len(serve.requests), 1),
                    deadline=p.req.deadline,
                    sla_met=(latency <= p.req.deadline
                             if p.req.deadline is not None else None))
                report.entries.append(entry)

    # ---------------------------------------------------------------- serve
    def serve(self, trace: Sequence[ServiceRequest]) -> ServiceReport:
        """Serve the whole trace: plan the dispatch schedule (virtual,
        deterministic), dispatch every batch's shard programs across the
        placement without blocking, then gather completions into the
        ledger.  Returns the ``ServiceReport``."""
        if not self.session.records:
            raise RuntimeError("train at least one stage before serving")
        batches = self.plan_schedule(trace)
        self.placement.reset_assignment()
        report = ServiceReport(policy=self.policy.describe(),
                               placement=self.placement.describe(),
                               num_batches=len(batches))
        t0 = time.perf_counter()
        all_serves: List[_Serve] = []
        for batch in batches:
            serves = self._merge_groups(batch)
            self._dispatch(serves, t0)
            all_serves.extend(serves)
        self._gather(all_serves, report, t0)
        report.serve_wall = time.perf_counter() - t0
        report.placement = self.placement.describe()   # incl. job counters
        report.entries.sort(key=lambda e: e.rid)
        return report
