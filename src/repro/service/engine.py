"""The online unlearning service engine: event loop, async dispatch, SLA
ledger.

``UnlearningService`` turns a trained ``FederatedSession`` into a server for
a *stream* of unlearning requests:

1. **Schedule** (deterministic, virtual time): arrivals from the workload
   trace are admitted to a queue as the discrete-event clock advances; the
   scheduling policy (``repro.service.policy``) decides when queued requests
   dispatch and which coalesce into one batch.  Nothing here reads the wall
   clock, so the dispatch plan is a pure function of (trace, policy,
   session) — reproducible run-to-run.
2. **Dispatch** (asynchronous, measured): each batch's requests merge per
   compatible serving options (the session's union-of-clients semantics);
   every impacted (stage, shard) becomes an independent shard-retraining
   job placed on a device by ``DevicePlacement`` and dispatched without
   blocking.  ``block_until_ready`` happens only at the request-completion
   ledger, inside the worker that ran the job.
3. **Ledger**: per request — queue wait (virtual), batch wait (measured
   executor delay), retrain wall (measured), end-to-end latency, SLA
   verdict — aggregated into a ``ServiceReport`` with p50/p95/p99 latency
   and throughput, exported via ``to_json`` into the BENCH trajectory.

Serving runs in **throughput mode**: batches are dispatched back-to-back
as fast as the placement accepts them, not paced to the virtual timeline
(virtual seconds are not wall seconds).  On a multi-batch trace a later
batch's measured ``batch_wait`` can therefore include capacity contention
from earlier batches that, on the virtual timeline, would already have
drained during its (separately charged) ``queue_wait`` — latencies and SLA
verdicts are *conservative upper bounds*: a paced real-time server would
see equal or lower latency, and ``sla_met=True`` here is always true
there.

The sequential baseline (``policy="fifo"`` + ``single_device_placement()``)
takes the exact same code path as ``FederatedSession.run`` serving the same
trace — single-victim serves are bit-identical (the service-layer test
asserts it).
"""
from __future__ import annotations

import json
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.faults.events import (DeviceFault, FaultError, JobHang,
                                 RecoveryEvent)
from repro.fl.experiment.frameworks import (FRAMEWORKS, UnlearnContext,
                                            get_framework, run_prepared_job)
from repro.fl.experiment.session import UnlearnRequest
from repro.fl.simulator import UnlearnResult
from repro.service.placement import DevicePlacement
from repro.service.policy import Pending, SchedulingPolicy, make_policy
from repro.service.workload import (ServiceRequest, VirtualClock,
                                    service_request_id)
from repro.telemetry import AuditLog, get_tracer


@dataclass(frozen=True)
class RetryPolicy:
    """How the service reacts to a failed job attempt.

    ``max_retries`` bounds re-dispatches per job (after which the job aborts
    cleanly into the ledger); ``backoff``/``backoff_factor``/``max_backoff``
    shape the bounded exponential sleep between attempts.  ``timeout`` caps
    the *simulated* hang of an injected ``JobHang`` — it deliberately does
    NOT arm a wall-clock watchdog on real jobs, because elapsed-time-based
    fault events would vary run-to-run and break ledger replay (and a stuck
    XLA program cannot be preempted from a worker thread anyway; genuine
    hang isolation needs a process boundary).
    """
    max_retries: int = 2
    timeout: Optional[float] = None
    backoff: float = 0.02
    backoff_factor: float = 2.0
    max_backoff: float = 0.25

    def backoff_for(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (1-based)."""
        return min(self.backoff * self.backoff_factor ** (attempt - 1),
                   self.max_backoff)

    def describe(self) -> dict:
        return {"max_retries": self.max_retries, "timeout": self.timeout,
                "backoff": self.backoff,
                "backoff_factor": self.backoff_factor,
                "max_backoff": self.max_backoff}


# ---------------------------------------------------------------------------
# Ledger
# ---------------------------------------------------------------------------

@dataclass
class LedgerEntry:
    """One served request's latency decomposition.

    ``queue_wait`` is virtual (arrival -> policy release, deterministic);
    ``batch_wait`` and ``retrain_wall`` are measured — dispatch -> first job
    start (waiting for a free device/worker), and first job start -> last
    job blocked (the retraining itself).  ``latency`` =
    ``queue_wait + batch_wait + retrain_wall`` — the end-to-end figure the
    SLA verdict uses.
    """
    rid: int
    arrival: float
    clients: Tuple[int, ...]
    framework: str
    batch_id: int
    queue_wait: float = 0.0
    batch_wait: float = 0.0
    retrain_wall: float = 0.0
    latency: float = 0.0
    n_jobs: int = 0
    devices: List[int] = field(default_factory=list)
    impacted: List[Tuple[int, int]] = field(default_factory=list)
    cost_units: float = 0.0
    deadline: Optional[float] = None
    sla_met: Optional[bool] = None
    job_attempts: int = 0             # total attempts across this serve's jobs
    job_retries: int = 0              # attempts beyond the first
    aborted: bool = False             # some job exhausted its retry budget
    request_id: str = ""              # stable idempotency key (svc-<rid> fallback)

    def to_dict(self) -> dict:
        return {
            "request_id": self.request_id or f"svc-{self.rid}",
            "rid": self.rid, "arrival_s": self.arrival,
            "clients": list(self.clients), "framework": self.framework,
            "batch_id": self.batch_id, "queue_wait_s": self.queue_wait,
            "batch_wait_s": self.batch_wait,
            "retrain_wall_s": self.retrain_wall, "latency_s": self.latency,
            "n_jobs": self.n_jobs, "devices": list(self.devices),
            "impacted": [list(p) for p in self.impacted],
            "cost_units": self.cost_units, "deadline_s": self.deadline,
            "sla_met": self.sla_met, "job_attempts": self.job_attempts,
            "job_retries": self.job_retries, "aborted": self.aborted,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "LedgerEntry":
        """Inverse of ``to_dict`` — journal replay rebuilds committed
        entries bit-identically from their ``svc_commit`` payloads."""
        return cls(
            rid=int(d["rid"]), arrival=float(d["arrival_s"]),
            clients=tuple(int(c) for c in d["clients"]),
            framework=d["framework"], batch_id=int(d["batch_id"]),
            queue_wait=float(d["queue_wait_s"]),
            batch_wait=float(d["batch_wait_s"]),
            retrain_wall=float(d["retrain_wall_s"]),
            latency=float(d["latency_s"]), n_jobs=int(d["n_jobs"]),
            devices=[int(x) for x in d["devices"]],
            impacted=[tuple(p) for p in d["impacted"]],
            cost_units=float(d["cost_units"]),
            deadline=d["deadline_s"], sla_met=d["sla_met"],
            job_attempts=int(d["job_attempts"]),
            job_retries=int(d["job_retries"]),
            aborted=bool(d["aborted"]),
            request_id=str(d.get("request_id", "")))


@dataclass
class ServiceReport:
    """Per-request ledger plus the serving aggregates the paper's SLA story
    needs: latency percentiles, throughput, batching/placement effect."""
    entries: List[LedgerEntry] = field(default_factory=list)
    policy: dict = field(default_factory=dict)
    placement: dict = field(default_factory=dict)
    serve_wall: float = 0.0
    num_batches: int = 0
    faults: dict = field(default_factory=dict)   # attempts/retries/recoveries

    # ------------------------------------------------------------ aggregates
    @property
    def completed(self) -> List[LedgerEntry]:
        """Entries whose jobs all finished (aborted serves excluded — their
        latencies describe the failure, not the service)."""
        return [e for e in self.entries if not e.aborted]

    @property
    def latencies(self) -> np.ndarray:
        return np.asarray([e.latency for e in self.completed], np.float64)

    def percentile(self, q: float) -> float:
        """Latency percentile over completed requests; ``nan`` when the
        ledger is empty or every request aborted (never raises)."""
        lat = self.latencies
        return float(np.percentile(lat, q)) if lat.size else float("nan")

    @property
    def p50(self) -> float:
        return self.percentile(50)

    @property
    def p95(self) -> float:
        return self.percentile(95)

    @property
    def p99(self) -> float:
        return self.percentile(99)

    @property
    def throughput(self) -> float:
        """Completed requests per measured serving second; ``nan`` for an
        empty/all-aborted ledger or an unmeasured serve (never raises)."""
        done = len(self.completed)
        if not done or self.serve_wall <= 0.0:
            return float("nan")
        return done / self.serve_wall

    @property
    def sla_hit_rate(self) -> Optional[float]:
        """Fraction of deadline-carrying completed requests that met their
        deadline; ``None`` when no completed request had a deadline."""
        verdicts = [e.sla_met for e in self.completed
                    if e.sla_met is not None]
        if not verdicts:
            return None
        return sum(verdicts) / len(verdicts)

    @property
    def num_aborted(self) -> int:
        return sum(1 for e in self.entries if e.aborted)

    @property
    def total_retrain_wall(self) -> float:
        return sum(e.retrain_wall for e in self.entries)

    def per_client_p99(self) -> Dict[int, float]:
        """{client: p99 latency} over completed requests naming the client —
        the per-client breakdown aggregate percentiles hide (ROADMAP item 3:
        a hot client can starve behind a healthy aggregate p99)."""
        by_client: Dict[int, List[float]] = {}
        for e in self.completed:
            for c in e.clients:
                by_client.setdefault(int(c), []).append(e.latency)
        return {c: float(np.percentile(np.asarray(v, np.float64), 99))
                for c, v in sorted(by_client.items())}

    def to_dict(self) -> dict:
        d = {
            "policy": self.policy,
            "placement": self.placement,
            "num_requests": len(self.entries),
            "num_batches": self.num_batches,
            "num_aborted": self.num_aborted,
            "serve_wall_s": self.serve_wall,
            "throughput_rps": self.throughput,
            "latency_p50_s": self.p50,
            "latency_p95_s": self.p95,
            "latency_p99_s": self.p99,
            "sla_hit_rate": self.sla_hit_rate,
            "faults": self.faults,
            # keyed on the stable request_id, not list position, so journal
            # replay / resumed serves merge into an identical report
            "requests": {(e.request_id or f"svc-{e.rid}"): e.to_dict()
                         for e in self.entries},
            "client_latency_p99_s": {str(c): v for c, v
                                     in self.per_client_p99().items()},
        }
        tr = get_tracer()
        if tr.enabled:
            d["telemetry"] = tr.describe()
        return d

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)


# ---------------------------------------------------------------------------
# Internal dispatch records
# ---------------------------------------------------------------------------

@dataclass
class _Batch:
    bid: int
    time: float                       # virtual release time
    pendings: List[Pending]


@dataclass
class _Serve:
    """One merged request-group in flight: its per-stage job futures plus
    everything the gather pass needs to assemble ``UnlearnResult``s and
    ledger entries."""
    batch: _Batch
    requests: List[Pending]
    framework: str
    rounds: Optional[int]
    apply: bool
    clients: List[int]
    stage_ctxs: Dict[int, UnlearnContext] = field(default_factory=dict)
    stage_jobs: Dict[int, list] = field(default_factory=dict)  # futures
    dispatch_off: float = 0.0          # wall offset at dispatch


class UnlearningService:
    """Event-driven serving of unlearning requests against a trained
    ``FederatedSession``.

    >>> service = UnlearningService(session, policy="window",
    ...                             policy_opts={"width": 0.5})
    >>> report = service.serve(poisson_trace(plan.clients, n=16, rate=8.0))
    >>> print(report.p95, report.throughput)
    """

    def __init__(self, session, policy="fifo",
                 policy_opts: Optional[dict] = None,
                 placement: Optional[DevicePlacement] = None,
                 faults=None, retry: Optional[RetryPolicy] = None,
                 journal=None):
        self.session = session
        self.policy: SchedulingPolicy = (
            make_policy(policy, **(policy_opts or {}))
            if isinstance(policy, str) else policy)
        self.placement = placement or DevicePlacement()
        self.faults = faults                      # optional FaultPlan
        self.retry = retry or RetryPolicy()
        # optional repro.durability.Journal: svc_dispatch before any retrain
        # work, svc_commit (with the full ledger entry) after — a crash in
        # between leaves the id dispatched-but-uncommitted, and
        # serve(resume=True) re-dispatches it exactly once
        self.journal = journal
        # hash-chained lifecycle audit (received → scheduled → retrained →
        # committed); with a journal the chain is durable and a fresh service
        # on the same journal splices onto the existing chain (resume path)
        self.audit = AuditLog(journal=journal)

    def _journal(self, event: dict) -> None:
        if self.journal is not None:
            self.journal.append(event)

    # ------------------------------------------------------------- recovery
    def _attempt_with_retries(self, key: tuple, dev_idx: int, body):
        """Run ``body(dev_idx)`` with the service's recovery semantics:
        consult the fault plan per attempt (straggler delay / injected
        error), catch ONLY typed ``FaultError``s (genuine bugs propagate),
        mark failed/hung devices unhealthy and re-dispatch to the next
        healthy one, back off exponentially between attempts, and abort
        cleanly once ``retry.max_retries`` re-dispatches are spent.

        Returns ``(result_or_None, dev_idx, attempts, aborted)``.
        """
        plan, rp = self.faults, self.retry
        attempts = 0
        while True:
            attempts += 1
            try:
                err = None
                if plan is not None:
                    delay, err = plan.job_action(key, attempts, dev_idx)
                    if delay:
                        time.sleep(delay)
                if err is not None:
                    if isinstance(err, JobHang):
                        hang = err.hang_s if rp.timeout is None \
                            else min(err.hang_s, rp.timeout)
                        time.sleep(max(hang, 0.0))
                    raise err
                return body(dev_idx), dev_idx, attempts, False
            except FaultError as exc:
                if isinstance(exc, (DeviceFault, JobHang)):
                    self.placement.mark_unhealthy(dev_idx)
                if attempts > rp.max_retries:
                    if plan is not None:
                        plan.ledger.record(RecoveryEvent(
                            "abort", site=key,
                            detail=(attempts, type(exc).__name__)))
                    return None, dev_idx, attempts, True
                time.sleep(rp.backoff_for(attempts))
                if isinstance(exc, (DeviceFault, JobHang)):
                    # device-level fault: re-dispatch to the next healthy
                    # device (deterministic; never consumes the rr cursor)
                    dev_idx = self.placement.reassign(dev_idx)
                    event = "redispatch"
                else:
                    # job-level transient: same device, fresh attempt
                    event = "retry"
                if plan is not None:
                    plan.ledger.record(RecoveryEvent(
                        event, site=key,
                        detail=(attempts, type(exc).__name__)))

    # ----------------------------------------------------------- scheduling
    def _impact_of(self, req: ServiceRequest) -> frozenset:
        """What the request's framework reports it would retrain — the
        (stage, shard) pairs the scheduler merges and places by."""
        fw_cls = FRAMEWORKS.get(req.framework)
        if fw_cls is None:
            raise ValueError(f"unknown unlearning framework "
                             f"{req.framework!r} in request {req.rid}")
        out = set()
        for i, rec in enumerate(self.session.records):
            stage_clients = [c for c in req.clients
                             if c in set(rec.plan.clients)]
            if not stage_clients:
                continue
            for s in fw_cls.impacted_shards(rec.plan, stage_clients):
                out.add((i, s))
        return frozenset(out)

    def plan_schedule(self, trace) -> List[_Batch]:
        """The deterministic half: run the discrete-event loop over the
        trace and return the dispatch plan (who batches with whom, when).
        Pure virtual time — no wall clock, no device work.

        ``trace`` may be a materialized sequence (sorted here) or any
        iterable/generator (ROADMAP item 3a streaming replay: requests are
        admitted one at a time and never held as a list — the stream must
        arrive in non-decreasing ``t`` order, which the seeded ``iter_*``
        generators produce by construction).  Both forms plan, audit, and
        serve bit-identically for the same requests."""
        if isinstance(trace, Sequence):
            return self._plan_materialized(sorted(
                trace, key=lambda r: (r.t, r.rid)))
        return self._plan_stream(iter(trace))

    def _plan_materialized(self,
                           arrivals: List[ServiceRequest]) -> List[_Batch]:
        clock = VirtualClock()
        tr = get_tracer()
        # spans opened from here on carry the deterministic virtual time of
        # the discrete-event loop alongside their measured wall offsets
        tr.attach_clock(clock)
        for req in arrivals:
            self.audit.record("received",
                              request_id=service_request_id(req),
                              clients=list(req.clients),
                              framework=req.framework, t_virtual=req.t)
        queue: List[Pending] = []
        batches: List[_Batch] = []
        i = 0
        with tr.span("service.plan", requests=len(arrivals)) as sp:
            while i < len(arrivals) or queue:
                candidates = []
                if i < len(arrivals):
                    candidates.append(arrivals[i].t)
                t_policy = self.policy.next_event(queue, clock.now)
                if t_policy is not None:
                    candidates.append(t_policy)
                final = not candidates
                if candidates:
                    clock.advance_to(min(candidates))
                while i < len(arrivals) and arrivals[i].t <= clock.now:
                    req = arrivals[i]
                    queue.append(Pending(req, impacted=self._impact_of(req)))
                    i += 1
                for group in self.policy.release(queue, clock.now,
                                                 final=final):
                    batches.append(_Batch(len(batches), clock.now, group))
                if final and queue:
                    # a policy that neither timed out nor drained would hang
                    # the loop — force the remainder out as one final batch
                    batches.append(_Batch(len(batches), clock.now,
                                          list(queue)))
                    queue.clear()
            sp.annotate(batches=len(batches))
        self._audit_scheduled(batches)
        return batches

    def _plan_stream(self, it) -> List[_Batch]:
        """Streaming twin of ``_plan_materialized``: pulls one request ahead
        of the clock, records its ``received`` audit at admission (same
        sorted order the materialized path pre-records), and enforces the
        monotone-arrival contract a stream cannot be re-sorted around."""
        clock = VirtualClock()
        tr = get_tracer()
        tr.attach_clock(clock)
        queue: List[Pending] = []
        batches: List[_Batch] = []
        nxt = next(it, None)
        last_t = float("-inf")
        n = 0
        with tr.span("service.plan") as sp:
            while nxt is not None or queue:
                candidates = []
                if nxt is not None:
                    candidates.append(nxt.t)
                t_policy = self.policy.next_event(queue, clock.now)
                if t_policy is not None:
                    candidates.append(t_policy)
                final = not candidates
                if candidates:
                    clock.advance_to(min(candidates))
                while nxt is not None and nxt.t <= clock.now:
                    if nxt.t < last_t:
                        raise ValueError(
                            f"streamed trace is not time-ordered: request "
                            f"{nxt.rid} arrives at t={nxt.t} after t="
                            f"{last_t}; stream traces must be sorted "
                            f"(materialize + sort, or generate in order)")
                    last_t = nxt.t
                    self.audit.record("received",
                                      request_id=service_request_id(nxt),
                                      clients=list(nxt.clients),
                                      framework=nxt.framework,
                                      t_virtual=nxt.t)
                    queue.append(Pending(nxt,
                                         impacted=self._impact_of(nxt)))
                    n += 1
                    nxt = next(it, None)
                for group in self.policy.release(queue, clock.now,
                                                 final=final):
                    batches.append(_Batch(len(batches), clock.now, group))
                if final and queue:
                    batches.append(_Batch(len(batches), clock.now,
                                          list(queue)))
                    queue.clear()
            sp.annotate(requests=n, batches=len(batches))
        self._audit_scheduled(batches)
        return batches

    def _audit_scheduled(self, batches: List[_Batch]) -> None:
        for b in batches:
            for p in b.pendings:
                self.audit.record(
                    "scheduled", request_id=service_request_id(p.req),
                    batch_id=b.bid, t_virtual=b.time,
                    shards=[list(x) for x in sorted(p.impacted)])

    # ------------------------------------------------------------- dispatch
    def _merge_groups(self, batch: _Batch) -> List[_Serve]:
        """Union-of-clients merge per compatible serving options — the same
        grouping rule as ``FederatedSession.unlearn_batch``."""
        groups: Dict[tuple, _Serve] = {}
        for p in batch.pendings:
            key = (p.req.framework, p.req.rounds, p.req.apply)
            serve = groups.get(key)
            if serve is None:
                serve = groups[key] = _Serve(
                    batch=batch, requests=[], framework=p.req.framework,
                    rounds=p.req.rounds, apply=p.req.apply, clients=[])
            serve.requests.append(p)
            for c in p.req.clients:
                if c not in serve.clients:
                    serve.clients.append(c)
        return list(groups.values())

    def _job_shard(self, serve: _Serve, stage: int, shard: int,
                   dev_idx: int, t0: float):
        """Worker body for one shard-level retraining job: prepare from the
        (lock-protected) store, commit to the assigned device, dispatch the
        G' calibration rounds asynchronously, and block only on this job's
        own outputs — the completion ledger."""
        ctx = serve.stage_ctxs[stage]
        fw = get_framework(serve.framework)
        start = time.perf_counter() - t0

        def body(dev: int):
            job = fw.prepare_shard_job(ctx, shard)
            if job is None:
                return {"models": {}, "cost": 0.0}
            s, w, cost = run_prepared_job(ctx, job,
                                          device=self.placement.device_of(dev))
            jax.block_until_ready(w)
            return {"models": {s: w}, "cost": cost}

        key = ("shard", stage, shard, tuple(serve.clients))
        with get_tracer().span("service.job", kind="shard", stage=stage,
                               shard=shard, batch=serve.batch.bid) as sp:
            out, dev_idx, attempts, aborted = self._attempt_with_retries(
                key, dev_idx, body)
            sp.annotate(device=dev_idx, attempts=attempts, aborted=aborted)
        if out is None:
            out = {"models": {}, "cost": 0.0}
        return {**out, "start": start, "done": time.perf_counter() - t0,
                "device": dev_idx, "attempts": attempts, "aborted": aborted}

    def _job_federation(self, serve: _Serve, stage: int, dev_idx: int,
                        t0: float):
        """Worker body for a federation-level framework (FE/FR/RR): one job
        retraining everything — still dispatched asynchronously so it
        overlaps with other in-flight serves."""
        ctx = serve.stage_ctxs[stage]
        fw = get_framework(serve.framework)
        start = time.perf_counter() - t0

        def body(dev: int):
            models, cost = fw.run(ctx)
            jax.block_until_ready(list(models.values()))
            return {"models": models, "cost": cost}

        key = ("federation", stage, tuple(serve.clients))
        with get_tracer().span("service.job", kind="federation", stage=stage,
                               batch=serve.batch.bid) as sp:
            out, dev_idx, attempts, aborted = self._attempt_with_retries(
                key, dev_idx, body)
            sp.annotate(device=dev_idx, attempts=attempts, aborted=aborted)
        if out is None:
            out = {"models": {}, "cost": 0.0}
        return {**out, "start": start, "done": time.perf_counter() - t0,
                "device": dev_idx, "attempts": attempts, "aborted": aborted}

    def _dispatch(self, serves: List[_Serve], t0: float):
        tr = get_tracer()
        for serve in serves:
            serve.dispatch_off = time.perf_counter() - t0
            with tr.span("service.dispatch", batch=serve.batch.bid,
                         framework=serve.framework,
                         clients=sorted(serve.clients)) as sp:
                for p in serve.requests:
                    self._journal({"ev": "svc_dispatch",
                                   "request_id": service_request_id(p.req),
                                   "batch_id": serve.batch.bid})
                sim = self.session.sim
                # resolve against completed stages (session step-wise API)
                request = UnlearnRequest(serve.clients,
                                         framework=serve.framework,
                                         rounds=serve.rounds,
                                         apply=serve.apply)
                _clients, stage_plan = self.session.resolve_request(request)
                fw_cls = FRAMEWORKS[serve.framework]
                rounds = (serve.rounds or self.session.rounds
                          or sim.fl.global_rounds)
                n_jobs = 0
                for i, stage_clients in stage_plan.items():
                    record = self.session.records[i]
                    ctx = UnlearnContext(sim, record, list(stage_clients),
                                         rounds)
                    serve.stage_ctxs[i] = ctx
                    futures = []
                    if fw_cls.shard_level:
                        for shard in ctx.impacted:
                            dev = self.placement.assign()
                            futures.append(self.placement.submit(
                                self._job_shard, serve, i, shard, dev, t0))
                    else:
                        dev = self.placement.assign()
                        futures.append(self.placement.submit(
                            self._job_federation, serve, i, dev, t0))
                    serve.stage_jobs[i] = futures
                    n_jobs += len(futures)
                sp.annotate(n_jobs=n_jobs)

    # --------------------------------------------------------------- gather
    def _gather(self, serves: List[_Serve], report: ServiceReport, t0: float):
        for serve in serves:
            outs = {i: [f.result() for f in futs]
                    for i, futs in serve.stage_jobs.items()}
            starts = [o["start"] for os_ in outs.values() for o in os_]
            dones = [o["done"] for os_ in outs.values() for o in os_]
            devices = sorted({o["device"] for os_ in outs.values()
                              for o in os_})
            done_off = max(dones, default=serve.dispatch_off)
            # land per-stage UnlearnResults through the session report
            total_cost = 0.0
            for i, os_ in sorted(outs.items()):
                ctx = serve.stage_ctxs[i]
                record = self.session.records[i]
                fw_cls = FRAMEWORKS[serve.framework]
                if fw_cls.shard_level:
                    models = dict(record.shard_models)
                else:
                    models = {}
                cost = 0.0
                for o in os_:
                    models.update(o["models"])
                    cost += o["cost"]
                total_cost += cost
                stage_dones = [o["done"] for o in os_]
                res = UnlearnResult(
                    serve.framework, models,
                    max(stage_dones, default=serve.dispatch_off)
                    - serve.dispatch_off,
                    cost, getattr(record.store, "stats", None), ctx.impacted)
                self.session.record_result(i, res, apply=serve.apply)
            # one ledger entry per ORIGINAL request in the merged group
            start_off = min(starts) if starts else serve.dispatch_off
            batch_wait = start_off - serve.dispatch_off
            retrain_wall = done_off - start_off
            attempts = sum(o.get("attempts", 1) for os_ in outs.values()
                           for o in os_)
            n_jobs_total = sum(len(v) for v in outs.values())
            aborted = any(o.get("aborted", False) for os_ in outs.values()
                          for o in os_)
            tr = get_tracer()
            for p in serve.requests:
                self.audit.record(
                    "retrained", request_id=service_request_id(p.req),
                    batch_id=serve.batch.bid,
                    shards=[list(x) for x in sorted(p.impacted)],
                    aborted=aborted)
            for p in serve.requests:
                queue_wait = serve.batch.time - p.req.t
                latency = queue_wait + batch_wait + retrain_wall
                entry = LedgerEntry(
                    rid=p.req.rid, arrival=p.req.t, clients=p.req.clients,
                    framework=serve.framework, batch_id=serve.batch.bid,
                    queue_wait=queue_wait, batch_wait=batch_wait,
                    retrain_wall=retrain_wall, latency=latency,
                    n_jobs=sum(len(v) for v in outs.values()),
                    devices=devices, impacted=sorted(p.impacted),
                    cost_units=total_cost / max(len(serve.requests), 1),
                    deadline=p.req.deadline,
                    sla_met=(latency <= p.req.deadline
                             if p.req.deadline is not None else None),
                    job_attempts=attempts,
                    job_retries=attempts - n_jobs_total,
                    aborted=aborted,
                    request_id=service_request_id(p.req))
                report.entries.append(entry)
                self._journal({"ev": "svc_commit",
                               "request_id": entry.request_id,
                               "entry": entry.to_dict()})
                self.audit.record("committed", request_id=entry.request_id,
                                  batch_id=serve.batch.bid,
                                  queue_wait_virtual_s=queue_wait)
                if not entry.aborted:
                    tr.metrics.counter("service.requests_served").inc()
                    for c in entry.clients:
                        tr.metrics.histogram("service.client_latency_s",
                                             client=c).observe(latency)

    # ---------------------------------------------------------------- serve
    def serve(self, trace, resume: bool = False) -> ServiceReport:
        """Serve the whole trace: plan the dispatch schedule (virtual,
        deterministic), dispatch every batch's shard programs across the
        placement without blocking, then gather completions into the
        ledger.  Returns the ``ServiceReport``.

        ``trace`` is a sequence of ``ServiceRequest`` or any time-ordered
        iterable/generator (``iter_poisson_trace`` / ``iter_trace``) — the
        streaming form never materializes the request list and serves
        bit-identically to the materialized trace for the same seed.

        With ``resume=True`` and a journal attached, requests whose
        ``svc_commit`` is already journaled are NOT re-dispatched — their
        ledger entries are replayed bit-identically from the journal — and
        dispatched-but-uncommitted requests (crash between retrain and
        ledger-commit) re-dispatch exactly once.
        """
        if not self.session.records:
            raise RuntimeError("train at least one stage before serving")
        replayed: List[LedgerEntry] = []
        if resume and self.journal is not None:
            committed: Dict[str, dict] = {}
            for ev in self.journal.events():
                if ev.get("ev") == "svc_commit":
                    committed[ev["request_id"]] = ev["entry"]
            if committed:
                if isinstance(trace, Sequence):
                    trace = [r for r in trace
                             if service_request_id(r) not in committed]
                else:                       # keep a stream a stream
                    trace = (r for r in trace
                             if service_request_id(r) not in committed)
                replayed = [LedgerEntry.from_dict(d)
                            for d in committed.values()]
        tr = get_tracer()
        batches = self.plan_schedule(trace)
        # every admitted request lands in exactly one batch, so this equals
        # len(trace) for materialized traces — and is the only way to count
        # a streamed one
        n_requests = sum(len(b.pendings) for b in batches)
        self.placement.reset_assignment()
        self.placement.reset_health()
        if self.faults is not None:
            for rec in self.session.records:
                if hasattr(rec.store, "attach_faults"):
                    rec.store.attach_faults(self.faults)
        rec_before = self._recovery_counters()
        report = ServiceReport(policy=self.policy.describe(),
                               placement=self.placement.describe(),
                               num_batches=len(batches))
        t0 = time.perf_counter()
        all_serves: List[_Serve] = []
        with tr.span("service.serve", requests=n_requests,
                     batches=len(batches), resume=resume):
            for batch in batches:
                serves = self._merge_groups(batch)
                self._dispatch(serves, t0)
                all_serves.extend(serves)
            with tr.span("service.gather"):
                self._gather(all_serves, report, t0)
        report.serve_wall = time.perf_counter() - t0
        report.placement = self.placement.describe()   # incl. job counters
        report.entries.extend(replayed)          # journal-replayed commits
        report.entries.sort(key=lambda e: e.rid)
        rec_after = self._recovery_counters()
        attempts = retries = aborts = 0
        for serve_ in all_serves:
            for futs in serve_.stage_jobs.values():
                for f in futs:                       # results already cached
                    o = f.result()
                    attempts += o.get("attempts", 1)
                    retries += o.get("attempts", 1) - 1
                    aborts += int(o.get("aborted", False))
        report.faults = {
            "attempts": attempts, "retries": retries, "aborts": aborts,
            "recoveries": rec_after["recovered_reads"]
            - rec_before["recovered_reads"],
            "recovered_slices": rec_after["slices"] - rec_before["slices"],
            "failed_reads": rec_after["failed_reads"]
            - rec_before["failed_reads"],
            "retry_policy": self.retry.describe(),
        }
        if self.faults is not None:
            report.faults["ledger"] = self.faults.ledger.kinds()
        # re-expose the serve's aggregates (incl. the per-client p99
        # breakdown) through the metrics registry; idempotent gauges
        tr.metrics.absorb_service_report(report)
        return report

    def _recovery_counters(self) -> dict:
        """Quorum-read recovery totals across the session's (unique) stores
        — diffed around a serve to report per-serve recoveries."""
        out = {"recovered_reads": 0, "slices": 0, "failed_reads": 0}
        for store in {id(r.store): r.store
                      for r in self.session.records}.values():
            stats = getattr(store, "stats", None)
            if stats is None:
                continue
            out["recovered_reads"] += getattr(stats, "recovered_reads", 0)
            out["slices"] += (getattr(stats, "erased_slices", 0)
                              + getattr(stats, "corrupted_slices", 0))
            out["failed_reads"] += getattr(stats, "failed_reads", 0)
        return out
