"""Device placement for asynchronously dispatched unlearning programs.

``DevicePlacement`` assigns independent shard-retraining jobs to the
available ``jax.devices()`` (on CPU, virtual devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=N``) and dispatches them
without blocking: each job's inputs are ``jax.device_put`` onto its device,
the jitted calibration rounds are enqueued asynchronously, and
``block_until_ready`` happens only at the request-completion ledger.

One practical wrinkle this module owns: JAX's *dispatch* is asynchronous,
but the XLA **CPU** client serializes *execution* across virtual host
devices when everything is enqueued from one Python thread (measured on
this container: 4 concurrent scan-heavy programs take 4.1x one program's
wall).  Driving each device from its own worker thread recovers the
overlap (bounded by physical cores), so the placement runs a small thread
pool — ``max_workers = min(num_devices, os.cpu_count())`` by default — and
routes each job to the executor with its inputs committed to the job's
device.  On real multi-device backends (TPU/GPU) the same structure holds;
the threads then merely hide per-device dispatch latency.
"""
from __future__ import annotations

import os
import threading
from concurrent.futures import Future, ThreadPoolExecutor
from typing import Callable, List, Optional, Sequence

import jax


class DevicePlacement:
    """Round-robin shard-group -> device assignment plus an async dispatch
    pool.

    ``devices`` defaults to every visible JAX device.  ``max_workers``
    bounds how many jobs execute concurrently (default: one per device,
    capped at the host's core count — more workers than cores just thrash
    the CPU client's shared pool).
    """

    def __init__(self, devices: Optional[Sequence] = None,
                 max_workers: Optional[int] = None):
        self.devices: List = list(devices) if devices else list(jax.devices())
        if not self.devices:
            raise ValueError("DevicePlacement needs at least one device")
        if max_workers is None:
            max_workers = min(len(self.devices), os.cpu_count() or 1)
        self.max_workers = max(int(max_workers), 1)
        self._rr = 0
        self._lock = threading.Lock()
        self._pool: Optional[ThreadPoolExecutor] = None
        self._submitted = 0
        self._unhealthy: set = set()

    # ------------------------------------------------------------ assignment
    @property
    def num_devices(self) -> int:
        return len(self.devices)

    def reset_assignment(self) -> None:
        """Restart the round-robin cursor — the engine calls this at the top
        of every ``serve`` so device assignment is a deterministic function
        of the dispatch plan (and a warmup serve touches exactly the devices
        the measured serve will)."""
        with self._lock:
            self._rr = 0

    def assign(self) -> int:
        """Next device index for a job — round-robin, reset per serve, so
        assignment is a deterministic function of the dispatch plan.
        Returns the *index* (report-friendly) — use ``device_of`` for the
        device object."""
        with self._lock:
            idx = self._rr % len(self.devices)
            self._rr += 1
            return idx

    def device_of(self, index: int):
        return self.devices[index % len(self.devices)]

    # ---------------------------------------------------------------- health
    def mark_unhealthy(self, index: int) -> None:
        """Flag a device as failed.  ``assign`` deliberately keeps routing
        round-robin over ALL devices — the initial dispatch plan stays a
        deterministic function of the trace even under faults — and only
        ``reassign`` (the retry path) avoids unhealthy devices."""
        with self._lock:
            self._unhealthy.add(index % len(self.devices))
        from repro.telemetry import get_tracer
        tr = get_tracer()
        if tr.enabled:
            tr.event("placement.unhealthy",
                     device=index % len(self.devices))
            tr.metrics.counter("placement.marked_unhealthy").inc()

    def reset_health(self) -> None:
        """Clear fault state — called at the top of every serve so each
        serve (and each replay) starts from the same health picture."""
        with self._lock:
            self._unhealthy.clear()

    def reassign(self, avoid: int) -> int:
        """Deterministic re-dispatch target after a device fault: the first
        healthy device after ``avoid``.  Never raises and never touches the
        round-robin cursor — with every device unhealthy it returns
        ``avoid`` so the caller's bounded-retry abort path still completes."""
        with self._lock:
            n = len(self.devices)
            for step in range(1, n + 1):
                idx = (avoid + step) % n
                if idx not in self._unhealthy:
                    return idx
            return avoid % n

    # -------------------------------------------------------------- dispatch
    def submit(self, fn: Callable, *args, **kw) -> Future:
        """Run ``fn(*args, **kw)`` on the worker pool.  The callable is
        expected to ``put`` its inputs on its assigned device and only
        block on its own outputs (the ledger's completion point)."""
        if self._pool is None:
            with self._lock:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=self.max_workers,
                        thread_name_prefix="unlearn-serve")
        self._submitted += 1
        return self._pool.submit(fn, *args, **kw)

    def shutdown(self):
        """Idempotent and thread-safe: the pool is detached under the lock,
        torn down outside it, and later calls are no-ops."""
        with self._lock:
            pool, self._pool = self._pool, None
        if pool is not None:
            pool.shutdown(wait=True)

    # ------------------------------------------------------- context manager
    def __enter__(self) -> "DevicePlacement":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        """Always shut the worker pool down — a serve raising mid-flight
        must not leak threads."""
        self.shutdown()
        return False

    def describe(self) -> dict:
        with self._lock:
            unhealthy = sorted(self._unhealthy)
        return {"devices": [str(d) for d in self.devices],
                "num_devices": self.num_devices,
                "max_workers": self.max_workers,
                "jobs_submitted": self._submitted,
                "unhealthy": unhealthy}


def single_device_placement() -> DevicePlacement:
    """The sequential baseline: one device, one worker — jobs execute in
    submission order, bit-identical to the synchronous session path."""
    return DevicePlacement(devices=jax.devices()[:1], max_workers=1)
