"""Scheduling policies for the online unlearning service.

A policy decides *when* queued requests dispatch and *which* dispatch
together (requests batched together are merged per impacted shard by the
engine, so each shard retrains once per batch).  Policies are pure functions
of the queue and the virtual clock — deterministic, wall-clock-free — and
live in a registry (``POLICIES`` / ``@register_policy``) like the store and
framework registries, so a third-party policy is one class away.

Built-ins:

* ``fifo``   — serve every request immediately on arrival, one dispatch per
  request in arrival order (the sequential baseline).
* ``window`` — fixed batch-window coalescing: arrivals inside one
  ``[k·w, (k+1)·w)`` window dispatch together when the window closes
  (generalizes the session's ``batch_requests=True``, which is one
  infinite window per stage boundary).
* ``sla``    — deadline-aware admission: each request must dispatch by
  ``arrival + deadline - est_serve`` (its latest safe start); until then it
  may be held to coalesce.  When a request comes due, every queued request
  sharing an impacted shard with the due set joins the batch (due requests
  merged per impacted shard — they retrain that shard anyway).

The engine drives the protocol:

* ``next_event(queue, now)`` — earliest virtual time the policy wants
  control back (window close, deadline), or ``None`` if it only reacts to
  arrivals / end-of-trace.
* ``release(queue, now, final)`` — batches ready to dispatch at ``now``
  (each a list of ``Pending``), removing them from ``queue``; ``final``
  means no more arrivals will come, so everything still queued must drain.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, FrozenSet, List, Optional, Tuple, Type

from repro.service.workload import ServiceRequest


@dataclass
class Pending:
    """A queued request plus what the scheduler knows about it: its impacted
    (stage, shard) set, reported by the unlearning framework at admission."""
    req: ServiceRequest
    impacted: FrozenSet[Tuple[int, int]] = frozenset()

    @property
    def t(self) -> float:
        return self.req.t


class SchedulingPolicy:
    """Base policy.  Subclass, implement ``release`` (and ``next_event`` if
    the policy keeps its own timers), then ``@register_policy("name")``."""

    name: str = ""

    def next_event(self, queue: List[Pending],
                   now: float) -> Optional[float]:
        return None

    def release(self, queue: List[Pending], now: float,
                final: bool = False) -> List[List[Pending]]:
        raise NotImplementedError

    def describe(self) -> dict:
        return {"policy": self.name}


POLICIES: Dict[str, Type[SchedulingPolicy]] = {}


def register_policy(*names: str):
    """Class decorator registering a ``SchedulingPolicy`` under ``names``."""
    if not names:
        raise ValueError("register_policy needs at least one name")

    def deco(cls: Type[SchedulingPolicy]) -> Type[SchedulingPolicy]:
        cls.name = names[0]
        for n in names:
            POLICIES[n] = cls
        return cls
    return deco


def make_policy(name: str, **options) -> SchedulingPolicy:
    try:
        cls = POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown scheduling policy {name!r}; registered: "
                         f"{sorted(POLICIES)}") from None
    return cls(**options)


# ---------------------------------------------------------------------------
# Built-ins
# ---------------------------------------------------------------------------

@register_policy("fifo")
class FIFOPolicy(SchedulingPolicy):
    """Serve each request as soon as it arrives, in arrival order — one
    single-request dispatch per request (the sequential baseline every
    other policy is measured against)."""

    def release(self, queue: List[Pending], now: float,
                final: bool = False) -> List[List[Pending]]:
        ready = [p for p in queue if p.t <= now]
        ready.sort(key=lambda p: (p.t, p.req.rid))
        for p in ready:
            queue.remove(p)
        return [[p] for p in ready]


@register_policy("window")
class BatchWindowPolicy(SchedulingPolicy):
    """Fixed batch-window coalescing: requests arriving inside the same
    ``width``-second window dispatch as ONE batch when the window closes.
    ``width=inf`` (or anything non-positive… rejected) batches per drain."""

    def __init__(self, width: float = 1.0):
        if width <= 0:
            raise ValueError("window width must be positive")
        self.width = float(width)

    def _window_end(self, p: Pending) -> float:
        return (int(p.t / self.width) + 1) * self.width

    def next_event(self, queue: List[Pending],
                   now: float) -> Optional[float]:
        ends = [self._window_end(p) for p in queue]
        return min(ends) if ends else None

    def release(self, queue: List[Pending], now: float,
                final: bool = False) -> List[List[Pending]]:
        by_window: Dict[int, List[Pending]] = {}
        for p in list(queue):
            if final or self._window_end(p) <= now:
                by_window.setdefault(int(p.t / self.width), []).append(p)
                queue.remove(p)
        batches = []
        for k in sorted(by_window):
            batch = by_window[k]
            batch.sort(key=lambda p: (p.t, p.req.rid))
            batches.append(batch)
        return batches

    def describe(self) -> dict:
        return {"policy": self.name, "width": self.width}


@register_policy("sla")
class SLAPolicy(SchedulingPolicy):
    """Deadline/SLA-aware admission.

    A request's *latest safe start* is ``arrival + deadline - est_serve``
    (``default_deadline`` covers requests without one; ``est_serve`` is the
    configured — deterministic — serving-time estimate).  Requests are held
    to coalesce until some request comes due, at which point the due set
    dispatches together with every queued request that shares an impacted
    shard with it (those shards retrain anyway, so merging is free work).
    Overlap closure is computed transitively, so one batch covers a
    connected component of shard overlap.

    ``max_hold`` caps the hold independently of the deadline; it defaults
    to half of ``default_deadline`` so that, even with the default
    ``est_serve=0`` (no serving-time estimate), a request is never held
    right up to its own deadline — which would make every verdict a miss
    by construction.  Pass ``max_hold=float("inf")`` for purely
    deadline-driven holds.
    """

    def __init__(self, default_deadline: float = 10.0,
                 est_serve: float = 0.0, max_hold: Optional[float] = None):
        self.default_deadline = float(default_deadline)
        self.est_serve = float(est_serve)
        self.max_hold = (0.5 * self.default_deadline if max_hold is None
                         else float(max_hold))

    def _due_time(self, p: Pending) -> float:
        deadline = (p.req.deadline if p.req.deadline is not None
                    else self.default_deadline)
        due = p.t + max(deadline - self.est_serve, 0.0)
        return min(due, p.t + self.max_hold)

    def next_event(self, queue: List[Pending],
                   now: float) -> Optional[float]:
        dues = [self._due_time(p) for p in queue]
        return min(dues) if dues else None

    def release(self, queue: List[Pending], now: float,
                final: bool = False) -> List[List[Pending]]:
        if final:
            seed = list(queue)
        else:
            seed = [p for p in queue if self._due_time(p) <= now]
        if not seed:
            return []
        # transitive closure over shard overlap: a held request sharing any
        # impacted (stage, shard) with the due set rides along for free
        batch = list(seed)
        covered = set().union(*(p.impacted for p in batch)) if batch else set()
        grew = True
        while grew:
            grew = False
            for p in queue:
                if p in batch:
                    continue
                if p.impacted & covered:
                    batch.append(p)
                    covered |= p.impacted
                    grew = True
        batch.sort(key=lambda p: (p.t, p.req.rid))
        for p in batch:
            queue.remove(p)
        return [batch]

    def describe(self) -> dict:
        return {"policy": self.name,
                "default_deadline": self.default_deadline,
                "est_serve": self.est_serve, "max_hold": self.max_hold}
