"""Workload generation for the online unlearning service — seeded,
wall-clock-free.

The service consumes a *trace*: a time-ordered list of ``ServiceRequest``
arrivals on a virtual clock (seconds since serve start).  Traces come from
seeded generators (Poisson and bursty arrival processes, optionally with
hot-client skew over the victim pool) or from a JSON trace file
(``save_trace``/``load_trace``), so every scheduling decision downstream is
reproducible run-to-run: nothing in the workload or scheduling logic reads
the wall clock — real time enters only in the serving ledger, where retrain
walls are *measured*.

``VirtualClock`` is the discrete-event clock the engine advances: it only
moves forward, and only to explicit event times (arrivals, policy timers).
"""
from __future__ import annotations

import json
from dataclasses import asdict, dataclass, field
from typing import List, Optional, Sequence, Tuple

import numpy as np


@dataclass(frozen=True)
class ServiceRequest:
    """One online unlearning request.

    ``t`` — virtual arrival time (seconds since serve start).
    ``clients`` — concrete victim ids (service traces are fully resolved;
    the session-level callable form does not appear in traces).
    ``deadline`` — optional SLA budget in seconds *relative to arrival*;
    the ledger marks the request late when measured latency exceeds it.
    ``apply`` — serving semantics: fold the unlearned shard models back
    into the session's stage records.
    ``request_id`` — stable idempotency key threaded through the service
    ledger and journal replay; "" means "derive from rid" (``svc-<rid>``,
    see ``service_request_id``), so legacy traces keep working.
    """
    t: float
    clients: Tuple[int, ...]
    framework: str = "SE"
    rounds: Optional[int] = None
    deadline: Optional[float] = None
    apply: bool = False
    rid: int = -1
    request_id: str = ""

    def to_dict(self) -> dict:
        return asdict(self)


def service_request_id(req: "ServiceRequest") -> str:
    """The request's stable idempotency key: its explicit ``request_id`` or
    the rid-derived ``svc-<rid>`` fallback.  Journal replay and the ledger
    key on this — never on list positions."""
    return req.request_id or f"svc-{req.rid}"


class VirtualClock:
    """Monotone discrete-event clock.  ``advance_to`` clamps backwards moves
    (an event in the past fires "now") so event loops cannot travel back."""

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def advance_to(self, t: float) -> float:
        self.now = max(self.now, float(t))
        return self.now

    def advance(self, dt: float) -> float:
        return self.advance_to(self.now + max(float(dt), 0.0))


# ---------------------------------------------------------------------------
# Victim sampling — hot-client skew
# ---------------------------------------------------------------------------

def client_sampler(pool: Sequence[int], seed: int, skew: float = 0.0,
                   replace: bool = True):
    """Seeded victim sampler over ``pool``.

    ``skew`` > 0 gives a Zipf-like popularity profile: the pool is shuffled
    once (seeded), then client at popularity rank r is drawn with
    probability proportional to ``1 / (r+1)**skew`` — a few "hot" clients
    receive most of the erasure requests (the realistic serving regime).
    ``skew=0`` is uniform.  ``replace=False`` samples without replacement
    (raises once the pool is exhausted).
    """
    rng = np.random.default_rng(seed)
    order = list(rng.permutation(np.asarray(list(pool))))
    probs = np.array([1.0 / (r + 1) ** skew for r in range(len(order))])
    probs /= probs.sum()

    def sample(k: int = 1) -> List[int]:
        nonlocal order, probs
        if not replace and k > len(order):
            raise ValueError(f"pool exhausted: {k} requested, "
                             f"{len(order)} left")
        idx = rng.choice(len(order), size=k, replace=replace, p=probs)
        out = [int(order[i]) for i in idx]
        if not replace:
            drawn = set(idx.tolist())      # hoisted: O(n), not O(n*k)
            keep = [i for i in range(len(order)) if i not in drawn]
            order = [order[i] for i in keep]
            probs = probs[keep]
            if probs.sum() > 0:
                probs = probs / probs.sum()
        return out

    return sample


# ---------------------------------------------------------------------------
# Arrival processes
# ---------------------------------------------------------------------------

@dataclass
class TraceConfig:
    """Shared knobs for the arrival generators."""
    framework: str = "SE"
    rounds: Optional[int] = None
    deadline: Optional[float] = None
    apply: bool = False
    victims_per_request: int = 1
    skew: float = 0.0
    replace: bool = True
    pool: Sequence[int] = field(default_factory=list)   # victim pool


def iter_poisson_trace(pool: Sequence[int], n: int, rate: float,
                       seed: int = 0, **cfg_kw):
    """Generator twin of ``poisson_trace``: yields the ``n`` requests one at
    a time without materializing the trace list, so a 10⁵–10⁶-request
    Zipf-skewed replay holds one request in memory at a time.  Identical RNG
    consumption order to the list form — ``list(iter_poisson_trace(...))``
    is element-for-element equal to ``poisson_trace(...)`` for the same
    seed (asserted in ``tests/test_service.py``)."""
    cfg = TraceConfig(pool=pool, **cfg_kw)
    rng = np.random.default_rng(seed)
    sample = client_sampler(cfg.pool, seed + 1, cfg.skew, cfg.replace)
    t = 0.0
    for i in range(n):
        t += float(rng.exponential(1.0 / rate))
        yield ServiceRequest(
            t=t, clients=tuple(sample(cfg.victims_per_request)),
            framework=cfg.framework, rounds=cfg.rounds,
            deadline=cfg.deadline, apply=cfg.apply, rid=i)


def poisson_trace(pool: Sequence[int], n: int, rate: float, seed: int = 0,
                  **cfg_kw) -> List[ServiceRequest]:
    """``n`` requests with Exponential(1/rate) inter-arrival times —
    memoryless arrivals at ``rate`` requests per virtual second."""
    return list(iter_poisson_trace(pool, n, rate, seed=seed, **cfg_kw))


def bursty_trace(pool: Sequence[int], n: int, burst_rate: float,
                 mean_burst: float = 3.0, seed: int = 0,
                 **cfg_kw) -> List[ServiceRequest]:
    """Bursty arrivals: burst epochs are Poisson(``burst_rate``), burst sizes
    are Geometric with mean ``mean_burst``, and every request in a burst
    arrives at the same virtual instant (e.g. a data-breach disclosure
    triggering a wave of erasure requests)."""
    cfg = TraceConfig(pool=pool, **cfg_kw)
    rng = np.random.default_rng(seed)
    sample = client_sampler(cfg.pool, seed + 1, cfg.skew, cfg.replace)
    t, out = 0.0, []
    while len(out) < n:
        t += float(rng.exponential(1.0 / burst_rate))
        size = min(int(rng.geometric(1.0 / max(mean_burst, 1.0))), n - len(out))
        for _ in range(size):
            out.append(ServiceRequest(
                t=t, clients=tuple(sample(cfg.victims_per_request)),
                framework=cfg.framework, rounds=cfg.rounds,
                deadline=cfg.deadline, apply=cfg.apply, rid=len(out)))
    return out


def sequenced_trace(victims: Sequence[Sequence[int]], spacing: float = 0.0,
                    **cfg_kw) -> List[ServiceRequest]:
    """Deterministic trace from an explicit victim sequence — one request per
    entry, ``spacing`` seconds apart (0 = all arrive at t=0).  ``victims``
    entries may be a single client id or a sequence of ids."""
    cfg = TraceConfig(**cfg_kw)
    out = []
    for i, v in enumerate(victims):
        clients = (int(v),) if np.isscalar(v) else tuple(int(c) for c in v)
        out.append(ServiceRequest(
            t=i * spacing, clients=clients, framework=cfg.framework,
            rounds=cfg.rounds, deadline=cfg.deadline, apply=cfg.apply, rid=i))
    return out


# ---------------------------------------------------------------------------
# Trace files
# ---------------------------------------------------------------------------

def save_trace(path: str, trace: Sequence[ServiceRequest]) -> None:
    with open(path, "w") as f:
        json.dump({"requests": [r.to_dict() for r in trace]}, f, indent=2)


def save_trace_jsonl(path: str, trace) -> int:
    """Streaming trace writer: one JSON object per line, consuming ``trace``
    (any iterable, including the ``iter_*`` generators) one request at a
    time.  Returns the number of requests written."""
    n = 0
    with open(path, "w") as f:
        for r in trace:
            f.write(json.dumps(r.to_dict()) + "\n")
            n += 1
    return n


def _request_from_dict(r: dict, i: int) -> ServiceRequest:
    return ServiceRequest(t=float(r["t"]),
                          clients=tuple(int(c) for c in r["clients"]),
                          framework=r.get("framework", "SE"),
                          rounds=r.get("rounds"),
                          deadline=r.get("deadline"),
                          apply=bool(r.get("apply", False)),
                          rid=int(r.get("rid", i)),
                          request_id=str(r.get("request_id", "")))


def iter_trace(path: str):
    """Streaming trace reader: yields requests line-by-line from a JSONL
    trace (``save_trace_jsonl``) without materializing the list.  A legacy
    ``save_trace`` JSON file (first line is not a complete request object —
    either the root object spans lines or it carries the ``requests`` key)
    transparently falls back to ``load_trace`` — still a generator, but
    materialized underneath (the legacy format cannot be streamed)."""
    with open(path) as f:
        first = f.readline().strip()
        legacy = False
        if first:
            try:
                legacy = "requests" in json.loads(first)
            except json.JSONDecodeError:
                legacy = True              # root object spans multiple lines
        if legacy:
            yield from load_trace(path)
            return
        f.seek(0)
        for i, line in enumerate(f):
            line = line.strip()
            if line:
                yield _request_from_dict(json.loads(line), i)


def load_trace(path: str) -> List[ServiceRequest]:
    """Trace-file replay: the JSON twin of ``save_trace`` (requests are
    re-sorted by arrival time; ties keep file order)."""
    with open(path) as f:
        payload = json.load(f)
    reqs = [_request_from_dict(r, i)
            for i, r in enumerate(payload["requests"])]
    return sorted(reqs, key=lambda r: (r.t, r.rid))
