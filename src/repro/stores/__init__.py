"""Parameter stores — the paper's storage substrate (``full`` / ``uncoded`` /
``coded``) behind the single ``ParameterStore.put_round(RoundPayload)``
protocol and the ``STORES`` registry.

This package was historically named ``repro.checkpoint`` — a misnomer: it
holds the paper's *intermediate parameter stores*, not training checkpoints.
``repro.checkpoint`` remains importable as a ``DeprecationWarning`` shim;
real crash-recovery checkpointing lives in ``repro.durability``.
"""
from repro.stores.store import (CodedStore, FullStore,  # noqa: F401
                                ParameterStore, RoundPayload, STORES,
                                StoreStats, UncodedShardStore, make_store,
                                register_store, tree_bytes)

# registration side-effect: makes store="tiered" resolvable everywhere the
# STORES registry is consulted (ScenarioConfig, FLSimulator, benchmarks)
import repro.tiering.store  # noqa: E402,F401
