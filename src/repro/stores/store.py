"""Intermediate-parameter stores — the storage substrate the paper optimizes.

``FullStore``      — FedEraser: central server keeps every participating
                     client's parameters for every round.
``UncodedShardStore`` — isolated sharding: each shard's server keeps only its
                     own clients' parameters (still uncoded).
``CodedStore``     — coded sharding: per round, the S shard-stacked parameter
                     vectors are Lagrange-encoded into C slices that live on
                     clients; the servers keep only the coding keys. Retrieval
                     reconstructs with any >=S intact slices and tolerates up
                     to (C-S)/2 corrupted ones.

Store API
---------
Every store implements the ``ParameterStore`` protocol with ONE write entry
point, ``put_round(RoundPayload)``.  A ``RoundPayload`` carries one round's
parameters in whichever of three forms the producer has on hand — per-client
trees, per-shard stacked ``(M, ...)`` trees, or per-shard pre-flattened
``(M, P)`` matrices — and each store consumes the richest form it supports
(``wants`` advertises the preferred one so the round engine can compute it
in-jit).  ``CodedStore`` additionally accepts a whole stage of slices
already Lagrange-encoded *inside* the stage-program engine's XLA program
(``put_stage_encoded`` — zero store-side encode dispatches).  Stores register themselves in the ``STORES`` registry under the
name used by ``FLSimulator``/``ScenarioConfig`` (``full`` / ``uncoded`` /
``coded``); third-party stores are one ``@register_store`` away.

Every store reports byte-level accounting (``StoreStats``) so the Fig. 5
benchmark can compare storage overhead and (modelled) communication time.
"""
from __future__ import annotations

import dataclasses
import threading
from dataclasses import dataclass
from typing import (Callable, Dict, List, Optional, Protocol, Sequence, Tuple,
                    runtime_checkable)

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding
from repro.telemetry import get_tracer


def tree_bytes(tree) -> int:
    return sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(tree))


@dataclass
class _StackedRow:
    """Lazy reference to row ``idx`` of a stacked (M, ...) parameter pytree —
    lets the uncoded stores accept device-resident stacked batches without a
    per-client unstack in the training hot loop; the row is materialized only
    if actually retrieved (unlearning preparation)."""
    stacked: object
    idx: int

    def materialize(self):
        return jax.tree.map(lambda a, i=self.idx: a[i], self.stacked)

    def stacked_rows(self) -> int:
        return jax.tree.leaves(self.stacked)[0].shape[0]

    def nbytes(self) -> int:
        """This row's share of the stacked batch's bytes."""
        return tree_bytes(self.stacked) // max(self.stacked_rows(), 1)


@dataclass
class StoreStats:
    server_bytes: int = 0
    client_bytes: int = 0
    encode_flops: int = 0
    decode_flops: int = 0
    comm_bytes_store: int = 0     # bytes moved client->server (or client<->client)
    comm_bytes_retrieve: int = 0
    # quorum-read recovery accounting (CodedStore fault path)
    reads: int = 0                # shard reads served
    recovered_reads: int = 0      # reads that had to decode around a fault
    erased_slices: int = 0        # unreachable slices tolerated across reads
    corrupted_slices: int = 0     # corrupted slices localized + excluded
    failed_reads: int = 0         # reads aborted: faults exceeded the budget
    # tiered-store accounting (repro.tiering.TieredStore); keyed by tier name.
    # tier_bytes is residency (bytes currently held in that tier's medium:
    # device / host RAM / disk); the rest are monotone counters.
    tier_bytes: Dict[str, int] = dataclasses.field(default_factory=dict)
    tier_hits: Dict[str, int] = dataclasses.field(default_factory=dict)
    tier_misses: Dict[str, int] = dataclasses.field(default_factory=dict)
    tier_evictions: Dict[str, int] = dataclasses.field(default_factory=dict)
    tier_promotions: Dict[str, int] = dataclasses.field(default_factory=dict)

    def merge(self, other: "StoreStats") -> "StoreStats":
        """Field-wise accumulate ``other`` into self (returns self) — the one
        aggregation point for session/benchmark reporting.  Dict-valued
        fields (the per-tier counters) accumulate key-wise."""
        for f in dataclasses.fields(self):
            mine, theirs = getattr(self, f.name), getattr(other, f.name)
            if isinstance(mine, dict):
                for k, v in theirs.items():
                    mine[k] = mine.get(k, 0) + v
            else:
                setattr(self, f.name, mine + theirs)
        return self

    def __iadd__(self, other: "StoreStats") -> "StoreStats":
        return self.merge(other)

    def __add__(self, other: "StoreStats") -> "StoreStats":
        return self.snapshot().merge(other)

    def snapshot(self) -> "StoreStats":
        out = dataclasses.replace(self)
        for f in dataclasses.fields(out):     # don't alias the dict fields
            v = getattr(out, f.name)
            if isinstance(v, dict):
                setattr(out, f.name, dict(v))
        return out

    def to_dict(self) -> Dict[str, int]:
        return dataclasses.asdict(self)


# ---------------------------------------------------------------------------
# Round payload + store protocol
# ---------------------------------------------------------------------------

@dataclass
class RoundPayload:
    """One FedAvg round's parameters, in producer-native form.

    Exactly one of ``client_params`` / ``stacked`` / ``flat`` is set:

    * ``client_params`` — {client_id: pytree} (the seed per-client path).
    * ``stacked``       — {shard: (M_s, ...) pytree}, rows in
                          ``shard_clients[shard]`` order (fused engine,
                          uncoded stores: no per-client unstack).
    * ``flat``          — {shard: (M_s, P) matrix} + ``row_spec`` (fused
                          engine, coded store: flattened in-jit by
                          ``coding.tree_to_flat_stacked``).

    ``shard_clients`` always carries the round's shard membership so every
    store can serve ``get_shard`` regardless of its internal layout.
    """
    rnd: int
    shard_clients: Dict[int, List[int]]
    client_params: Optional[Dict[int, object]] = None
    stacked: Optional[Dict[int, object]] = None
    flat: Optional[Dict[int, jnp.ndarray]] = None
    row_spec: object = None

    def __post_init__(self):
        forms = [x is not None for x in
                 (self.client_params, self.stacked, self.flat)]
        if sum(forms) != 1:
            raise ValueError("RoundPayload needs exactly one of "
                             "client_params / stacked / flat")
        if self.flat is not None and self.row_spec is None:
            raise ValueError("flat payload requires row_spec")

    # ------------------------------------------------------- constructors
    @classmethod
    def from_clients(cls, rnd: int, shard_clients: Dict[int, List[int]],
                     client_params: Dict[int, object]) -> "RoundPayload":
        return cls(rnd, {s: list(cs) for s, cs in shard_clients.items()},
                   client_params=client_params)

    @classmethod
    def from_stacked(cls, rnd: int, shard_clients: Dict[int, List[int]],
                     stacked: Dict[int, object]) -> "RoundPayload":
        return cls(rnd, {s: list(cs) for s, cs in shard_clients.items()},
                   stacked=stacked)

    @classmethod
    def from_flat(cls, rnd: int, shard_clients: Dict[int, List[int]],
                  flat: Dict[int, jnp.ndarray], row_spec) -> "RoundPayload":
        return cls(rnd, {s: list(cs) for s, cs in shard_clients.items()},
                   flat=flat, row_spec=row_spec)

    # ------------------------------------------------------------- views
    def iter_client_trees(self):
        """Yield (shard, client, lazy-or-real tree) for every client."""
        if self.client_params is not None:
            for s, cs in self.shard_clients.items():
                for c in cs:
                    if c in self.client_params:
                        yield s, c, self.client_params[c]
        elif self.stacked is not None:
            for s, cs in self.shard_clients.items():
                for i, c in enumerate(cs):
                    yield s, c, _StackedRow(self.stacked[s], i)
        else:
            raise ValueError("flat payload carries no per-client trees; "
                             "use a 'stacked' or 'client_params' payload")


@runtime_checkable
class ParameterStore(Protocol):
    """The single store interface the round engine / session driver target."""

    stats: StoreStats
    wants: str        # preferred payload form: "flat" | "stacked" | "tree"

    def put_round(self, payload: RoundPayload) -> None: ...

    def flush(self) -> None: ...

    def get(self, rnd: int, client: int): ...

    def get_shard(self, rnd: int, shard: int,
                  available: Optional[Sequence[int]] = None,
                  corrupt: Optional[np.ndarray] = None) -> Dict[int, object]: ...

    def clients_at(self, rnd: int) -> List[int]: ...


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

STORES: Dict[str, Callable[..., "ParameterStore"]] = {}


def register_store(name: str):
    """Register a store factory under ``name``.

    Factories are called as ``factory(shard_clients, **options)`` where
    ``options`` carries ``num_shards``, ``num_clients``, ``group_rounds``,
    ``slice_dtype``, ``use_kernel`` (factories ignore what they don't need).
    """
    def deco(fn):
        STORES[name] = fn
        return fn
    return deco


def make_store(kind: str, shard_clients: Dict[int, List[int]],
               **options) -> "ParameterStore":
    try:
        factory = STORES[kind]
    except KeyError:
        raise KeyError(f"unknown store {kind!r}; registered: "
                       f"{sorted(STORES)}") from None
    return factory(shard_clients, **options)


# ---------------------------------------------------------------------------
# Stores
# ---------------------------------------------------------------------------

class FullStore:
    """{(round, client_id): params} on the central server."""

    wants = "stacked"

    def __init__(self):
        self._data: Dict[Tuple[int, int], object] = {}
        self._shards: Dict[int, Dict[int, List[int]]] = {}  # rnd -> layout
        self.stats = StoreStats()
        # ``get`` materializes lazy stacked rows in place: serialize it so
        # interleaved serves (service worker threads) read safely
        self._lock = threading.RLock()

    def put_round(self, payload: RoundPayload) -> None:
        self._shards[payload.rnd] = payload.shard_clients
        for _s, c, p in payload.iter_client_trees():
            self._data[(payload.rnd, c)] = p
            b = p.nbytes() if isinstance(p, _StackedRow) else tree_bytes(p)
            self.stats.server_bytes += b
            self.stats.comm_bytes_store += b

    def flush(self) -> None:
        pass

    def get(self, rnd: int, client: int):
        with self._lock:
            p = self._data[(rnd, client)]
            if isinstance(p, _StackedRow):
                p = p.materialize()
                self._data[(rnd, client)] = p
            self.stats.comm_bytes_retrieve += tree_bytes(p)
        return p

    def get_shard(self, rnd: int, shard: int,
                  available: Optional[Sequence[int]] = None,
                  corrupt: Optional[np.ndarray] = None) -> Dict[int, object]:
        """Uncoded stores hold plaintext params: ``available``/``corrupt``
        model slice loss and are inapplicable here (ignored)."""
        return {c: self.get(rnd, c) for c in self._shards[rnd][shard]}

    def clients_at(self, rnd: int) -> List[int]:
        return sorted(c for (r, c) in self._data if r == rnd)


class UncodedShardStore(FullStore):
    """Same layout, but bytes are attributed per shard server (the shard's
    server only holds its own clients — server_bytes tracks the max shard)."""

    def __init__(self, shard_of: Dict[int, int]):
        super().__init__()
        self.shard_of = shard_of
        self._per_shard: Dict[int, int] = {}

    def put_round(self, payload: RoundPayload) -> None:
        self._shards[payload.rnd] = payload.shard_clients
        for s, c, p in payload.iter_client_trees():
            self._data[(payload.rnd, c)] = p
            b = p.nbytes() if isinstance(p, _StackedRow) else tree_bytes(p)
            self._per_shard[s] = self._per_shard.get(s, 0) + b
            self.stats.comm_bytes_store += b
        self.stats.server_bytes = max(self._per_shard.values(), default=0)


class CodedStore:
    """Lagrange-coded distributed store (paper Sec 3.3).

    Per (round): the S shard parameter vectors (concat of their clients'
    params) are encoded to C slices held by clients. The server side keeps
    only the CodingScheme (keys). Decode returns {client_id: params} for one
    shard.
    """

    wants = "flat"

    def __init__(self, scheme: coding.CodingScheme,
                 shard_clients: Dict[int, List[int]], use_kernel: bool = False,
                 slice_dtype=None, group_rounds: int = 1):
        self.scheme = scheme
        self.shard_clients = {s: list(cs) for s, cs in shard_clients.items()}
        self.use_kernel = use_kernel
        self.slice_dtype = slice_dtype        # e.g. bf16 coded slices
        self.group_rounds = max(int(group_rounds), 1)
        self._slices: Dict[int, jnp.ndarray] = {}    # round -> (C, P)
        self._specs: Dict[int, tuple] = {}
        self._layouts: Dict[int, list] = {}          # round -> client order per shard
        self._pending: List[Tuple[int, jnp.ndarray]] = []   # deferred rounds
        self._row_layout = None               # cached flat-path geometry
        self.faults = None                    # optional attached FaultPlan
        self.stats = StoreStats()
        self.stats.server_bytes = 16 * scheme.num_clients  # the keys
        # concurrent-read safety for interleaved serves: ``get_shard`` may
        # trigger ``flush`` (mutating _slices/_pending) and always mutates
        # stats, so the online service's worker threads reading different
        # shards of the same store must serialize through this lock.
        # Re-entrant because get_shard -> flush nests.
        self._lock = threading.RLock()

    def put_round(self, payload: RoundPayload) -> None:
        if payload.flat is not None:
            self._put_flat(payload.rnd, payload.flat, payload.row_spec)
        elif payload.client_params is not None:
            self._put_trees(payload.rnd, payload.client_params)
        else:
            # stacked trees: flatten host-side (slow path, kept for
            # completeness — the fused engine hands the coded store ``flat``)
            flat = {}
            row_spec = None
            for s, cs in sorted(payload.shard_clients.items()):
                f, spec = coding.tree_to_flat_stacked(payload.stacked[s])
                flat[s] = f
                row_spec = spec
            self._put_flat(payload.rnd, flat, row_spec)

    def _put_trees(self, rnd: int, client_params: Dict[int, object]):
        """Encode this round's per-shard parameter sets into client slices."""
        shard_trees = []
        layout = []
        for s in sorted(self.shard_clients):
            cs = [c for c in self.shard_clients[s] if c in client_params]
            layout.append((s, cs))
            shard_trees.append({c: client_params[c] for c in cs})
        slices, specs = coding.encode_pytrees(self.scheme, shard_trees,
                                              use_kernel=self.use_kernel)
        with self._lock:
            self._slices[rnd] = slices
            self._specs[rnd] = specs
            self._layouts[rnd] = layout
            self._account_stored(slices)

    def _put_flat(self, rnd: int, shard_flats: Dict[int, jnp.ndarray],
                  row_spec):
        """Fast path for the fused round engine: per-shard *stacked, already
        flat* ``(M_s, P)`` client-parameter matrices (from
        ``coding.tree_to_flat_stacked`` inside the jitted round step).

        The per-shard vector is the client-major ``reshape(-1)`` of the
        stacked matrix — bit-identical to the tree path's concat of per-client
        flats. Re-assembly specs and padding geometry are computed ONCE per
        stage (not re-flattened per client per round), and the Lagrange encode
        itself is deferred and batched ``group_rounds`` rounds at a time into
        a single (S, G*P) coded matmul (see ``flush``).
        """
        with self._lock:
            if self._row_layout is None:
                layout, specs, lens = [], [], []
                for s in sorted(self.shard_clients):
                    cs = list(self.shard_clients[s])
                    f = shard_flats[s]
                    assert f.shape[0] == len(cs), (s, f.shape, cs)
                    layout.append((s, cs))
                    specs.append(coding.StackedRowSpec(tuple(cs),
                                                       int(f.shape[1]),
                                                       row_spec))
                    lens.append(int(f.shape[0]) * int(f.shape[1]))
                self._row_layout = (layout, tuple(specs), max(lens))
            layout, specs, pmax = self._row_layout
            rows = [shard_flats[s].reshape(-1) for s, _ in layout]
            w = jnp.stack([r if r.shape[0] == pmax
                           else jnp.pad(r, (0, pmax - r.shape[0]))
                           for r in rows])
            self._layouts[rnd] = layout
            self._specs[rnd] = specs
            self._pending.append((rnd, w))
            if len(self._pending) >= self.group_rounds:
                self.flush()

    def put_stage_encoded(self, coded: jnp.ndarray, row_spec,
                          row_len: int) -> None:
        """Whole-stage write for the stage-program engine: ``coded`` is the
        ``(G, C, Pmax)`` slice tensor already Lagrange-encoded *inside* the
        training program (``coding.encode_rounds`` fused after the round
        scan), so the store does no encode dispatch at all — it only registers
        per-round views and accounts bytes/FLOPs exactly like the fused
        ``_put_flat``+``flush`` path (same shapes, same dtype).

        ``row_spec``/``row_len`` carry the per-client re-assembly geometry
        (every shard must have the same client count — the stage engine's
        stackability precondition, which ``train_stage`` checks before
        selecting this path).
        """
        layout, specs = [], []
        for s in sorted(self.shard_clients):
            cs = list(self.shard_clients[s])
            layout.append((s, cs))
            specs.append(coding.StackedRowSpec(tuple(cs), row_len, row_spec))
        specs = tuple(specs)
        with self._lock, get_tracer().span("store.put_stage",
                                           rounds=int(coded.shape[0])):
            for g in range(int(coded.shape[0])):
                self._slices[g] = coded[g]
                self._layouts[g] = layout
                self._specs[g] = specs
                self._account_stored(coded[g])

    def flush(self):
        """Encode all deferred rounds in one batched coded matmul."""
        with self._lock:
            if not self._pending:
                return
            rounds = [r for r, _ in self._pending]
            mats = [w for _, w in self._pending]
            self._pending = []
            with get_tracer().span("store.encode", rounds=len(rounds),
                                   kernel=self.use_kernel):
                coded = coding.encode_batched(self.scheme, mats,
                                              use_kernel=self.use_kernel,
                                              out_dtype=self.slice_dtype)
            for rnd, slices in zip(rounds, coded):
                self._slices[rnd] = slices
                self._account_stored(slices)

    def _account_stored(self, slices: jnp.ndarray):
        p = slices.shape[1]
        self.stats.client_bytes += int(slices.size * slices.dtype.itemsize)
        # distribution traffic: every client receives its slice
        self.stats.comm_bytes_store += int(slices.size * slices.dtype.itemsize)
        s_dim = self.scheme.num_shards
        self.stats.encode_flops += 2 * self.scheme.num_clients * s_dim * p

    def attach_faults(self, plan) -> None:
        """Attach a ``repro.faults.FaultPlan``: its slice injectors fire on
        every subsequent ``get_shard`` (keyed per round — every reader of a
        round observes the same fault) and reads route through the
        quorum-read recovery path."""
        self.faults = plan

    def _injected_faults(self, rnd: int, slices: jnp.ndarray):
        """Ask the attached ``FaultPlan`` (if any) for this round's slice
        faults: ``(lost_ids, {row: noise})``.  Subclasses widen this — the
        tiered store additionally exposes offloaded (cold-tier) slices to
        ``cold_corrupt`` injectors."""
        if self.faults is None:
            return [], {}
        host = np.asarray(jax.device_get(slices)).astype(np.float32)
        return self.faults.slice_faults(
            rnd, self.scheme, int(slices.shape[1]),
            scale_ref=float(np.abs(host).mean()))

    def _decode_tol(self, rnd: int, slices: jnp.ndarray) -> float:
        """Corruption-detection tolerance for ``decode_robust``.  bf16 slices
        round-trip with ~4e-3 relative residual, so the tolerance scales with
        the storage dtype; the tiered store widens it further for rounds that
        passed through the lossy int8 tier."""
        return 1e-3 if slices.dtype.itemsize >= 4 else 3e-2

    def get(self, rnd: int, client: int):
        """Single-client retrieval decodes the client's shard and indexes it
        (the coded layout has no per-client granularity)."""
        for s, cs in self.shard_clients.items():
            if client in cs:
                return self.get_shard(rnd, s)[client]
        raise KeyError(client)

    def get_shard(self, rnd: int, shard: int,
                  available: Optional[Sequence[int]] = None,
                  corrupt: Optional[np.ndarray] = None) -> Dict[int, object]:
        """Reconstruct shard ``shard``'s stored params at round ``rnd``.

        ``available``: client ids whose slices are reachable (default: all).
        ``corrupt``: optional (C,P)-shaped noise to model erroneous slices —
        triggers the error-correcting decode path.

        With an attached ``FaultPlan`` (``attach_faults``) or explicit
        ``available``/``corrupt``, the read runs in quorum mode: missing and
        corrupt slices are detected and decoded around
        (``coding.decode_robust``) instead of raising, with per-read recovery
        accounting in ``StoreStats``; faults beyond eq. 11's budget raise
        ``coding.CodingBudgetExceeded``.
        """
        with get_tracer().span("store.read", round=rnd, shard=shard) as sp:
            with self._lock:
                if rnd not in self._slices:
                    self.flush()              # materialize deferred encodes
                slices = self._slices[rnd]
                layout = self._layouts[rnd]
                specs = self._specs[rnd]
                self.stats.reads += 1
                self.stats.comm_bytes_retrieve += int(
                    self.scheme.num_shards * slices.shape[1]
                    * slices.dtype.itemsize)
                self.stats.decode_flops += (2 * self.scheme.num_shards ** 2
                                            * slices.shape[1])
            # decode outside the lock: pure function of the slice tensor, so
            # interleaved serves decode different shards concurrently
            c = self.scheme.num_clients
            inj_lost, inj_noise = self._injected_faults(rnd, slices)
            if corrupt is None and available is None \
                    and not inj_lost and not inj_noise:
                ids = list(range(c))
                w = coding.decode_erasure(self.scheme,
                                          slices[jnp.asarray(ids)],
                                          ids, use_kernel=self.use_kernel)
            else:
                if inj_noise:
                    rows = sorted(inj_noise)
                    noise = np.stack([inj_noise[r] for r in rows])
                    slices = slices.at[jnp.asarray(rows)].add(
                        jnp.asarray(noise, slices.dtype))
                if corrupt is not None:
                    slices = slices + jnp.asarray(corrupt, slices.dtype)
                avail = (set(available) if available is not None
                         else set(range(c)))
                avail -= set(inj_lost)
                tol = self._decode_tol(rnd, slices)
                try:
                    w, lost, bad = coding.decode_robust(
                        self.scheme, slices, available=sorted(avail),
                        use_kernel=self.use_kernel, tol=tol)
                except coding.CodingBudgetExceeded:
                    with self._lock:
                        self.stats.failed_reads += 1
                    sp.annotate(failed=True)
                    raise
                if lost or bad:
                    with self._lock:
                        self.stats.recovered_reads += 1
                        self.stats.erased_slices += len(lost)
                        self.stats.corrupted_slices += len(bad)
                    sp.annotate(recovered=True, erased=len(lost),
                                corrupted=len(bad))
                    if self.faults is not None:
                        from repro.faults.events import RecoveryEvent
                        self.faults.ledger.record(RecoveryEvent(
                            "quorum_read",
                            site=("round", rnd, "shard", shard),
                            detail=(tuple(lost), tuple(bad))))
            for idx, (s, cs) in enumerate(layout):
                if s == shard:
                    spec = specs[idx]
                    if isinstance(spec, coding.StackedRowSpec):
                        return coding.flat_to_client_trees(w[idx], spec)
                    return coding.flat_to_tree(w[idx], spec)
            raise KeyError(f"shard {shard} not stored at round {rnd}")

    def clients_at(self, rnd: int) -> List[int]:
        return sorted(c for _, cs in self._layouts[rnd] for c in cs)


# ---------------------------------------------------------------------------
# Registered factories (the names FLSimulator / ScenarioConfig use)
# ---------------------------------------------------------------------------

@register_store("full")
def _make_full(shard_clients, **_options) -> FullStore:
    return FullStore()


@register_store("uncoded")
def _make_uncoded(shard_clients, **_options) -> UncodedShardStore:
    return UncodedShardStore({c: s for s, cs in shard_clients.items()
                              for c in cs})


@register_store("coded")
def _make_coded(shard_clients, *, num_shards: int, num_clients: int,
                group_rounds: int = 1, slice_dtype=None,
                use_kernel: bool = False, **_options) -> CodedStore:
    scheme = coding.CodingScheme(num_shards=num_shards,
                                 num_clients=num_clients)
    return CodedStore(scheme, shard_clients, group_rounds=group_rounds,
                      slice_dtype=slice_dtype, use_kernel=use_kernel)
