"""Unified telemetry: span tracing, metrics, Perfetto export, audit chain.

The single entry point every instrumented layer uses::

    from repro.telemetry import get_tracer

    with get_tracer().span("stage.train", stage=k, engine="stage") as sp:
        ...
        sp.annotate(cost_units=cost)

``get_tracer()`` returns a no-op tracer until ``configure(enabled=True)``
installs a recording one — the hot path pays nothing when disabled.  See
``tracer`` (spans, dual clocks, determinism), ``metrics`` (registry),
``export`` (Perfetto/JSONL/summary), and ``audit`` (hash-chained
unlearning event log).
"""
from repro.telemetry.audit import (
    GENESIS,
    AuditChainError,
    AuditLog,
    chain_hash,
    journal_chain,
    verify_chain,
    verify_journal,
)
from repro.telemetry.export import (
    hlo_cost_of,
    render_tree,
    to_chrome_trace,
    validate_chrome_trace,
    write_chrome_trace,
    write_jsonl,
)
from repro.telemetry.metrics import MetricsRegistry, NullMetrics
from repro.telemetry.tracer import (
    NULL_TRACER,
    NullTracer,
    Span,
    Tracer,
    configure,
    get_tracer,
    set_tracer,
)

__all__ = [
    "GENESIS",
    "AuditChainError",
    "AuditLog",
    "chain_hash",
    "journal_chain",
    "verify_chain",
    "verify_journal",
    "hlo_cost_of",
    "render_tree",
    "to_chrome_trace",
    "validate_chrome_trace",
    "write_chrome_trace",
    "write_jsonl",
    "MetricsRegistry",
    "NullMetrics",
    "NULL_TRACER",
    "NullTracer",
    "Span",
    "Tracer",
    "configure",
    "get_tracer",
    "set_tracer",
]
