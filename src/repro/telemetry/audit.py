"""Append-only, hash-chained audit log of unlearning lifecycle events.

Unlearning is a compliance operation: "client 7's data was erased" needs an
auditable, tamper-evident record, not just a ledger row (Blockchain-enabled
Trustworthy Federated Unlearning, arXiv 2401.15917, makes the case; this is
the lightweight, chain-without-the-blockchain version).  Every lifecycle
event — request received → scheduled → shards retrained → committed — is
appended as a record carrying the SHA-256 of its predecessor::

    hash_n = sha256(hash_{n-1} || canonical_json(event_n))

so truncating, reordering, or editing any record breaks every later hash
(``verify_chain`` walks the chain and raises ``AuditChainError`` at the
first break).

Durability layers on the PR 8 write-ahead journal: with a
``repro.durability.Journal`` attached, every audit record is ALSO journaled
(``{"ev": "audit", "event": ..., "prev": ..., "hash": ...}``, fsynced,
CRC-per-line), and a fresh ``AuditLog`` on the same journal **splices**:
it replays the journaled chain, verifies it, and continues appending from
its head — so a ``serve(resume=True)`` after a crash extends the original
chain into one verifiable history instead of starting a second one.

Determinism contract: callers record only deterministic fields (request
ids, client ids, shard sets, batch ids, virtual times — never measured
walls), so two seeded runs of the same workload produce bit-identical
chain heads (asserted in ``tests/test_telemetry.py``).
"""
from __future__ import annotations

import hashlib
import json
from typing import List, Optional

GENESIS = "0" * 64


class AuditChainError(RuntimeError):
    """The audit chain failed verification: a record was altered, dropped,
    reordered, or spliced from a different history."""


def canonical(event: dict) -> str:
    """The byte-stable form a record's hash covers."""
    return json.dumps(event, sort_keys=True, separators=(",", ":"))


def chain_hash(prev_hash: str, event: dict) -> str:
    return hashlib.sha256((prev_hash + canonical(event)).encode()).hexdigest()


def verify_chain(records: List[dict], genesis: str = GENESIS) -> str:
    """Walk ``[{"event", "prev", "hash"}, ...]`` from ``genesis``; returns
    the verified head hash, raises ``AuditChainError`` at the first break."""
    head = genesis
    for i, rec in enumerate(records):
        if rec["prev"] != head:
            raise AuditChainError(
                f"record {i} ({rec['event'].get('kind')!r}): prev hash "
                f"{rec['prev'][:12]}... does not extend head "
                f"{head[:12]}...")
        expect = chain_hash(head, rec["event"])
        if rec["hash"] != expect:
            raise AuditChainError(
                f"record {i} ({rec['event'].get('kind')!r}): stored hash "
                f"{rec['hash'][:12]}... != recomputed {expect[:12]}... "
                f"(record tampered)")
        head = rec["hash"]
    return head


def journal_chain(journal) -> List[dict]:
    """Extract the audit records from a ``repro.durability.Journal`` (or
    anything with ``events()``), in append order — the on-disk chain a
    verifier checks end-to-end with ``verify_chain``."""
    return [{"event": ev["event"], "prev": ev["prev"], "hash": ev["hash"]}
            for ev in journal.events() if ev.get("ev") == "audit"]


class AuditLog:
    """The writer: in-memory chain, optionally journal-backed.

    >>> audit = AuditLog(journal=service.journal)
    >>> audit.record("received", request_id="svc-3", clients=[7])
    >>> audit.verify()    # head hash; raises on tampering
    """

    def __init__(self, journal=None):
        self.journal = journal
        self.records: List[dict] = []
        self.head = GENESIS
        if journal is not None:
            self._splice()

    def _splice(self) -> None:
        """Adopt (and verify) the chain already in the journal — the resume
        path: a crashed run's audit history becomes this log's prefix."""
        self.records = journal_chain(self.journal)
        self.head = verify_chain(self.records)

    def record(self, kind: str, **fields) -> str:
        """Append one lifecycle event; returns the new head hash.  Callers
        pass deterministic fields only (ids, shards, virtual times)."""
        event = {"kind": kind, **fields}
        h = chain_hash(self.head, event)
        rec = {"event": event, "prev": self.head, "hash": h}
        self.records.append(rec)
        self.head = h
        if self.journal is not None:
            self.journal.append({"ev": "audit", **rec})
        return h

    def verify(self) -> str:
        """Re-verify the whole in-memory chain; returns the head hash."""
        head = verify_chain(self.records)
        if head != self.head:
            raise AuditChainError(
                f"head mismatch: chain verifies to {head[:12]}... but log "
                f"head is {self.head[:12]}...")
        return head

    def kinds(self) -> List[str]:
        return [r["event"]["kind"] for r in self.records]

    def events_of(self, request_id: str) -> List[dict]:
        """This request's lifecycle, in chain order."""
        return [r["event"] for r in self.records
                if r["event"].get("request_id") == request_id]

    def __len__(self) -> int:
        return len(self.records)

    def to_dict(self) -> dict:
        return {"head": self.head, "num_records": len(self.records),
                "kinds": self.kinds()}


def verify_journal(journal, genesis: str = GENESIS) -> Optional[str]:
    """End-to-end check of a journal's audit chain: extract, verify, return
    the head hash (``None`` when the journal holds no audit records)."""
    records = journal_chain(journal)
    if not records:
        return None
    return verify_chain(records, genesis=genesis)
