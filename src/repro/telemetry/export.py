"""Trace exporters: Chrome/Perfetto ``trace.json``, JSONL event log, and the
human summary tree.

* ``write_chrome_trace`` — the Chrome trace-event format Perfetto loads
  directly (https://ui.perfetto.dev): one complete ("X") event per span and
  one instant ("i") event per tracer event, laid out one lane per
  device/worker — spans labelled ``device=<i>`` land on a ``device-<i>``
  lane, everything else on its recording thread's lane.  XLA-dispatch spans
  annotated by the stage engine carry ``roofline.hlo_cost`` FLOP/byte
  estimates in their ``args``.
* ``validate_chrome_trace`` — structural validation against the trace-event
  schema (required keys, phase-specific fields, numeric timestamps); the CI
  telemetry job fails on any finding.
* ``write_jsonl`` — one JSON object per span, flat, for ad-hoc ``jq``-style
  analysis and the audit trail next to the Perfetto file.
* ``render_tree`` — the ``--trace-summary`` tree ``benchmarks/run.py``
  prints: spans aggregated by name at each nesting level with call counts
  and total wall.
"""
from __future__ import annotations

import json
from typing import Dict, List

# FLOP/byte annotations per compiled program: keyed on the jitted callable's
# id — safe because annotated programs live in the simulator's program cache
# for the simulator's lifetime.
_COST_CACHE: Dict[int, dict] = {}


def hlo_cost_of(fn, *args) -> dict:
    """``roofline.hlo_cost`` FLOP/byte estimates for a jitted program, via
    one cached AOT lower+compile.  Returns ``{}`` when the backend does not
    expose a cost analysis (never raises — annotation is best-effort)."""
    key = id(fn)
    if key in _COST_CACHE:
        return _COST_CACHE[key]
    try:
        from repro.roofline.hlo_cost import xla_cost_analysis
        ca = xla_cost_analysis(fn.lower(*args).compile())
        out = {}
        if "flops" in ca:
            out["hlo_flops"] = float(ca["flops"])
        if "bytes accessed" in ca:
            out["hlo_bytes_accessed"] = float(ca["bytes accessed"])
    except Exception:                      # noqa: BLE001 — best-effort
        out = {}
    _COST_CACHE[key] = out
    return out


# ---------------------------------------------------------------------------
# Chrome / Perfetto
# ---------------------------------------------------------------------------

def _lane_of(span) -> str:
    """Perfetto lane: one per device for placed jobs, one per recording
    thread otherwise (the service's ``unlearn-serve`` workers each get a
    lane; the main thread gets its own)."""
    if "device" in span.labels:
        return f"device-{span.labels['device']}"
    return span.lane or "main"


def to_chrome_trace(tracer) -> dict:
    """The Perfetto-loadable trace object (see module docstring)."""
    spans = tracer.all_spans()
    lanes = sorted({_lane_of(s) for s in spans})
    # MainThread lane first so the session timeline tops the view
    lanes.sort(key=lambda x: (x != "MainThread", x))
    tid_of = {lane: i + 1 for i, lane in enumerate(lanes)}
    events: List[dict] = [
        {"name": "process_name", "ph": "M", "pid": 1, "tid": 0,
         "args": {"name": "repro"}}]
    for lane, tid in tid_of.items():
        events.append({"name": "thread_name", "ph": "M", "pid": 1,
                       "tid": tid, "args": {"name": lane}})
    for s in spans:
        args = {k: (v if isinstance(v, (bool, int, float, str)) else str(v))
                for k, v in sorted(s.labels.items())}
        if s.v0 is not None:
            args["t_virtual_s"] = s.v0
        ev = {"name": s.name, "cat": s.name.split(".", 1)[0],
              "pid": 1, "tid": tid_of[_lane_of(s)],
              "ts": round(s.t0 * 1e6, 3), "args": args}
        if s.kind == "event":
            ev.update(ph="i", s="t")
        else:
            ev.update(ph="X", dur=round(max(s.t1 - s.t0, 0.0) * 1e6, 3))
        events.append(ev)
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"producer": "repro.telemetry",
                          "span_signature": tracer.signature()}}


def write_chrome_trace(tracer, path: str) -> str:
    with open(path, "w") as f:
        json.dump(to_chrome_trace(tracer), f, indent=1)
    tracer.trace_path = path
    return path


_PHASES = {"X", "B", "E", "i", "I", "M", "C", "b", "e", "n", "s", "t", "f"}


def validate_chrome_trace(obj) -> List[str]:
    """Structural validation against the Chrome trace-event schema.  Returns
    a list of findings — empty means Perfetto-loadable."""
    errors: List[str] = []
    if not isinstance(obj, dict):
        return [f"top level must be an object, got {type(obj).__name__}"]
    events = obj.get("traceEvents")
    if not isinstance(events, list):
        return ["missing/invalid 'traceEvents' array"]
    for i, ev in enumerate(events):
        where = f"traceEvents[{i}]"
        if not isinstance(ev, dict):
            errors.append(f"{where}: not an object")
            continue
        ph = ev.get("ph")
        if ph not in _PHASES:
            errors.append(f"{where}: invalid phase {ph!r}")
            continue
        if not isinstance(ev.get("name"), str) or not ev["name"]:
            errors.append(f"{where}: missing event name")
        for key in ("pid", "tid"):
            if not isinstance(ev.get(key), int):
                errors.append(f"{where}: {key} must be an int")
        if ph == "M":
            continue                       # metadata carries no timestamp
        ts = ev.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            errors.append(f"{where}: ts must be a non-negative number")
        if ph == "X":
            dur = ev.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                errors.append(f"{where}: 'X' event needs non-negative dur")
        if ph == "i" and ev.get("s") not in (None, "g", "p", "t"):
            errors.append(f"{where}: instant scope must be g/p/t")
    return errors


# ---------------------------------------------------------------------------
# JSONL event log
# ---------------------------------------------------------------------------

def write_jsonl(tracer, path: str) -> str:
    """One flat JSON object per span/event, in canonical (deterministic
    tree) order, wall and virtual clocks side by side."""
    with open(path, "w") as f:
        for s in tracer.all_spans():
            row = {"name": s.name, "kind": s.kind, "lane": s.lane,
                   "t0_s": s.t0, "t1_s": s.t1, "wall_s": s.t1 - s.t0,
                   "v0_s": s.v0, "v1_s": s.v1}
            row.update({f"l_{k}": (v if isinstance(v, (bool, int, float,
                                                       str)) else str(v))
                        for k, v in sorted(s.labels.items())})
            f.write(json.dumps(row, sort_keys=True) + "\n")
    return path


# ---------------------------------------------------------------------------
# Human summary
# ---------------------------------------------------------------------------

def render_tree(tracer, max_depth: int = 8) -> str:
    """The ``--trace-summary`` view: spans aggregated by name per nesting
    level, with call counts and total wall.

    stage.train x3                 412.1 ms
      store.encode x3                8.4 ms
    service.serve x1               130.0 ms
      ...
    """
    lines: List[str] = []

    def walk(spans, depth):
        if depth >= max_depth or not spans:
            return
        groups: Dict[str, list] = {}
        for s in spans:
            groups.setdefault(s.name, []).append(s)
        for name, group in groups.items():
            total_ms = sum(s.t1 - s.t0 for s in group) * 1e3
            label = f"{'  ' * depth}{name} x{len(group)}"
            lines.append(f"{label:<48s} {total_ms:10.1f} ms")
            walk([c for s in group for c in s.children], depth + 1)

    walk(tracer.sorted_roots(), 0)
    if not lines:
        return "(no spans recorded)"
    return "\n".join(lines)
