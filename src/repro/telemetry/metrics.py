"""Metrics registry — counters, gauges, and histograms with label sets.

The registry is the numeric half of the telemetry layer: the span tracer
answers "where did the time go", the registry answers "how much of what".
It absorbs and re-exposes the accounting the subsystems already keep —
``StoreStats`` byte/FLOP counters, the service's fault-recovery counters,
and ``ServiceReport`` latency percentiles — and adds the per-client p99
unlearning-latency breakdown (ROADMAP item 3: aggregate p99 hides
hot-client starvation; FedShard, arXiv 2508.09866).

Conventions:

* ``counter(name, **labels)`` — monotone, ``.inc()`` at the instrumentation
  site (fault events, served requests).
* ``gauge(name, **labels)`` — last-write-wins, used by the ``absorb_*``
  helpers so re-absorbing a snapshot is idempotent (reports can call
  ``to_dict`` twice without double counting).
* ``histogram(name, **labels)`` — raw observations with exact percentiles
  (``observe`` per served request; per-client p99 comes from the
  ``client=<id>`` label set).

Every metric family is keyed on ``(name, sorted labels)``; ``snapshot()``
renders ``name{k=v,...}`` keys, the form embedded in report JSON.
"""
from __future__ import annotations

import threading
from typing import Dict, List, Tuple

import numpy as np


def _label_key(labels: dict) -> Tuple[Tuple[str, str], ...]:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _render_key(name: str, key: Tuple[Tuple[str, str], ...]) -> str:
    if not key:
        return name
    return name + "{" + ",".join(f"{k}={v}" for k, v in key) + "}"


class Counter:
    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0.0
        self._lock = lock

    def inc(self, v: float = 1.0) -> None:
        with self._lock:
            self.value += v


class Gauge:
    __slots__ = ("value", "_lock")

    def __init__(self, lock: threading.Lock):
        self.value = 0.0
        self._lock = lock

    def set(self, v: float) -> None:
        with self._lock:
            self.value = float(v)


class Histogram:
    __slots__ = ("values", "_lock")

    def __init__(self, lock: threading.Lock):
        self.values: List[float] = []
        self._lock = lock

    def observe(self, v: float) -> None:
        with self._lock:
            self.values.append(float(v))

    @property
    def count(self) -> int:
        return len(self.values)

    @property
    def sum(self) -> float:
        return float(sum(self.values))

    def percentile(self, q: float) -> float:
        with self._lock:
            vals = list(self.values)
        if not vals:
            return float("nan")
        return float(np.percentile(np.asarray(vals, np.float64), q))

    def summary(self) -> dict:
        return {"count": self.count, "sum": self.sum,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99)}


class MetricsRegistry:
    """Thread-safe, label-keyed metric families."""

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: Dict[tuple, Counter] = {}
        self._gauges: Dict[tuple, Gauge] = {}
        self._histograms: Dict[tuple, Histogram] = {}

    def _get(self, table: dict, cls, name: str, labels: dict):
        key = (name, _label_key(labels))
        with self._lock:
            m = table.get(key)
            if m is None:
                m = table[key] = cls(self._lock)
        return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(self._counters, Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(self._gauges, Gauge, name, labels)

    def histogram(self, name: str, **labels) -> Histogram:
        return self._get(self._histograms, Histogram, name, labels)

    # ------------------------------------------------------------- snapshot
    def snapshot(self) -> dict:
        with self._lock:
            counters = dict(self._counters)
            gauges = dict(self._gauges)
            hists = dict(self._histograms)
        return {
            "counters": {_render_key(n, k): c.value
                         for (n, k), c in sorted(counters.items())},
            "gauges": {_render_key(n, k): g.value
                       for (n, k), g in sorted(gauges.items())},
            "histograms": {_render_key(n, k): h.summary()
                           for (n, k), h in sorted(hists.items())},
        }

    # ------------------------------------------------------ absorb existing
    def absorb_store_stats(self, stats, **labels) -> None:
        """Re-expose a ``StoreStats`` snapshot as ``store.<field>`` gauges
        (idempotent — absorbing the same snapshot twice is a no-op).  The
        per-tier dict fields of a tiered store fan out into one gauge per
        tier label: ``store.tier_bytes{tier=warm}`` etc."""
        for field, value in stats.to_dict().items():
            if isinstance(value, dict):
                for tier, v in value.items():
                    self.gauge(f"store.{field}", tier=tier, **labels).set(v)
            else:
                self.gauge(f"store.{field}", **labels).set(value)

    def absorb_faults(self, faults: dict, **labels) -> None:
        """Re-expose a serve's fault/recovery counters (the ``faults`` dict
        of ``ServiceReport``) as ``faults.<name>`` gauges."""
        for k, v in faults.items():
            if isinstance(v, (int, float)) and not isinstance(v, bool):
                self.gauge(f"faults.{k}", **labels).set(v)

    def absorb_service_report(self, report, **labels) -> None:
        """Re-expose a ``ServiceReport``'s aggregates — latency p50/p95/p99,
        throughput, SLA hit rate — plus the per-client p99 breakdown."""
        self.gauge("service.latency_p50_s", **labels).set(report.p50)
        self.gauge("service.latency_p95_s", **labels).set(report.p95)
        self.gauge("service.latency_p99_s", **labels).set(report.p99)
        self.gauge("service.throughput_rps", **labels).set(report.throughput)
        sla = report.sla_hit_rate
        if sla is not None:
            self.gauge("service.sla_hit_rate", **labels).set(sla)
        self.gauge("service.num_requests", **labels).set(len(report.entries))
        self.absorb_faults(report.faults, **labels)
        for client, p99 in report.per_client_p99().items():
            self.gauge("service.client_latency_p99_s", client=client,
                       **labels).set(p99)

    def per_client_p99(self, name: str = "service.client_latency_s") -> dict:
        """{client: p99} from the per-client latency histograms the serving
        engine observes into ``name{client=<id>}``."""
        with self._lock:
            hists = dict(self._histograms)
        out = {}
        for (n, key), h in hists.items():
            if n != name:
                continue
            labels = dict(key)
            if "client" in labels:
                out[int(labels["client"])] = h.percentile(99)
        return dict(sorted(out.items()))


# ---------------------------------------------------------------------------
# No-op twins (the NullTracer's .metrics)
# ---------------------------------------------------------------------------

class _NullInstrument:
    __slots__ = ()
    value = 0.0
    values: List[float] = []
    count = 0
    sum = 0.0

    def inc(self, v: float = 1.0) -> None:
        pass

    def set(self, v: float) -> None:
        pass

    def observe(self, v: float) -> None:
        pass

    def percentile(self, q: float) -> float:
        return float("nan")

    def summary(self) -> dict:
        return {}


_NULL_INSTRUMENT = _NullInstrument()


class NullMetrics:
    """No-op registry: every accessor returns the shared null instrument."""

    __slots__ = ()

    def counter(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def gauge(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def histogram(self, name: str, **labels) -> _NullInstrument:
        return _NULL_INSTRUMENT

    def snapshot(self) -> dict:
        return {}

    def absorb_store_stats(self, stats, **labels) -> None:
        pass

    def absorb_faults(self, faults: dict, **labels) -> None:
        pass

    def absorb_service_report(self, report, **labels) -> None:
        pass

    def per_client_p99(self, name: str = "service.client_latency_s") -> dict:
        return {}
