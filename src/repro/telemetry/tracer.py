"""Structured span tracing — nested, thread-safe, dual-clocked.

One ``Tracer`` records a forest of ``Span``s: every instrumented layer
(``FederatedSession``, ``UnlearningService``, ``CodedStore``, fault
injection, snapshot/journal I/O) opens spans through the single
``get_tracer()`` entry point::

    with get_tracer().span("stage.train", engine="stage", shards=2):
        ...

Design points, in the order they matter:

* **No-op by default.**  ``get_tracer()`` returns the ``NULL_TRACER``
  singleton until ``configure(enabled=True)`` installs a recording tracer,
  and the null tracer's ``span``/``event`` return one preallocated null
  context manager — the instrumented hot paths pay a dict build and two
  no-op calls, nothing else (asserted < 2% of a stage's wall in
  ``tests/test_telemetry.py``; measured off/on in ``benchmarks/
  fig10_telemetry.py``).
* **Thread-safe nesting.**  Each thread keeps its own span stack
  (``threading.local``): a span closed on the thread that opened it
  attaches to that thread's enclosing span, or — for the service's
  ``unlearn-serve`` worker threads, whose stacks start empty — becomes a
  new root under the tracer lock.  Parent/child order within a thread is
  therefore deterministic; only the root list is completion-ordered, and
  every tree/signature/export consumer re-sorts roots canonically.
* **Dual clocks.**  Every span records wall offsets from the tracer epoch
  (``time.perf_counter``) and, when a ``VirtualClock`` is attached
  (``attach_clock`` — the service engine attaches its discrete-event clock
  while planning), the deterministic virtual time at entry and exit.  The
  canonical ``signature()`` hashes names, labels, virtual times, and
  nesting — never wall times or thread names — so two seeded service runs
  produce bit-identical span trees (asserted in tests).
"""
from __future__ import annotations

import hashlib
import json
import threading
import time
from typing import List, Optional

from repro.telemetry.metrics import MetricsRegistry, NullMetrics


class Span:
    """One traced operation.  Context manager: entering pushes it on the
    current thread's stack, exiting records end times and attaches it to
    the enclosing span (or the tracer's root list)."""

    __slots__ = ("name", "labels", "kind", "t0", "t1", "v0", "v1", "lane",
                 "children", "_tracer")

    def __init__(self, tracer: "Tracer", name: str, labels: dict,
                 kind: str = "span"):
        self._tracer = tracer
        self.name = name
        self.labels = labels
        self.kind = kind                  # "span" | "event" (zero-duration)
        self.t0 = self.t1 = 0.0           # wall offsets from tracer epoch
        self.v0 = self.v1 = None          # virtual times (clock attached)
        self.lane = ""
        self.children: List["Span"] = []

    # ---------------------------------------------------------------- enter
    def __enter__(self) -> "Span":
        tr = self._tracer
        self.lane = threading.current_thread().name
        self.t0 = time.perf_counter() - tr.epoch
        clock = tr.clock
        if clock is not None:
            self.v0 = float(clock.now)
        tr._stack().append(self)
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        tr = self._tracer
        self.t1 = time.perf_counter() - tr.epoch
        clock = tr.clock
        if clock is not None:
            self.v1 = float(clock.now)
        stack = tr._stack()
        if stack and stack[-1] is self:
            stack.pop()
        if stack:
            stack[-1].children.append(self)
        else:
            with tr._lock:
                tr.roots.append(self)
        return False

    def annotate(self, **labels) -> "Span":
        """Attach labels after creation (e.g. recovery counts discovered
        mid-span, FLOP/byte estimates of the dispatched program)."""
        self.labels.update(labels)
        return self

    @property
    def wall(self) -> float:
        return self.t1 - self.t0

    def to_dict(self) -> dict:
        return {"name": self.name, "kind": self.kind,
                "labels": dict(self.labels), "lane": self.lane,
                "t0_s": self.t0, "t1_s": self.t1,
                "v0_s": self.v0, "v1_s": self.v1,
                "children": [c.to_dict() for c in self.children]}


class _NullSpan:
    """The preallocated no-op span: entering/exiting/annotating costs two
    attribute lookups and nothing else."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, exc_type, exc, tb):
        return False

    def annotate(self, **labels):
        return self


_NULL_SPAN = _NullSpan()


def _canon_value(v):
    """Canonicalize a label value for the deterministic signature."""
    if isinstance(v, (list, tuple)):
        return [_canon_value(x) for x in v]
    if v is None or isinstance(v, (bool, int, float, str)):
        return v
    return str(v)


def _canon_node(span: Span) -> dict:
    """The deterministic form of one span: name, labels, virtual times,
    children — wall times and thread lanes deliberately excluded."""
    return {"name": span.name, "kind": span.kind,
            "labels": {k: _canon_value(v)
                       for k, v in sorted(span.labels.items())},
            "v0": span.v0, "v1": span.v1,
            "children": [_canon_node(c) for c in span.children]}


class Tracer:
    """A recording tracer: span forest + metrics registry + exporter state."""

    enabled = True

    def __init__(self, clock=None, annotate_costs: bool = False):
        self.epoch = time.perf_counter()
        self.clock = clock                 # optional VirtualClock
        self.annotate_costs = bool(annotate_costs)
        self.metrics = MetricsRegistry()
        self.roots: List[Span] = []
        self.trace_path: Optional[str] = None
        self._lock = threading.Lock()
        self._local = threading.local()

    def _stack(self) -> list:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    # ------------------------------------------------------------------ api
    def span(self, name: str, **labels) -> Span:
        return Span(self, name, labels)

    def event(self, name: str, **labels) -> None:
        """Record an instant (zero-duration) event at the current nesting."""
        with Span(self, name, labels, kind="event"):
            pass

    def attach_clock(self, clock) -> None:
        """Attach a ``VirtualClock``: subsequent spans carry deterministic
        virtual times alongside their measured wall offsets."""
        self.clock = clock

    def detach_clock(self) -> None:
        self.clock = None

    # ------------------------------------------------------------ inspection
    def sorted_roots(self) -> List[Span]:
        """Roots in canonical order — completion order is thread-racy, so
        every consumer (tree, signature, export) sorts by the deterministic
        node form first, wall start second (same-thread ties)."""
        with self._lock:
            roots = list(self.roots)
        return sorted(roots, key=lambda s: (json.dumps(
            _canon_node(s), sort_keys=True), s.t0))

    def all_spans(self) -> List[Span]:
        out: List[Span] = []

        def walk(span: Span):
            out.append(span)
            for c in span.children:
                walk(c)

        for root in self.sorted_roots():
            walk(root)
        return out

    def span_names(self) -> List[str]:
        return sorted({s.name for s in self.all_spans()})

    def tree(self) -> List[dict]:
        """The canonical (deterministic) span forest."""
        return [_canon_node(r) for r in self.sorted_roots()]

    def signature(self) -> str:
        """sha256 over the canonical span forest — two seeded runs of the
        same workload must produce equal signatures (wall times excluded)."""
        blob = json.dumps(self.tree(), sort_keys=True,
                          separators=(",", ":"))
        return hashlib.sha256(blob.encode()).hexdigest()

    def describe(self) -> dict:
        """The report-embeddable summary (``telemetry`` section of
        ``SessionReport``/``ServiceReport`` JSON)."""
        return {"enabled": True,
                "num_spans": len(self.all_spans()),
                "span_signature": self.signature(),
                "trace_path": self.trace_path,
                "metrics": self.metrics.snapshot()}


class NullTracer:
    """The disabled tracer: every operation is a no-op and ``span``/``event``
    allocate nothing beyond the caller's kwargs dict."""

    enabled = False
    clock = None
    annotate_costs = False
    trace_path = None
    metrics = NullMetrics()
    roots: List[Span] = []

    def span(self, name: str, **labels) -> _NullSpan:
        return _NULL_SPAN

    def event(self, name: str, **labels) -> None:
        pass

    def attach_clock(self, clock) -> None:
        pass

    def detach_clock(self) -> None:
        pass

    def sorted_roots(self) -> list:
        return []

    def all_spans(self) -> list:
        return []

    def span_names(self) -> list:
        return []

    def tree(self) -> list:
        return []

    def signature(self) -> str:
        return ""

    def describe(self) -> dict:
        return {"enabled": False}


NULL_TRACER = NullTracer()
_CURRENT: object = NULL_TRACER


def get_tracer():
    """The process-wide tracer — ``NULL_TRACER`` until ``configure`` installs
    a recording one.  The single entry point every instrumented layer uses."""
    return _CURRENT


def set_tracer(tracer) -> None:
    global _CURRENT
    _CURRENT = tracer if tracer is not None else NULL_TRACER


def configure(enabled: bool = True, clock=None,
              annotate_costs: bool = False):
    """Install (and return) a fresh recording tracer, or restore the no-op
    default with ``enabled=False``.

    ``annotate_costs=True`` additionally annotates XLA-dispatch spans with
    ``roofline.hlo_cost`` FLOP/byte estimates (one extra AOT compile per
    unique program — leave off for overhead-sensitive runs).
    """
    if not enabled:
        set_tracer(None)
        return NULL_TRACER
    tracer = Tracer(clock=clock, annotate_costs=annotate_costs)
    set_tracer(tracer)
    return tracer
