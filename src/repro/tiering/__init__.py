"""Tiered coded parameter storage — hot / warm / cold under a memory budget.

The paper's Fig. 5 storage claim measured one point (f32/bf16 coded slices,
device-resident).  This subsystem turns it into a *frontier*: coded rounds
demote through ``TIERS`` (hot device → warm host int8 → cold mmap'd disk)
under a ``MemoryBudget`` with pluggable ``EVICTION`` policies, and
``benchmarks/fig11_tiering.py`` measures storage-bytes × decode-error ×
SE-unlearn-wall across budget sweeps.  ``TieredStore`` registers as
``"tiered"`` in ``repro.stores.STORES`` — every scenario, framework, and the
unlearning service run on it unchanged (``ScenarioConfig(store="tiered",
store_options={...})``).
"""
from repro.tiering.budget import (EVICTION, UNLIMITED, MemoryBudget,
                                  make_eviction, register_eviction)
from repro.tiering.quant import (dequantize_int8, quant_error_bound,
                                 quantize_int8)
from repro.tiering.store import TierTable, TieredStore
from repro.tiering.tiers import (TIER_ORDER, TIERS, TierEntry, register_tier)

__all__ = [
    "EVICTION", "MemoryBudget", "TIERS", "TIER_ORDER", "TierEntry",
    "TierTable", "TieredStore", "UNLIMITED", "dequantize_int8",
    "make_eviction", "quant_error_bound", "quantize_int8",
    "register_eviction", "register_tier",
]
