"""Memory budgets and the ``EVICTION`` policy registry.

A ``MemoryBudget`` caps the bytes resident in the hot (device) and warm
(host-RAM) tiers; ``None`` means unlimited — the default budget keeps every
round hot, which is exactly today's ``CodedStore`` behavior (and what the
bit-identity tests assert).  The cold tier is disk and unbounded.

Eviction policies are victim selectors: given the candidate entries of an
over-budget tier, pick the one to demote a rung down.  Registered like every
other pluggable in this repo (``STORES``/``POLICIES``/``INJECTORS``):

* ``lru``       — demote the least-recently-accessed round.
* ``stage_age`` — demote the oldest round (training history cools front to
  back: early rounds are only re-read when an unlearning request reaches
  back to them).
* ``heat``      — Zipf-aware: demote the *coldest* round by service access
  count (ties broken by recency).  Under the service layer's Zipf-skewed
  workloads hot clients keep their shard's recent rounds pinned while the
  long tail offloads.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.tiering.tiers import TierEntry


@dataclass(frozen=True)
class MemoryBudget:
    """Byte caps per capped tier (``None`` = unlimited)."""
    hot_bytes: Optional[int] = None
    warm_bytes: Optional[int] = None

    def limit(self, tier: str) -> Optional[int]:
        return {"hot": self.hot_bytes, "warm": self.warm_bytes}.get(tier)

    def admits_hot(self, nbytes: int) -> bool:
        """Can an entry of this size ever be hot-resident at all?  (Promotion
        is skipped entirely when it can't — avoids promote/demote churn when
        ``hot_bytes`` is below one round.)"""
        return self.hot_bytes is None or nbytes <= self.hot_bytes

    def to_dict(self) -> dict:
        return {"hot_bytes": self.hot_bytes, "warm_bytes": self.warm_bytes}


UNLIMITED = MemoryBudget()


EVICTION: Dict[str, Callable[[List[TierEntry]], TierEntry]] = {}


def register_eviction(name: str):
    def deco(fn):
        EVICTION[name] = fn
        return fn
    return deco


def make_eviction(name: str) -> Callable[[List[TierEntry]], TierEntry]:
    try:
        return EVICTION[name]
    except KeyError:
        raise KeyError(f"unknown eviction policy {name!r}; registered: "
                       f"{sorted(EVICTION)}") from None


@register_eviction("lru")
def _lru(entries: List[TierEntry]) -> TierEntry:
    return min(entries, key=lambda e: (e.last_access, e.key))


@register_eviction("stage_age")
def _stage_age(entries: List[TierEntry]) -> TierEntry:
    return min(entries, key=lambda e: (e.stage, e.key))


@register_eviction("heat")
def _heat(entries: List[TierEntry]) -> TierEntry:
    return min(entries, key=lambda e: (e.hits, e.last_access, e.key))
