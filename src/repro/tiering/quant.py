"""int8 symmetric per-slice quantization — the warm/cold tier codec.

A coded round is a ``(C, P)`` slice tensor (one Lagrange slice per client).
Each *row* gets its own symmetric scale ``amax / 127`` so a hot client's
large-magnitude slice cannot blow up the quantization error of its
neighbours — the per-slice granularity mirrors how slices live on distinct
clients in the paper's protocol.

Determinism contract: once a round is quantized its ``(q, scales)`` payload
is canonical.  Re-quantizing a *dequantized* tensor with the SAME stored
scales reproduces ``q`` bit-exactly (the dequantized values sit within a few
float32 ulps of the integer grid points, far inside the rint rounding
window), which is what makes promote→demote→read bit-stable without keeping
the int8 payload resident.  The tiered store therefore always passes the
entry's stored ``scales`` back into :func:`quantize_int8` when it re-demotes
a lossy round.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def quantize_int8(arr, scales: Optional[np.ndarray] = None
                  ) -> Tuple[np.ndarray, np.ndarray]:
    """Quantize a ``(C, P)`` slice tensor to ``(q int8 (C, P), scales f32
    (C,))`` with symmetric per-row scales ``amax / 127`` (zero rows get
    scale 1.0 so dequantization is always well-defined).

    Passing previously stored ``scales`` skips the amax recompute and makes
    requantization of a dequantized tensor bit-exact (see module docstring).
    """
    a = np.asarray(jax.device_get(arr), dtype=np.float32)
    if a.ndim != 2:
        raise ValueError(f"expected a (C, P) slice tensor, got {a.shape}")
    if scales is None:
        amax = np.abs(a).max(axis=1)
        scales = np.where(amax > 0.0, amax / 127.0, 1.0).astype(np.float32)
    else:
        scales = np.asarray(scales, dtype=np.float32)
        if scales.shape != (a.shape[0],):
            raise ValueError(f"scales shape {scales.shape} != ({a.shape[0]},)")
    q = np.clip(np.rint(a / scales[:, None]), -127, 127).astype(np.int8)
    return q, scales


def dequantize_int8(q: np.ndarray, scales: np.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    """Reconstruct the slice tensor on device in ``dtype`` (the tier's
    original hot dtype, so downstream decode sees the shapes/dtypes it
    always saw)."""
    a = q.astype(np.float32) * np.asarray(scales, np.float32)[:, None]
    return jnp.asarray(a, dtype=dtype)


def quant_error_bound(scales: np.ndarray) -> float:
    """Worst-case absolute reconstruction error per element: half a
    quantization step of the widest row, plus a few float32 ulps of
    headroom for the dequant multiply."""
    smax = float(np.asarray(scales, np.float32).max())
    return smax * (0.5 + 127 * float(np.finfo(np.float32).eps))
