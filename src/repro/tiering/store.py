"""``TieredStore`` — the coded store on a hot/warm/cold tier ladder.

Drop-in ``CodedStore`` subclass registered as ``"tiered"`` in ``STORES``:
the only structural change is that the round→slices dict is replaced by a
``TierTable``, a ``MutableMapping`` whose ``__setitem__`` admits rounds to
the hot tier and enforces the ``MemoryBudget`` by demoting victims down the
ladder (hot → warm int8 → cold disk), and whose ``__getitem__`` serves the
round back from whatever tier it lives in (dequantizing / mmap-reading as
needed) with per-tier hit/miss/byte accounting in ``StoreStats`` and
``tier.decode`` / ``tier.promote`` / ``tier.demote`` spans in the tracer.

With the default unlimited budget nothing ever demotes and every read is the
device array itself — bit-identical to ``CodedStore``, byte-for-byte in the
shared ``StoreStats`` fields (asserted in ``tests/test_tiering.py``).  Under
pressure the store trades bytes for a bounded decode error: warm/cold rounds
reconstruct within the int8 quantization bound
(``repro.tiering.quant.quant_error_bound``), and the robust-decode tolerance
widens accordingly so quantization residue is never mistaken for corruption.

Thread-safety: the table is only touched inside ``CodedStore``'s read/write
paths, which already hold ``self._lock`` (re-entrant) around every
``_slices`` access — the service layer's interleaved serves therefore
promote/demote safely.
"""
from __future__ import annotations

import os
import tempfile
from typing import Dict, Iterator, MutableMapping, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import coding
from repro.stores.store import CodedStore, register_store
from repro.telemetry import get_tracer
from repro.tiering.budget import UNLIMITED, MemoryBudget, make_eviction
from repro.tiering.tiers import TIER_ORDER, TIERS, TierEntry, next_tier


class TierTable(MutableMapping):
    """round id → slice tensor, tier-managed.

    Callers must hold the owning store's ``_lock`` (true for every
    ``CodedStore`` path that touches ``_slices``)."""

    def __init__(self, store: "TieredStore"):
        self._store = store
        self._entries: Dict[int, TierEntry] = {}
        self._seq = 0                       # access clock (LRU order)
        self._births = 0                    # insert clock (stage-age order)
        self.last_served: Dict[int, str] = {}   # rnd -> tier of latest read

    # ------------------------------------------------------------ mapping
    def __setitem__(self, rnd: int, slices: jnp.ndarray) -> None:
        e = self._entries.get(rnd)
        if e is None:
            e = TierEntry(key=rnd,
                          shape=(int(slices.shape[0]), int(slices.shape[1])),
                          dtype=slices.dtype, stage=self._births)
            self._births += 1
            self._entries[rnd] = e
        else:
            self._drop_bytes(e)
        self._seq += 1
        e.last_access = self._seq
        TIERS["hot"].place(e, array=slices)
        self._add_bytes(e)
        self._enforce()

    def __getitem__(self, rnd: int) -> jnp.ndarray:
        e = self._entries[rnd]
        self._seq += 1
        e.last_access = self._seq
        e.hits += 1
        served = e.tier
        self.last_served[rnd] = served
        stats = self._store.stats
        stats.tier_hits[served] = stats.tier_hits.get(served, 0) + 1
        for t in TIER_ORDER:             # tiers above the serving one missed
            if t == served:
                break
            stats.tier_misses[t] = stats.tier_misses.get(t, 0) + 1
        if served == "hot":
            return e.device
        with get_tracer().span("tier.decode", round=rnd, tier=served):
            arr = TIERS[served].read(e)
        if (self._store.promote_on_read
                and self._store.budget.admits_hot(e.hot_nbytes())):
            with get_tracer().span("tier.promote", round=rnd, src=served):
                self._drop_bytes(e)
                TIERS["hot"].place(e, array=arr)
                self._add_bytes(e)
                stats.tier_promotions["hot"] = \
                    stats.tier_promotions.get("hot", 0) + 1
            self._enforce(pin=rnd)
        return arr

    def __delitem__(self, rnd: int) -> None:
        e = self._entries.pop(rnd)
        self._drop_bytes(e)
        self.last_served.pop(rnd, None)

    def __iter__(self) -> Iterator[int]:
        return iter(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, rnd) -> bool:     # MutableMapping's default would
        return rnd in self._entries          # decode via __getitem__

    # ------------------------------------------------------------- tiering
    def entry(self, rnd: int) -> Optional[TierEntry]:
        return self._entries.get(rnd)

    def entries(self) -> Dict[int, TierEntry]:
        return dict(self._entries)

    def _enforce(self, pin: Optional[int] = None) -> None:
        """Demote victims one rung at a time until every capped tier fits.
        ``pin`` protects the round just promoted by the in-flight read from
        being demoted back before it is even returned."""
        budget = self._store.budget
        for tier in ("hot", "warm"):
            limit = budget.limit(tier)
            if limit is None:
                continue
            while self._store.stats.tier_bytes.get(tier, 0) > limit:
                cands = [e for e in self._entries.values()
                         if e.tier == tier and e.key != pin]
                if not cands:
                    break
                self._demote(self._store.evict(cands))

    def _demote(self, e: TierEntry) -> None:
        src, dst = e.tier, next_tier(e.tier)
        with get_tracer().span("tier.demote", round=e.key, src=src, dst=dst):
            self._drop_bytes(e)
            TIERS[dst].place(e, cold_dir=self._store.cold_dir)
            self._add_bytes(e)
        stats = self._store.stats
        stats.tier_evictions[src] = stats.tier_evictions.get(src, 0) + 1

    def _add_bytes(self, e: TierEntry) -> None:
        tb = self._store.stats.tier_bytes
        tb[e.tier] = tb.get(e.tier, 0) + e.nbytes()

    def _drop_bytes(self, e: TierEntry) -> None:
        tb = self._store.stats.tier_bytes
        tb[e.tier] = tb.get(e.tier, 0) - e.nbytes()


class TieredStore(CodedStore):
    """Coded store whose slice tensors live on the hot/warm/cold ladder."""

    def __init__(self, scheme: coding.CodingScheme,
                 shard_clients, use_kernel: bool = False, slice_dtype=None,
                 group_rounds: int = 1,
                 budget: Optional[MemoryBudget] = None,
                 eviction: str = "lru",
                 offload_dir: Optional[str] = None,
                 promote_on_read: bool = True):
        super().__init__(scheme, shard_clients, use_kernel=use_kernel,
                         slice_dtype=slice_dtype, group_rounds=group_rounds)
        self.budget = budget if budget is not None else UNLIMITED
        self.eviction = eviction
        self.evict = make_eviction(eviction)
        self.promote_on_read = bool(promote_on_read)
        self.offload_dir = offload_dir
        self._cold_dir: Optional[str] = None
        self._slices = TierTable(self)       # type: ignore[assignment]

    # ------------------------------------------------------------ cold dir
    @property
    def cold_dir(self) -> str:
        """Lazy per-store offload directory — unique even when several stage
        stores share one ``offload_dir``, so cold files never collide."""
        if self._cold_dir is None:
            if self.offload_dir is not None:
                os.makedirs(self.offload_dir, exist_ok=True)
                self._cold_dir = tempfile.mkdtemp(prefix="cold-",
                                                  dir=self.offload_dir)
            else:
                self._cold_dir = tempfile.mkdtemp(prefix="repro-cold-")
        return self._cold_dir

    # ------------------------------------------------------- decode hooks
    def _injected_faults(self, rnd: int, slices: jnp.ndarray):
        """Base slice faults, plus ``cold_corrupt`` noise when this read was
        served from the cold tier (bit-rot lives on the offloaded medium)."""
        lost, noise = super()._injected_faults(rnd, slices)
        if (self.faults is not None
                and self._slices.last_served.get(rnd) == "cold"):
            host = np.asarray(jax.device_get(slices)).astype(np.float32)
            cold = self.faults.cold_faults(
                rnd, self.scheme, int(slices.shape[1]),
                scale_ref=float(np.abs(host).mean()))
            for row, vec in cold.items():
                noise[row] = noise[row] + vec if row in noise else vec
        return lost, noise

    def _decode_tol(self, rnd: int, slices: jnp.ndarray) -> float:
        """Rounds that passed through the int8 tier carry ~0.4% relative
        quantization residue (same order as bf16 round-trip): widen the
        corruption-detection tolerance so lossy-but-honest slices are never
        flagged as corrupted."""
        e = self._slices.entry(rnd)
        if e is not None and e.lossy:
            return 3e-2
        return super()._decode_tol(rnd, slices)

    # -------------------------------------------------------------- misc
    def tier_of(self, rnd: int) -> Optional[str]:
        e = self._slices.entry(rnd)
        return e.tier if e is not None else None

    def demote_all(self, to: str = "cold") -> None:
        """Force every resident round down to ``to`` (test/benchmark helper:
        'serve this session entirely from warm+cold')."""
        if to not in TIER_ORDER:
            raise ValueError(f"unknown tier {to!r}")
        with self._lock:
            self.flush()
            depth = TIER_ORDER.index(to)
            for e in self._slices.entries().values():
                while TIER_ORDER.index(e.tier) < depth:
                    self._slices._demote(e)


@register_store("tiered")
def _make_tiered(shard_clients, *, num_shards: int, num_clients: int,
                 group_rounds: int = 1, slice_dtype=None,
                 use_kernel: bool = False,
                 hot_bytes: Optional[int] = None,
                 warm_bytes: Optional[int] = None,
                 eviction: str = "lru",
                 offload_dir: Optional[str] = None,
                 promote_on_read: bool = True, **_options) -> TieredStore:
    scheme = coding.CodingScheme(num_shards=num_shards,
                                 num_clients=num_clients)
    return TieredStore(scheme, shard_clients, group_rounds=group_rounds,
                       slice_dtype=slice_dtype, use_kernel=use_kernel,
                       budget=MemoryBudget(hot_bytes=hot_bytes,
                                           warm_bytes=warm_bytes),
                       eviction=eviction, offload_dir=offload_dir,
                       promote_on_read=promote_on_read)
