"""The ``TIERS`` registry: hot / warm / cold storage media for coded rounds.

Each tier knows how to *place* a round's payload into its medium, *read* it
back as the device-resident ``(C, P)`` slice tensor the decode path expects,
report its *resident bytes*, and *release* its payload.  The tier ladder is
strictly ordered hot → warm → cold (``TIER_ORDER``); demotion walks down one
rung at a time, promotion jumps straight back to hot.

* **hot**  — device-resident exact array (f32/bf16): today's ``CodedStore``
  behavior; reads are free.
* **warm** — host-RAM int8 symmetric per-slice quantization with stored
  scales (``repro.tiering.quant``); reads dequantize to device.  The first
  demotion into warm is the lossy event — from then on the entry's
  ``(q, scales)`` payload is canonical and every read reconstructs the same
  bits.
* **cold** — disk-offloaded ``[C·P int8 | C f32 scales]`` file, written once
  with the durability layer's atomic idiom (tmp + fsync + ``os.replace`` +
  dir fsync) and read back through ``np.memmap``; the file doubles as the
  snapshot's cold pointer, so resume never re-writes or re-quantizes.

A ``TierEntry`` is the per-round record the tiers operate on; it lives in
the ``TieredStore``'s tier table and carries the payload slots for every
medium plus the access stats the eviction policies consume.
"""
from __future__ import annotations

import os
import zlib
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.tiering.quant import dequantize_int8, quantize_int8


@dataclass
class TierEntry:
    """One stored round's tier state: payload slots + access accounting."""
    key: int                                  # round id
    shape: Tuple[int, int]                    # (C, P)
    dtype: object                             # hot-tier jnp dtype
    tier: str = "hot"                         # current residence
    device: Optional[jnp.ndarray] = None      # hot payload
    q: Optional[np.ndarray] = None            # warm payload (int8)
    scales: Optional[np.ndarray] = None       # canonical once lossy (f32 (C,))
    path: Optional[str] = None                # cold payload (file)
    file_crc: Optional[int] = None            # crc32 of the cold file bytes
    lossy: bool = False                       # passed through int8 at least once
    hits: int = 0
    last_access: int = 0
    stage: int = 0                            # birth order (stage-age eviction)

    def hot_nbytes(self) -> int:
        c, p = self.shape
        return c * p * jnp.dtype(self.dtype).itemsize

    def warm_nbytes(self) -> int:
        c, p = self.shape
        return c * p + c * 4                   # int8 payload + f32 scales

    def nbytes(self) -> int:
        """Bytes resident in the entry's *current* tier's medium."""
        return {"hot": self.hot_nbytes, "warm": self.warm_nbytes,
                "cold": self.warm_nbytes}[self.tier]()


TIERS: Dict[str, "Tier"] = {}
TIER_ORDER = ("hot", "warm", "cold")


def register_tier(name: str):
    def deco(cls):
        cls.name = name
        TIERS[name] = cls()
        return cls
    return deco


def next_tier(name: str) -> Optional[str]:
    i = TIER_ORDER.index(name)
    return TIER_ORDER[i + 1] if i + 1 < len(TIER_ORDER) else None


class Tier:
    """One rung of the ladder.  ``place`` moves an entry's payload into this
    medium (from the rung directly above, or from an exact array on first
    admit); ``read`` returns the device-resident slice tensor; ``release``
    drops this medium's payload."""

    name: str = ""

    def place(self, entry: TierEntry, **ctx) -> None:
        raise NotImplementedError

    def read(self, entry: TierEntry) -> jnp.ndarray:
        raise NotImplementedError

    def release(self, entry: TierEntry) -> None:
        raise NotImplementedError


@register_tier("hot")
class HotTier(Tier):
    def place(self, entry: TierEntry, array=None, **ctx) -> None:
        if array is not None:                    # fresh admit (put path)
            entry.device = array
        else:                                    # promotion: decode from below
            entry.device = TIERS[entry.tier].read(entry)
        entry.tier = "hot"

    def read(self, entry: TierEntry) -> jnp.ndarray:
        return entry.device

    def release(self, entry: TierEntry) -> None:
        entry.device = None


@register_tier("warm")
class WarmTier(Tier):
    def place(self, entry: TierEntry, **ctx) -> None:
        if entry.q is None:
            if entry.path is not None:
                # the cold file is canonical: reload rather than requantize
                entry.q, entry.scales = _read_cold_file(entry)
            else:
                # passing stored scales keeps requantization bit-exact for
                # already-lossy entries (see quant module docstring)
                entry.q, entry.scales = quantize_int8(entry.device,
                                                      scales=entry.scales)
        entry.lossy = True
        TIERS["hot"].release(entry)
        entry.tier = "warm"

    def read(self, entry: TierEntry) -> jnp.ndarray:
        return dequantize_int8(entry.q, entry.scales, dtype=entry.dtype)

    def release(self, entry: TierEntry) -> None:
        entry.q = None                 # scales stay: canonical once lossy


@register_tier("cold")
class ColdTier(Tier):
    def place(self, entry: TierEntry, cold_dir: str = None, **ctx) -> None:
        if entry.path is None:
            if cold_dir is None:
                raise ValueError("cold tier needs an offload directory")
            entry.path = os.path.join(cold_dir, f"round{entry.key}.tier")
            entry.file_crc = _write_cold_file(entry.path, entry.q,
                                              entry.scales)
        TIERS["warm"].release(entry)
        entry.tier = "cold"

    def read(self, entry: TierEntry) -> jnp.ndarray:
        q, scales = _read_cold_file(entry)
        return dequantize_int8(q, scales, dtype=entry.dtype)

    def release(self, entry: TierEntry) -> None:
        pass                           # the file outlives residence: it is
                                       # the canonical lossy payload


# ---------------------------------------------------------------------------
# Cold-file I/O — [C*P int8 | C f32 scales], atomic-rename committed
# ---------------------------------------------------------------------------

def _write_cold_file(path: str, q: np.ndarray, scales: np.ndarray) -> int:
    """Commit ``[q | scales]`` with the durability layer's atomic idiom so a
    crash mid-offload can only leave a tmp file, never a torn cold round.
    Returns the crc32 of the committed bytes (the snapshot manifest's
    integrity pointer)."""
    buf = q.tobytes() + np.asarray(scales, np.float32).tobytes()
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        f.write(buf)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)
    dfd = os.open(os.path.dirname(os.path.abspath(path)), os.O_RDONLY)
    try:
        os.fsync(dfd)
    finally:
        os.close(dfd)
    return zlib.crc32(buf)


def _read_cold_file(entry: TierEntry) -> Tuple[np.ndarray, np.ndarray]:
    """mmap-backed read: the int8 payload maps lazily (the dequant multiply
    is the only full materialization); scales read from the tail."""
    c, p = entry.shape
    q = np.memmap(entry.path, dtype=np.int8, mode="r", shape=(c, p))
    with open(entry.path, "rb") as f:
        f.seek(c * p)
        scales = np.frombuffer(f.read(c * 4), dtype=np.float32)
    if scales.shape != (c,):
        raise IOError(f"cold file {entry.path} truncated: "
                      f"expected {c} scales, got {scales.shape}")
    return q, scales


def cold_file_crc(path: str) -> int:
    with open(path, "rb") as f:
        return zlib.crc32(f.read())
