"""Forgetting verification — did the unlearning actually unlearn?

The subsystem answers with three registered probes scored against the exact
ground truth:

* ``oracle``      — per-shard retrain-from-scratch on retained data (exact
                    unlearning; registered as a framework so every driver
                    can dispatch it);
* ``shadow-mia``  — N shadow federations calibrate a membership attack with
                    no access to the victim's labels; attack F1 on the
                    forgotten client's data is the reported metric;
* ``canary``      — seeded memorization-only examples planted into the
                    victim clients; forgetting = accuracy collapse to chance;
* ``utility``     — retained/test accuracy, the axis forgetting must not buy
                    itself with.

``run_verification`` drives one victim scenario through all of it and emits
a forgetting × utility × cost Pareto ``VerifyReport`` per framework.
"""
from repro.verify.canary import CanaryVerifier, plant_canaries
from repro.verify.oracle import RetrainOracle
from repro.verify.registry import (VERIFIERS, ForgettingVerifier,
                                   get_verifier, register_verifier,
                                   resolve_verifiers)
from repro.verify.report import CandidateScore, VerifyReport
from repro.verify.shadow import (ShadowAttack, ShadowMIAVerifier,
                                 train_shadow_attack)
from repro.verify.suite import (UtilityVerifier, VerificationSuite,
                                predict_stage_victim, run_verification)

__all__ = [
    "VERIFIERS", "ForgettingVerifier", "register_verifier", "get_verifier",
    "resolve_verifiers", "RetrainOracle", "ShadowAttack",
    "train_shadow_attack", "ShadowMIAVerifier", "CanaryVerifier",
    "plant_canaries", "UtilityVerifier", "VerificationSuite",
    "predict_stage_victim", "run_verification", "VerifyReport",
    "CandidateScore",
]
