"""Canary injection — forgetting measured as memorization collapse.

Seeded memorization-only examples (canaries) are planted into each victim
client's training data BEFORE the stage trains: inputs off the task's data
manifold mapped to random targets, so no model can score above the chance
rate on them without having memorized the victim's data.  Construction is
task-owned (``TaskSpec.make_canaries``): high-contrast binary noise images
with random labels for classification, random token→token mappings for
generation — the probe works for every registered task × model family.

After unlearning, canary accuracy is the forgetting verdict:

* no-unlearn model      — memorized, accuracy ≫ chance;
* retrain oracle        — never saw them, accuracy ≈ chance;
* a correct framework   — indistinguishable from the oracle.

This is the backdoor-style check of the federated-unlearning literature
(Halimi et al., arXiv 2207.05521 §5: a backdoor that survives unlearning is
data that survived unlearning).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import numpy as np

from repro.verify.registry import ForgettingVerifier, register_verifier


def plant_canaries(client_data: Dict[int, Tuple[np.ndarray, np.ndarray]],
                   victims, task_spec, model_cfg, n: int, seed: int):
    """Replace the first ``n`` examples of every victim client with seeded
    canaries (in place).  Replacement — not appending — keeps every client's
    example count unchanged, so stage stacking and shard geometry are
    untouched.  Returns ``(cx, cy, chance)``: all planted canaries
    concatenated, plus the task's chance rate."""
    if n < 1:
        raise ValueError(f"need at least 1 canary per victim, got {n}")
    all_x, all_y, chance = [], [], None
    for v in victims:
        x, y = client_data[v]
        k = min(n, len(x))
        cx, cy, chance = task_spec.make_canaries(model_cfg, x, y, k,
                                                 seed=seed * 9176 + int(v))
        x, y = np.array(x), np.array(y)
        x[:k], y[:k] = cx, cy
        client_data[v] = (x, y)
        all_x.append(cx)
        all_y.append(cy)
    return np.concatenate(all_x), np.concatenate(all_y), chance


@register_verifier("canary")
class CanaryVerifier(ForgettingVerifier):
    """Pareto axis: canary accuracy (down toward the chance rate = data
    actually forgotten).  ``plant`` injects at partition time — the hook runs
    before the victim stage trains — and ``score`` evaluates each candidate
    model set on the planted canaries through the standard task metrics."""

    def __init__(self, n_canaries: Optional[int] = None):
        self.n_canaries = n_canaries       # None -> the suite's default
        self.cx = self.cy = None
        self.chance: float = 0.0

    def plant(self, suite) -> None:
        n = self.n_canaries or suite.n_canaries
        self.cx, self.cy, self.chance = plant_canaries(
            suite.sim.client_data, suite.victims, suite.sim.task_spec,
            suite.sim.cfg, n, seed=suite.seed)

    def score(self, suite, models: Dict[int, object]) -> Dict[str, float]:
        if self.cx is None:
            raise RuntimeError("CanaryVerifier.score before plant: the "
                               "canaries were never injected")
        m = suite.eval_models(models, self.cx, self.cy)
        return {"canary_acc": m["acc"], "canary_chance": self.chance}
