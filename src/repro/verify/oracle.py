"""Retrain-from-scratch oracle — the ground truth that defines EXACT
unlearning (Halimi et al., arXiv 2207.05521): the model the federation would
have produced had the requested clients never participated.

Under the paper's isolation a shard's model is a pure function of its own
clients' data, so the exact counterfactual is computable per shard: restart
from the stage's actual initial model (same ``plan.stage``-derived seed), run
the stage's G rounds at the FULL L local epochs, with the requested clients'
data simply absent.  The pass reuses the stage engine's fused ``shard_round``
body — impacted shards with matching geometry are vmapped together and the
rounds scanned, one XLA dispatch for the whole oracle
(``FLSimulator._get_retrain_program``).

Registered as an unlearning framework (``"oracle"``), so every driver —
``run_unlearn``, ``FederatedSession``, the online service — can dispatch to
it by name, and the verification suite scores approximate frameworks
(SE/FE/RR) against it with the same ``UnlearnResult`` wall/cost accounting.
It is NOT a practical serving framework: its cost is the full retraining
bill the paper's SE exists to avoid.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.fl.experiment.frameworks import (UnlearnContext, UnlearnFramework,
                                            register_framework)


@register_framework("oracle", "retrain-oracle")
class RetrainOracle(UnlearnFramework):
    """Exact per-shard retraining on retained data only — the reference
    every approximate framework's forgetting is measured against."""

    shard_level = True
    exact = True     # marks the ground-truth framework for reports/tests

    def run(self, ctx: UnlearnContext):
        models = dict(ctx.record.shard_models)
        w0 = ctx.stage_init_model()
        jobs = []
        for s in ctx.impacted:
            retained = ctx.retained(s)
            # the stage's ACTUAL round count, not the request's G' budget:
            # the oracle replays history, it doesn't serve a reduced retrain
            g = len(ctx.record.round_globals[s]) - 1
            if not retained:
                # every client of the shard was erased: the counterfactual
                # shard never trained, its model is the from-scratch init
                models[s] = w0
                continue
            xs, ys = ctx.stack_client_data(retained)
            jobs.append((s, retained, xs, ys, g))

        cost = 0.0
        groups: dict = {}
        for job in jobs:
            groups.setdefault((job[2].shape, job[4]), []).append(job)
        for (_shape, g), group in groups.items():
            xs = jnp.stack([j[2] for j in group])      # (K, M', n, ...)
            ys = jnp.stack([j[3] for j in group])
            final = ctx.retrain_shards(w0, xs, ys, g)
            for i, (s, retained, *_rest) in enumerate(group):
                models[s] = jax.tree.map(lambda a, i=i: a[i], final)
                cost += g * len(retained) * ctx.fl.local_epochs
        return models, cost

    @classmethod
    def impacted_shards(cls, plan, clients):
        hit = set(clients)
        return sorted(s for s, cs in plan.shard_clients.items()
                      if hit & set(cs))
