"""Verifier registry — forgetting probes behind the same registry idiom as
``STORES`` / ``FRAMEWORKS`` / ``TASKS`` / ``FAMILIES`` / ``PARTITIONERS``.

A ``ForgettingVerifier`` measures ONE axis of the forgetting-vs-utility
Pareto report for every candidate model set (no-unlearn baseline, each
unlearning framework, the retrain oracle).  It gets three hooks around the
victim scenario's lifecycle:

* ``plant(suite)``   — before training: mutate the victim clients' data
                       (canary injection) or precompute nothing.
* ``prepare(suite)`` — after the victim stage trained: build whatever the
                       scoring needs once (train the shadow-model attack,
                       stack the retained-client eval split).
* ``score(suite, models)`` — evaluate one candidate model set, returning a
                       flat ``{metric: value}`` dict merged into that
                       candidate's ``CandidateScore``.

Registered probes: ``shadow-mia`` (attack F1), ``canary`` (memorization
collapse), ``utility`` (retained/test accuracy — forgetting that destroys
retained-client utility is damage, not unlearning).  A third-party probe is
one subclass + ``@register_verifier`` away from appearing in every
``BENCH_verify.json``.
"""
from __future__ import annotations

from typing import Dict, Iterable, List, Type


class ForgettingVerifier:
    """Base class for forgetting probes.  Subclass, implement ``score`` (and
    optionally ``plant``/``prepare``), register with
    ``@register_verifier(name, *aliases)``."""

    name: str = ""

    # ------------------------------------------------------------ lifecycle
    def plant(self, suite) -> None:
        """Pre-training hook: may mutate ``suite.sim.client_data`` for the
        suite's victim clients (e.g. canary injection).  Default: no-op."""

    def prepare(self, suite) -> None:
        """Post-training hook: one-time setup against the trained victim
        stage (``suite.record``) before candidates are scored."""

    def score(self, suite, models: Dict[int, object]) -> Dict[str, float]:
        """Score one candidate model set (a shard-model dict, or ``{0: w}``
        for federation-level frameworks).  Returns ``{metric: value}``."""
        raise NotImplementedError


VERIFIERS: Dict[str, Type[ForgettingVerifier]] = {}


def register_verifier(*names: str):
    """Class decorator registering a ``ForgettingVerifier`` under ``names``
    (the first is canonical)."""
    if not names:
        raise ValueError("register_verifier needs at least one name")

    def deco(cls: Type[ForgettingVerifier]) -> Type[ForgettingVerifier]:
        cls.name = names[0]
        for n in names:
            VERIFIERS[n] = cls
        return cls
    return deco


def get_verifier(name: str, **kwargs) -> ForgettingVerifier:
    """Resolve a registered verifier, with constructor ``kwargs`` applied."""
    try:
        cls = VERIFIERS[name]
    except KeyError:
        raise ValueError(f"unknown verifier {name!r}; registered: "
                         f"{sorted(VERIFIERS)}") from None
    return cls(**kwargs)


def resolve_verifiers(specs: Iterable) -> List[ForgettingVerifier]:
    """Accept registered names, ``ForgettingVerifier`` classes, or instances
    (mixed freely) and return instances."""
    out: List[ForgettingVerifier] = []
    for spec in specs:
        if isinstance(spec, ForgettingVerifier):
            out.append(spec)
        elif isinstance(spec, type) and issubclass(spec, ForgettingVerifier):
            out.append(spec())
        else:
            out.append(get_verifier(spec))
    return out
