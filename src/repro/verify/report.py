"""Pareto report — forgetting × utility × cost, per unlearning framework.

One ``CandidateScore`` per candidate model set (the ``"none"`` no-unlearn
baseline, each framework SE/FE/FR/RR, the ``"oracle"`` ground truth), each
carrying the merged metrics of every verifier that scored it plus the
serve's wall time and retraining cost.  ``VerifyReport`` aggregates them:
per-candidate gap-to-oracle, the non-dominated Pareto front over
(forgetting ↓, utility ↑, cost ↓), and JSON export through the benchmark
``--json-dir`` flow (``BENCH_verify.json``).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

# default Pareto axes: (metric, maximize?) — forgetting metrics fall, utility
# rises, retraining cost falls.  Candidates missing an axis (e.g. no canary
# verifier ran) are compared on the axes they have.
DEFAULT_AXES: Tuple[Tuple[str, bool], ...] = (
    ("mia_f1", False), ("canary_acc", False),
    ("retain_acc", True), ("cost_units", False),
)


@dataclass
class CandidateScore:
    """One candidate model set's verification scores."""
    name: str                         # "none", "SE", ..., "oracle"
    framework: Optional[str]          # FRAMEWORKS key (None for "none")
    wall_s: float
    cost_units: float
    metrics: Dict[str, float] = field(default_factory=dict)

    def axis(self, name: str) -> Optional[float]:
        """A metric by name, with the cost/wall accounting addressable as
        pseudo-metrics (the Pareto cost axis)."""
        if name == "wall_s":
            return self.wall_s
        if name == "cost_units":
            return self.cost_units
        return self.metrics.get(name)

    def to_dict(self) -> dict:
        return {"name": self.name, "framework": self.framework,
                "wall_s": self.wall_s, "cost_units": self.cost_units,
                "metrics": dict(self.metrics)}


@dataclass
class VerifyReport:
    """The forgetting-verification report for one victim scenario."""
    task: str
    store: str
    seed: int
    victims: List[int]
    n_shadows: int
    n_canaries: int
    verifiers: List[str]
    candidates: List[CandidateScore] = field(default_factory=list)
    oracle_name: str = "oracle"
    baseline_name: str = "none"

    # -------------------------------------------------------------- accessors
    def candidate(self, name: str) -> CandidateScore:
        for c in self.candidates:
            if c.name == name:
                return c
        raise KeyError(f"no candidate {name!r}; scored: "
                       f"{[c.name for c in self.candidates]}")

    @property
    def oracle(self) -> CandidateScore:
        return self.candidate(self.oracle_name)

    def gap(self, name: str, metric: str) -> float:
        """|candidate − oracle| on one metric: the forgetting gap the
        acceptance tests bound (≈0 for a correct framework)."""
        return abs(self.candidate(name).metrics[metric]
                   - self.oracle.metrics[metric])

    def gaps(self, name: str) -> Dict[str, float]:
        oracle = self.oracle.metrics
        return {m: abs(v - oracle[m])
                for m, v in self.candidate(name).metrics.items()
                if m in oracle}

    # ----------------------------------------------------------------- pareto
    def pareto_front(self, axes: Sequence[Tuple[str, bool]] = DEFAULT_AXES
                     ) -> List[str]:
        """Names of the non-dominated candidates over ``axes`` (each a
        ``(metric, maximize?)`` pair), in report order.  A dominates B when
        A is at least as good on every shared axis and strictly better on
        one."""
        def dominates(a: CandidateScore, b: CandidateScore) -> bool:
            strictly = False
            shared = 0
            for m, maximize in axes:
                va, vb = a.axis(m), b.axis(m)
                if va is None or vb is None:
                    continue
                shared += 1
                if not maximize:
                    va, vb = -va, -vb
                if va < vb:
                    return False
                if va > vb:
                    strictly = True
            return strictly and shared > 0

        return [c.name for c in self.candidates
                if not any(dominates(o, c) for o in self.candidates
                           if o is not c)]

    # ------------------------------------------------------------------ export
    def metrics_dict(self) -> Dict[str, Dict[str, float]]:
        """The deterministic slice of the report — per-candidate metrics and
        cost units, NO wall times — for bit-reproducibility assertions
        (identical configs + seeds must produce identical dicts)."""
        return {c.name: dict(c.metrics, cost_units=c.cost_units)
                for c in self.candidates}

    def to_dict(self) -> dict:
        oracle_known = any(c.name == self.oracle_name for c in self.candidates)
        return {
            "task": self.task,
            "store": self.store,
            "seed": self.seed,
            "victims": [int(v) for v in self.victims],
            "n_shadows": self.n_shadows,
            "n_canaries": self.n_canaries,
            "verifiers": list(self.verifiers),
            "oracle": self.oracle_name if oracle_known else None,
            "pareto_front": self.pareto_front(),
            "candidates": [c.to_dict() for c in self.candidates],
            "gaps_to_oracle": ({c.name: self.gaps(c.name)
                                for c in self.candidates
                                if c.name != self.oracle_name}
                               if oracle_known else {}),
        }

    def to_json(self, **kw) -> str:
        kw.setdefault("indent", 2)
        return json.dumps(self.to_dict(), **kw)
