"""Shadow-model membership inference — the attack calibrated WITHOUT access
to the victim's membership ground truth ([Shokri et al. 2017]; the protocol
Halimi et al., arXiv 2207.05521 use to audit federated unlearning).

The threshold attack in ``repro.fl.mia`` fits its classifier on the victim
model's own member/non-member features — fine as a unit-level separability
probe, but it hands the attacker labels no real attacker has.  The shadow
attack trains N *shadow federations* (same ``ScenarioConfig``, different
seeds → disjoint synthetic draws of the same distribution, fresh inits),
where the attacker KNOWS which examples were members, fits the logistic
attack on the pooled shadow features, and only then scores the victim's
models.  Evaluating that fixed attack on the forgotten client's data for the
unlearned / oracle / no-unlearn models is the reported forgetting metric:
an exactly-unlearned model scores the no-information F1 (~0.5 under the
balanced decision rule), a model that still remembers scores higher.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from repro.fl import mia
from repro.fl.experiment.scenario import build_simulator
from repro.fl.experiment.stage import train_stage
from repro.verify.registry import ForgettingVerifier, register_verifier


@dataclass
class ShadowAttack:
    """A fitted membership attack: logistic model + balanced threshold,
    calibrated purely on shadow-federation features."""

    model: tuple                  # (w, b, mu, sd) from mia._logreg_fit
    threshold: float              # balanced decision threshold (shadow median)
    n_shadows: int
    train_acc: float              # member/non-member acc on shadow features

    # ---------------------------------------------------------------- scoring
    def member_flags(self, iface, models: Dict[int, object],
                     xs, ys) -> np.ndarray:
        """Attack decisions (1 = 'member') on ``(xs, ys)`` under ``models``,
        features extracted through the public ``PredictInterface``."""
        fx = mia._features(iface.predict, models, iface.make_batch,
                           xs, ys, iface.task)
        return mia._logreg_predict(self.model, fx, self.threshold)

    def f1(self, iface, models: Dict[int, object], forgotten_data,
           nonmember_data) -> float:
        """F1 of the attack claiming 'member' on the forgotten data (false
        positives from an equal-sized true non-member split).  Lower =
        better forgotten; the retrain oracle scores ~the no-information
        rate."""
        flags_f = self.member_flags(iface, models, *forgotten_data)
        flags_n = self.member_flags(iface, models, *nonmember_data)
        n_eval = min(len(flags_f), len(flags_n))
        return mia.attack_f1(flags_f[:n_eval], flags_n[:n_eval])


def train_shadow_attack(cfg, n_shadows: int = 3,
                        rounds: Optional[int] = None,
                        seed: Optional[int] = None) -> ShadowAttack:
    """Train N seeded shadow federations and fit the attack on their pooled
    member/non-member features.

    Each shadow re-runs ``cfg`` at ``seed + 7919*(i+1)`` — a fresh draw of
    the same data distribution, partitioner, model family, and training
    protocol — trains one stage, and contributes a balanced feature batch
    (stage members vs its held-out test split).  ``rounds`` optionally
    shortens the shadows' stage (the attack transfers as long as shadows and
    victim overfit comparably; default = the victim's round count).
    Deterministic in (cfg, n_shadows, rounds, seed).
    """
    if n_shadows < 1:
        raise ValueError(f"need at least 1 shadow model, got {n_shadows}")
    base_seed = cfg.seed if seed is None else seed
    feats, labels = [], []
    for i in range(n_shadows):
        scfg = dataclasses.replace(cfg, seed=base_seed + 7919 * (i + 1),
                                   schedule=None, num_stages=1)
        sim, test = build_simulator(scfg)
        record = train_stage(sim, store_kind=scfg.store, rounds=rounds,
                             engine=scfg.engine)
        iface = sim.predict_interface()
        mx = np.concatenate([sim.client_data[c][0]
                             for c in record.plan.clients])
        my = np.concatenate([sim.client_data[c][1]
                             for c in record.plan.clients])
        fm = mia._features(iface.predict, record.shard_models,
                           iface.make_batch, mx, my, iface.task)
        fn = mia._features(iface.predict, record.shard_models,
                           iface.make_batch, *test, iface.task)
        # balanced member/non-member batch, deterministic member subsample
        k = min(len(fm), len(fn))
        idx = np.random.default_rng(scfg.seed).choice(len(fm), k,
                                                      replace=False)
        feats.extend([fm[idx], fn[:k]])
        labels.extend([np.ones(k), np.zeros(k)])
    x = np.concatenate(feats)
    y = np.concatenate(labels)
    model = mia._logreg_fit(x, y)
    threshold = float(np.median(mia._logreg_score(model, x)))
    pred = mia._logreg_predict(model, x, threshold)
    return ShadowAttack(model, threshold, n_shadows,
                        train_acc=float((pred == y).mean()))


@register_verifier("shadow-mia")
class ShadowMIAVerifier(ForgettingVerifier):
    """Pareto axis: shadow-attack F1 on the forgotten client's data (down =
    better forgotten).  Trains the attack once per suite (``prepare``) and
    scores every candidate with the same fixed attack."""

    def __init__(self, attack: Optional[ShadowAttack] = None):
        self.attack = attack          # pre-fitted attack skips the shadows

    def prepare(self, suite) -> None:
        if self.attack is None:
            self.attack = train_shadow_attack(suite.cfg,
                                              n_shadows=suite.n_shadows,
                                              rounds=suite.shadow_rounds)

    def score(self, suite, models: Dict[int, object]) -> Dict[str, float]:
        f1 = self.attack.f1(suite.iface, models, suite.forgotten_data,
                            suite.nonmember_data)
        return {"mia_f1": f1}
