"""The verification suite — one victim scenario, every framework, every probe.

``run_verification`` is the subsystem's entry point: it builds the victim
federation from a ``ScenarioConfig``, lets the verifiers plant (canaries go
into the victim clients BEFORE the stage trains), trains the stage, prepares
the probes (the shadow attack fits here), then scores every candidate model
set — the untouched no-unlearn record, each requested framework's unlearned
models, and the retrain oracle — producing the forgetting × utility × cost
``VerifyReport`` the benchmarks emit as ``BENCH_verify.json``.

Victim choice is deterministic: ``ShardManager`` sampling depends only on
``(num_clients, num_shards, clients_per_round, seed)``, so
``predict_stage_victim`` replays the stage-0 plan before any training and
canaries can be planted for a client that is guaranteed to participate.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.sharding import ShardManager
from repro.fl.experiment.frameworks import run_unlearn
from repro.fl.experiment.scenario import ScenarioConfig, build_simulator
from repro.fl.experiment.session import FederatedSession
from repro.verify.registry import (ForgettingVerifier, register_verifier,
                                   resolve_verifiers)
from repro.verify.report import CandidateScore, VerifyReport

DEFAULT_FRAMEWORKS = ("SE", "FE", "FR", "RR")
DEFAULT_VERIFIERS = ("shadow-mia", "canary", "utility")


class VerificationSuite:
    """Shared state the verifiers hook into: the victim scenario's config,
    simulator, trained record, victim client ids, and the evaluation
    surfaces (``predict_interface``, forgotten/retained/non-member splits)."""

    def __init__(self, cfg: ScenarioConfig, sim, test, victims: Sequence[int],
                 n_shadows: int = 3, n_canaries: int = 8,
                 shadow_rounds: Optional[int] = None):
        self.cfg = cfg
        self.sim = sim
        self.test = test
        self.victims = [int(v) for v in victims]
        self.seed = cfg.seed
        self.n_shadows = n_shadows
        self.n_canaries = n_canaries
        self.shadow_rounds = shadow_rounds
        self.iface = sim.predict_interface()
        self.record = None                      # set once the stage trained
        self.session: Optional[FederatedSession] = None

    # ------------------------------------------------------------ data splits
    @property
    def forgotten_data(self) -> Tuple[np.ndarray, np.ndarray]:
        """The victim clients' training data as it entered the stage (post
        planting) — what the attack probes for residual membership."""
        xs = np.concatenate([self.sim.client_data[v][0] for v in self.victims])
        ys = np.concatenate([self.sim.client_data[v][1] for v in self.victims])
        return xs, ys

    @property
    def nonmember_data(self) -> Tuple[np.ndarray, np.ndarray]:
        """True non-members: the held-out test split."""
        return self.test

    def retained_data(self, cap_per_client: int = 40
                      ) -> Tuple[np.ndarray, np.ndarray]:
        """Training data of the stage's NON-victim participants (capped per
        client) — the utility the unlearning must not destroy."""
        if self.record is None:
            raise RuntimeError("retained_data before the stage trained")
        keep = [c for c in self.record.plan.clients if c not in self.victims]
        xs = np.concatenate([self.sim.client_data[c][0][:cap_per_client]
                             for c in keep])
        ys = np.concatenate([self.sim.client_data[c][1][:cap_per_client]
                             for c in keep])
        return xs, ys

    # ------------------------------------------------------------- evaluation
    def eval_models(self, models: Dict[int, object], xs, ys) -> Dict[str, float]:
        """Task metrics of the shard-ensemble on ``(xs, ys)``."""
        return self.sim.evaluate(models, xs, ys)


@register_verifier("utility")
class UtilityVerifier(ForgettingVerifier):
    """Pareto axis: retained-client + test utility (up = unlearning that did
    not damage what it was supposed to keep).  Task-aware: perplexity rides
    along for generation tasks."""

    def __init__(self, cap_per_client: int = 40):
        self.cap_per_client = cap_per_client
        self._retain = None

    def prepare(self, suite) -> None:
        self._retain = suite.retained_data(self.cap_per_client)

    def score(self, suite, models: Dict[int, object]) -> Dict[str, float]:
        r = suite.eval_models(models, *self._retain)
        t = suite.eval_models(models, *suite.test)
        out = {"retain_acc": r["acc"], "retain_loss": r["loss"],
               "test_acc": t["acc"], "test_loss": t["loss"]}
        if "ppl" in r:
            out["retain_ppl"] = r["ppl"]
            out["test_ppl"] = t["ppl"]
        return out


def predict_stage_victim(cfg: ScenarioConfig) -> int:
    """The id of a client guaranteed to participate in stage 0 — replayed
    from a throwaway ``ShardManager`` with the scenario's seed (sampling is
    deterministic, so the real stage produces the identical plan)."""
    mgr = ShardManager(cfg.num_clients, cfg.num_shards,
                       cfg.clients_per_round, cfg.seed)
    plan = mgr.new_stage()
    s = min(plan.shard_clients)
    return int(sorted(plan.shard_clients[s])[0])


def run_verification(cfg: ScenarioConfig,
                     frameworks: Sequence[str] = DEFAULT_FRAMEWORKS,
                     verifiers: Sequence = DEFAULT_VERIFIERS,
                     victims: Optional[Sequence[int]] = None,
                     n_shadows: int = 3, n_canaries: int = 8,
                     shadow_rounds: Optional[int] = None,
                     include_oracle: bool = True,
                     include_baseline: bool = True) -> VerifyReport:
    """Run the full forgetting-verification protocol for one scenario.

    Returns a ``VerifyReport`` whose candidates are ``"none"`` (the trained
    stage untouched, when ``include_baseline``), each framework in
    ``frameworks``, and ``"oracle"`` (exact retrain, when
    ``include_oracle``) — each scored by every verifier.
    """
    probes = resolve_verifiers(verifiers)
    if victims is None:
        victims = [predict_stage_victim(cfg)]
    victims = [int(v) for v in victims]

    sim, test = build_simulator(cfg)
    suite = VerificationSuite(cfg, sim, test, victims, n_shadows=n_shadows,
                              n_canaries=n_canaries,
                              shadow_rounds=shadow_rounds)

    # plant BEFORE training — canaries must be in the victims' data when the
    # stage stacks it
    for probe in probes:
        probe.plant(suite)

    session = FederatedSession(sim, store_kind=cfg.store, engine=cfg.engine,
                               encode_group=cfg.encode_group,
                               slice_dtype=cfg.slice_dtype)
    record = session.run_stage()
    suite.record = record
    suite.session = session

    missing = [v for v in victims if v not in record.plan.clients]
    if missing:
        raise ValueError(f"victims {missing} did not participate in the "
                         f"trained stage (clients: {record.plan.clients}); "
                         "pick victims via predict_stage_victim(cfg)")

    for probe in probes:
        probe.prepare(suite)

    def scored(name: str, framework: Optional[str], models,
               wall_s: float, cost_units: float) -> CandidateScore:
        cand = CandidateScore(name=name, framework=framework, wall_s=wall_s,
                              cost_units=cost_units)
        for probe in probes:
            cand.metrics.update(probe.score(suite, models))
        return cand

    candidates: List[CandidateScore] = []
    if include_baseline:
        candidates.append(scored("none", None, record.shard_models, 0.0, 0.0))
    for fw in frameworks:
        res = run_unlearn(sim, fw, record, victims)
        candidates.append(scored(fw, fw, res.models, res.wall_time,
                                 res.cost_units))
    if include_oracle:
        res = run_unlearn(sim, "oracle", record, victims)
        candidates.append(scored("oracle", "oracle", res.models,
                                 res.wall_time, res.cost_units))

    return VerifyReport(
        task=cfg.task, store=cfg.store, seed=cfg.seed, victims=victims,
        n_shadows=n_shadows, n_canaries=n_canaries,
        verifiers=[p.name or type(p).__name__ for p in probes],
        candidates=candidates)
