"""Child process for the durability kill/resume acceptance test.

Three modes (``argv[1]``, with ``argv[2]`` = checkpoint directory):

- ``baseline``: run the 3-stage session uninterrupted (no checkpointing)
  and print its content signature.
- ``crash``: run the same session with checkpointing and a ``process_kill``
  injector in ``mode="exit"`` — the process dies via ``os._exit(137)``
  mid-session (stage 1 served, snapshot not yet written), leaving only the
  snapshots and journal behind.
- ``resume``: build a fresh identically-configured session, resume from the
  checkpoint directory the dead process left, finish the run, and print
  its signature plus resume accounting.

The signature hashes every shard model, every coded slice, every unlearn
result model, and the report JSON with wall-time fields zeroed — the
parent test asserts crash+resume is bit-identical to the baseline.
"""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses  # noqa: E402
import hashlib  # noqa: E402
import json  # noqa: E402
import sys  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import FLConfig, OptimizerConfig, get_config  # noqa: E402
from repro.faults import FaultPlan  # noqa: E402
from repro.data import client_datasets_images, make_image_data  # noqa: E402
from repro.fl import FLSimulator  # noqa: E402
from repro.fl.experiment import (FederatedSession,  # noqa: E402
                                 RequestSchedule, UnlearnRequest)

FL = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
              local_epochs=2, global_rounds=2, retrain_ratio=2.0)
NUM_STAGES = 3
WALL_FIELDS = ("train_wall_s", "wall_time_s", "total_train_wall_s",
               "total_unlearn_wall_s")


def _zero_walls(node):
    if isinstance(node, dict):
        return {k: (0.0 if k in WALL_FIELDS else _zero_walls(v))
                for k, v in node.items()}
    if isinstance(node, list):
        return [_zero_walls(x) for x in node]
    return node


def _hash_tree(h, tree):
    for leaf in jax.tree.leaves(tree):
        a = np.ascontiguousarray(np.asarray(leaf))
        h.update(str((a.dtype.name, a.shape)).encode())
        h.update(a.tobytes())


def session_signature(session) -> str:
    """Content hash of everything the durability contract promises: shard
    models, coded slices, unlearn-result models, and the (wall-free)
    accounting report."""
    h = hashlib.sha256()
    for rec in session.records:
        for s in sorted(rec.shard_models):
            _hash_tree(h, rec.shard_models[s])
        store = rec.store
        if hasattr(store, "flush"):
            store.flush()
        for key in sorted(getattr(store, "_slices", {}), key=repr):
            _hash_tree(h, store._slices[key])
    for st in session.report.stages:
        for u in st.unlearn:
            h.update(u.request_id.encode())
            for s in sorted(u.models):
                _hash_tree(h, u.models[s])
    h.update(json.dumps(_zero_walls(session.report.to_dict()),
                        sort_keys=True).encode())
    return h.hexdigest()


def make_schedule() -> RequestSchedule:
    # callable clients: resolved against the trained plan when served, so
    # every run (baseline / crashed / resumed) targets the same victims
    return RequestSchedule([
        UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                       after_stage=0, rounds=1),
        UnlearnRequest(lambda p: [p.shard_clients[1][0]], framework="SE",
                       after_stage=1, rounds=1),
        UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                       after_stage=2, rounds=1),
    ])


def build_session(ckpt_dir=None, faults=None) -> FederatedSession:
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL.num_clients, iid=True)
    sim = FLSimulator(cfg, FL, clients, task="image",
                      opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                              grad_clip=0.0),
                      local_batch=10, seed=0)
    return FederatedSession(sim, store_kind="coded", faults=faults,
                            checkpoint_every=1 if ckpt_dir else 0,
                            checkpoint_dir=ckpt_dir)


def main():
    mode, ckpt_dir = sys.argv[1], sys.argv[2]
    if mode == "baseline":
        session = build_session()
        session.run(NUM_STAGES, schedule=make_schedule())
        print(json.dumps({"sig": session_signature(session)}))
    elif mode == "crash":
        plan = FaultPlan(seed=7).add("process_kill", stage=1,
                                     phase="after_requests", mode="exit",
                                     exit_code=137)
        session = build_session(ckpt_dir, faults=plan)
        session.run(NUM_STAGES, schedule=make_schedule())
        print(json.dumps({"error": "process_kill never fired"}))
        sys.exit(3)
    elif mode == "resume":
        session = build_session(ckpt_dir)
        session.run(NUM_STAGES, schedule=make_schedule(),
                    resume_from=ckpt_dir)
        info = session.last_resume_info
        pairs = [(i, u.request_id)
                 for i, st in enumerate(session.report.stages)
                 for u in st.unlearn]
        print(json.dumps({"sig": session_signature(session),
                          "start_stage": info["start_stage"],
                          "resumed_step": info["step"],
                          "inflight": info["inflight"],
                          "request_ids": sorted({r for _, r in pairs}),
                          "once_per_stage": len(pairs) == len(set(pairs))}))
    else:
        raise SystemExit(f"unknown mode {mode!r}")


if __name__ == "__main__":
    main()
