"""Child process for the device-failure chaos test: forced to 4 virtual CPU
devices via XLA_FLAGS (must be set before jax import — hence the subprocess),
it trains one tiny stage, serves a 4-victim trace fault-free, then serves the
same trace twice under a plan that kills device 0 — every request must still
complete (re-dispatched to healthy devices) with models bit-identical to the
fault-free serve and an identical replayed fault ledger.  Prints one JSON
line the parent test asserts on."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import FLConfig, OptimizerConfig, get_config  # noqa: E402
from repro.core.sharding import even_requests  # noqa: E402
from repro.data import client_datasets_images, make_image_data  # noqa: E402
from repro.faults import FaultPlan  # noqa: E402
from repro.fl import FLSimulator  # noqa: E402
from repro.fl.experiment import FederatedSession  # noqa: E402
from repro.service import (DevicePlacement, RetryPolicy,  # noqa: E402
                           UnlearningService, sequenced_trace)

FAULT_SEED = int(os.environ.get("REPRO_FAULT_SEED", "7"))


def _chaos_plan():
    return FaultPlan(seed=FAULT_SEED).add("device_failure", device=0)


def serve_once(session, trace, plan):
    placement = DevicePlacement()
    svc = UnlearningService(session, policy="window",
                            policy_opts={"width": 1.0}, placement=placement,
                            faults=plan, retry=RetryPolicy(backoff=0.001))
    try:
        report = svc.serve(trace)
    finally:
        placement.shutdown()
        for rec in session.records:
            if hasattr(rec.store, "attach_faults"):
                rec.store.attach_faults(None)
    return report, report.placement["unhealthy"]


def main():
    fl = FLConfig(num_clients=12, clients_per_round=8, num_shards=4,
                  local_epochs=2, global_rounds=2, retrain_ratio=2.0)
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(fl.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, fl.num_clients, iid=True)
    sim = FLSimulator(cfg, fl, clients, task="image",
                      opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                              grad_clip=0.0),
                      local_batch=10, seed=0)
    session = FederatedSession(sim, store_kind="coded")
    record = session.run_stage()
    victims = even_requests(record.plan, 4)      # 4 distinct shards
    trace = sequenced_trace(victims, spacing=0.0, rounds=2)

    rep_ok, _ = serve_once(session, trace, None)
    p1, p2 = _chaos_plan(), _chaos_plan()
    rep_chaos, unhealthy = serve_once(session, trace, p1)
    serve_once(session, trace, p2)               # replay, fresh same-seed plan

    # one merged window batch per serve -> one UnlearnResult per serve
    results = [u for st in session.report.stages for u in st.unlearn]
    healthy, chaotic = results[0], results[1]
    max_err = 0.0
    for s in healthy.models:
        for a, b in zip(jax.tree.leaves(healthy.models[s]),
                        jax.tree.leaves(chaotic.models[s])):
            max_err = max(max_err, float(np.max(np.abs(
                np.asarray(a, np.float64) - np.asarray(b, np.float64)))))

    print(json.dumps({
        "num_devices": len(jax.devices()),
        "num_requests": len(rep_chaos.entries),
        "aborts": rep_chaos.faults["aborts"],
        "retries": rep_chaos.faults["retries"],
        "redispatches": p1.ledger.count("redispatch"),
        "device_faults": p1.ledger.count("device_failure"),
        "unhealthy": unhealthy,
        "max_abs_err": max_err,
        "models_bit_identical": max_err == 0.0,
        "ledger_replay_identical":
            p1.ledger.signature() == p2.ledger.signature(),
        "healthy_retries": rep_ok.faults["retries"],
    }))


if __name__ == "__main__":
    main()
