"""Deterministic fallback for ``hypothesis`` when it is not installed.

The property-based test modules import ``given``/``settings``/``st`` through
this shim so the suite still COLLECTS AND RUNS without the dependency: each
``@given`` test executes ``max_examples`` seeded draws from the declared
strategies (a fixed per-test rng — reproducible, no shrinking). With real
hypothesis installed (see requirements-dev.txt) the shim is bypassed
entirely and full property testing applies.
"""
from __future__ import annotations

import functools
import inspect
import zlib

try:                                      # pragma: no cover - prefer the real thing
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False
    import numpy as _np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

    class st:  # noqa: N801 - mirrors the hypothesis module name
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def sampled_from(elements):
            elems = list(elements)
            return _Strategy(lambda rng: elems[int(rng.integers(len(elems)))])

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **_kw):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(2)))

    def settings(max_examples: int = 10, **_kw):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_max_examples",
                            getattr(fn, "_max_examples", 10))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    fn(*args, **drawn, **kwargs)
            # hide the strategy-filled params from pytest's fixture
            # resolution (real hypothesis does the same)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            del wrapper.__wrapped__
            return wrapper
        return deco
