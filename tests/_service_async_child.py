"""Child process for the multi-device service test: forced to 4 virtual CPU
devices via XLA_FLAGS (must be set before jax import — hence the subprocess),
it trains one tiny stage, serves the same 4-request single-victim trace with
the sequential FIFO/1-device baseline and the async window/4-device
placement, checks the per-shard models agree, and prints one JSON line the
parent test asserts on."""
import os

os.environ.setdefault("JAX_PLATFORMS", "cpu")
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=4")

import dataclasses  # noqa: E402
import json  # noqa: E402

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.configs import FLConfig, OptimizerConfig, get_config  # noqa: E402
from repro.core.sharding import even_requests  # noqa: E402
from repro.data import client_datasets_images, make_image_data  # noqa: E402
from repro.fl import FLSimulator  # noqa: E402
from repro.fl.experiment import FederatedSession  # noqa: E402
from repro.service import (DevicePlacement, UnlearningService,  # noqa: E402
                           sequenced_trace, single_device_placement)


def main():
    fl = FLConfig(num_clients=12, clients_per_round=8, num_shards=4,
                  local_epochs=2, global_rounds=2, retrain_ratio=2.0)
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(fl.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, fl.num_clients, iid=True)
    sim = FLSimulator(cfg, fl, clients, task="image",
                      opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                              grad_clip=0.0),
                      local_batch=10, seed=0)
    session = FederatedSession(sim, store_kind="coded")
    record = session.run_stage()
    # 4 single-victim requests hitting 4 distinct shards
    victims = even_requests(record.plan, 4)
    trace = sequenced_trace(victims, spacing=0.0, rounds=2)

    seq = UnlearningService(session, policy="fifo",
                            placement=single_device_placement())
    rep_seq = seq.serve(trace)
    qsync = UnlearningService(session, policy="window",
                              policy_opts={"width": 1.0},
                              placement=DevicePlacement())
    rep_async = qsync.serve(trace)

    # victims hit distinct shards, so the async merged serve retrains each
    # shard with exactly its own victim removed — per-shard models must
    # match the sequential single-request serves
    results = [u for st in session.report.stages for u in st.unlearn]
    seq_results, async_result = results[:4], results[4]
    max_err = 0.0
    for r in seq_results:
        (s,) = r.impacted_shards
        for a, b in zip(jax.tree.leaves(r.models[s]),
                        jax.tree.leaves(async_result.models[s])):
            max_err = max(max_err, float(np.max(np.abs(
                np.asarray(a, np.float64) - np.asarray(b, np.float64)))))

    print(json.dumps({
        "num_devices": len(jax.devices()),
        "devices_used": sorted({d for e in rep_async.entries
                                for d in e.devices}),
        "async_batches": rep_async.num_batches,
        "async_jobs": max(e.n_jobs for e in rep_async.entries),
        "seq_wall_s": rep_seq.serve_wall,
        "async_wall_s": rep_async.serve_wall,
        "max_abs_err": max_err,
        "impacted": sorted(async_result.impacted_shards),
    }))


if __name__ == "__main__":
    main()
