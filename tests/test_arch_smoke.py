"""Per-architecture smoke tests: a REDUCED variant of each assigned family
(2 layers, d_model<=256, <=4 experts) runs one forward and one train step on
CPU; output shapes and finiteness are asserted. The FULL configs are exercised
only via the dry-run (see launch/dryrun.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config, reduce_for_smoke
from repro.models import (abstract_params, decode_fn, init_cache, init_params,
                          loss_fn, num_params, param_axes, prefill_fn)

B, S = 2, 32


def _batch(cfg, rng):
    r1, r2 = jax.random.split(jax.random.key(rng))
    batch = {
        "tokens": jax.random.randint(r1, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(r2, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(r1, (B, cfg.vision_tokens, cfg.d_model),
                                             jnp.float32)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(r1, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, 0)
    lf = loss_fn(cfg)

    loss, metrics = jax.jit(lf)(params, batch)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), f"{arch}: non-finite loss {loss}"

    grads = jax.jit(jax.grad(lambda p, b: lf(p, b)[0]))(params, batch)
    gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                         for g in jax.tree.leaves(grads)))
    assert np.isfinite(float(gnorm)), f"{arch}: non-finite grad norm"
    # one SGD step changes the loss
    params2 = jax.tree.map(lambda p, g: p - 0.1 * g.astype(p.dtype), params, grads)
    loss2, _ = jax.jit(lf)(params2, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_param_axes_structure(arch):
    cfg = reduce_for_smoke(get_config(arch))
    axes = param_axes(cfg)
    shapes = abstract_params(cfg)
    ax_leaves = jax.tree.leaves(axes, is_leaf=lambda x: isinstance(x, tuple))
    sh_leaves = jax.tree.leaves(shapes)
    assert len(ax_leaves) == len(sh_leaves)
    for a, s in zip(ax_leaves, sh_leaves):
        assert len(a) == len(s.shape), f"{arch}: axes {a} vs shape {s.shape}"


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_smoke_decode_step(arch):
    cfg = reduce_for_smoke(get_config(arch))
    params = init_params(cfg, jax.random.key(1))
    batch = _batch(cfg, 0)
    pf = prefill_fn(cfg)
    logits, cache = jax.jit(pf)(params, batch)
    assert np.all(np.isfinite(np.asarray(logits, np.float32))), f"{arch}: prefill NaN"

    df = decode_fn(cfg)
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    logits2, cache2 = jax.jit(df)(params, tok, cache)
    v_padded = logits2.shape[-1]
    assert logits2.shape[:2] == (B, 1) and v_padded >= cfg.vocab_size
    assert np.all(np.isfinite(np.asarray(logits2, np.float32))), f"{arch}: decode NaN"
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["olmo-1b", "gemma3-27b", "rwkv6-3b",
                                  "jamba-1.5-large-398b"])
def test_multi_token_decode_matches_forward(arch):
    """Property: N sequential decode steps after prefill reproduce the full
    forward's logits at every step — exercises cache headroom, ring buffers
    (gemma local layers), and SSM/RWKV state continuity."""
    import dataclasses
    from repro.models import predict_fn
    n_gen = 4
    # capacity-based MoE drops depend on the token-group size, which differs
    # between prefill and decode by construction; unbind capacity so the test
    # checks the cache/state logic, not the (documented) drop semantics.
    cfg = dataclasses.replace(reduce_for_smoke(get_config(arch)),
                              moe_capacity_factor=16.0)
    params = init_params(cfg, jax.random.key(5))
    s_total = 48 + n_gen
    toks = jax.random.randint(jax.random.key(6), (B, s_total), 0, cfg.vocab_size)
    full_batch = _batch(cfg, 0)
    full_batch["tokens"] = toks
    full_batch["labels"] = toks
    if cfg.family == "audio":
        full_batch["frames"] = jnp.zeros((B, 32, cfg.d_model), jnp.float32)
    full_logits = jax.jit(predict_fn(cfg))(params, full_batch)

    pre_batch = dict(full_batch)
    pre_batch["tokens"] = toks[:, :48]
    pre_batch["labels"] = toks[:, :48]
    _, cache = jax.jit(prefill_fn(cfg, max_len=s_total))(params, pre_batch)
    df = jax.jit(decode_fn(cfg))
    for i in range(n_gen):
        lg, cache = df(params, toks[:, 48 + i: 49 + i], cache)
        np.testing.assert_allclose(
            np.asarray(lg[:, 0], np.float32),
            np.asarray(full_logits[:, 48 + i], np.float32),
            rtol=5e-3, atol=5e-3,
            err_msg=f"{arch}: decode step {i} diverged from full forward")


def test_decode_matches_prefill_continuation():
    """Property: decoding token t+1 after prefill(t) == prefill(t+1) logits."""
    cfg = reduce_for_smoke(get_config("olmo-1b"))
    params = init_params(cfg, jax.random.key(3))
    toks = jax.random.randint(jax.random.key(4), (B, S + 1), 0, cfg.vocab_size)
    b_full = {"tokens": toks, "labels": toks}
    b_pre = {"tokens": toks[:, :S], "labels": toks[:, :S]}
    lg_full, _ = jax.jit(prefill_fn(cfg))(params, b_full)
    _, cache = jax.jit(prefill_fn(cfg, max_len=S + 1))(params, b_pre)
    lg_dec, _ = jax.jit(decode_fn(cfg))(params, toks[:, S:], cache)
    np.testing.assert_allclose(np.asarray(lg_full[:, -1], np.float32),
                               np.asarray(lg_dec[:, -1], np.float32),
                               rtol=2e-3, atol=2e-3)
