"""Coded-computing tests: exact reconstruction, erasure tolerance, error
correction (Berlekamp-Welch), pytree round-trips, property-based sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import coding


def _scheme(c, s):
    return coding.CodingScheme(num_shards=s, num_clients=c)


class TestEncodeDecode:
    def test_roundtrip_exact(self):
        sch = _scheme(20, 4)
        w = jnp.asarray(np.random.default_rng(0).standard_normal((4, 257)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        assert slices.shape == (20, 257)
        out = coding.decode_erasure(sch, slices, list(range(20)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)

    def test_any_s_subset_suffices(self):
        sch = _scheme(12, 3)
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
        slices = coding.encode(sch, w)
        for _ in range(5):
            ids = sorted(rng.choice(12, size=3, replace=False).tolist())
            out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
            np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                       rtol=2e-3, atol=2e-3)

    def test_vandermonde_matches_paper_eq7(self):
        """The paper's literal pseudo-inverse decode agrees at small C."""
        sch = _scheme(8, 3)
        w = jnp.asarray(np.random.default_rng(2).standard_normal((3, 33)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        out = coding.decode_vandermonde(sch, slices)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)

    def test_storage_at_c100(self):
        """Paper scale: C=100 clients, S=4 shards — f32-stable decode."""
        sch = _scheme(100, 4)
        w = jnp.asarray(np.random.default_rng(3).standard_normal((4, 128)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        ids = list(range(0, 100, 25))  # any 4 slices
        out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=5e-3, atol=5e-3)


class TestErrors:
    def test_error_localization_and_decode(self):
        sch = _scheme(16, 4)
        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.standard_normal((4, 96)), jnp.float32)
        slices = np.array(coding.encode(sch, w))  # writable copy
        bad_true = [3, 11]
        slices[bad_true] += rng.standard_normal((2, 96)) * 5.0
        out, bad = coding.decode_with_errors(sch, jnp.asarray(slices))
        assert set(bad.tolist()) == set(bad_true)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=2e-3, atol=2e-3)

    def test_no_error_fast_path(self):
        sch = _scheme(10, 3)
        w = jnp.asarray(np.random.default_rng(5).standard_normal((3, 40)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        out, bad = coding.decode_with_errors(sch, slices)
        assert bad.size == 0
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)

    def test_max_errors_eq11(self):
        assert _scheme(20, 4).max_errors == 8   # (C-S)/2
        assert _scheme(100, 4).max_errors == 48


class TestPytrees:
    def test_pytree_roundtrip(self):
        rng = jax.random.key(0)
        trees = []
        for s in range(3):
            k = jax.random.fold_in(rng, s)
            trees.append({
                "a": jax.random.normal(k, (7, 5), jnp.float32),
                "b": {"c": jax.random.normal(k, (11,), jnp.float32)},
            })
        sch = _scheme(9, 3)
        slices, specs = coding.encode_pytrees(sch, trees)
        out = coding.decode_pytrees(sch, slices[jnp.asarray([1, 4, 8])],
                                    [1, 4, 8], specs)
        for t, o in zip(trees, out):
            for la, lb in zip(jax.tree.leaves(t), jax.tree.leaves(o)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(4, 40), s=st.integers(2, 6), p=st.integers(1, 50),
       seed=st.integers(0, 100))
def test_property_roundtrip(c, s, p, seed):
    """Property: for any C>=S, encode->erasure-decode is identity (f32 tol)."""
    if c < s:
        c = s
    sch = _scheme(c, s)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((s, p)), jnp.float32)
    slices = coding.encode(sch, w)
    ids = sorted(rng.choice(c, size=s, replace=False).tolist())
    out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                               rtol=2e-2, atol=2e-2)
