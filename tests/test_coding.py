"""Coded-computing tests: exact reconstruction, erasure tolerance, error
correction (Berlekamp-Welch), pytree round-trips, property-based sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.core import coding


def _scheme(c, s):
    return coding.CodingScheme(num_shards=s, num_clients=c)


class TestEncodeDecode:
    def test_roundtrip_exact(self):
        sch = _scheme(20, 4)
        w = jnp.asarray(np.random.default_rng(0).standard_normal((4, 257)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        assert slices.shape == (20, 257)
        out = coding.decode_erasure(sch, slices, list(range(20)))
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-4, atol=1e-4)

    def test_any_s_subset_suffices(self):
        sch = _scheme(12, 3)
        rng = np.random.default_rng(1)
        w = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
        slices = coding.encode(sch, w)
        for _ in range(5):
            ids = sorted(rng.choice(12, size=3, replace=False).tolist())
            out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
            np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                       rtol=2e-3, atol=2e-3)

    def test_vandermonde_matches_paper_eq7(self):
        """The paper's literal pseudo-inverse decode agrees at small C."""
        sch = _scheme(8, 3)
        w = jnp.asarray(np.random.default_rng(2).standard_normal((3, 33)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        out = coding.decode_vandermonde(sch, slices)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)

    def test_storage_at_c100(self):
        """Paper scale: C=100 clients, S=4 shards — f32-stable decode."""
        sch = _scheme(100, 4)
        w = jnp.asarray(np.random.default_rng(3).standard_normal((4, 128)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        ids = list(range(0, 100, 25))  # any 4 slices
        out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=5e-3, atol=5e-3)


class TestErrors:
    def test_error_localization_and_decode(self):
        sch = _scheme(16, 4)
        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.standard_normal((4, 96)), jnp.float32)
        slices = np.array(coding.encode(sch, w))  # writable copy
        bad_true = [3, 11]
        slices[bad_true] += rng.standard_normal((2, 96)) * 5.0
        out, bad = coding.decode_with_errors(sch, jnp.asarray(slices))
        assert set(bad.tolist()) == set(bad_true)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=2e-3, atol=2e-3)

    def test_no_error_fast_path(self):
        sch = _scheme(10, 3)
        w = jnp.asarray(np.random.default_rng(5).standard_normal((3, 40)),
                        jnp.float32)
        slices = coding.encode(sch, w)
        out, bad = coding.decode_with_errors(sch, slices)
        assert bad.size == 0
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)

    def test_max_errors_eq11(self):
        assert _scheme(20, 4).max_errors == 8   # (C-S)/2
        assert _scheme(100, 4).max_errors == 48


class TestErrorDecodingPaths:
    """Error-correcting decode beyond the BW happy path: consensus (ransac)
    localization, the BW→ransac fallback, and the full ``decode_with_errors``
    pipeline under corruption at the paper's C=20/S=4 tolerance budget."""

    C, S = 20, 4

    def _corrupted(self, bad, p=96, scale=10.0, seed=0):
        sch = _scheme(self.C, self.S)
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.standard_normal((self.S, p)), jnp.float32)
        slices = np.array(coding.encode(sch, w), np.float64)
        slices[bad] += rng.standard_normal((len(bad), p)) * scale
        return sch, w, slices

    def test_ransac_locates_errors(self):
        bad_true = [0, 4, 9, 13, 17]
        sch, _, slices = self._corrupted(bad_true)
        bad = coding.locate_errors(sch, slices, method="ransac")
        assert sorted(bad.tolist()) == bad_true

    def test_ransac_no_error_fast_path(self):
        sch, _, slices = self._corrupted([], seed=1)
        assert coding.locate_errors(sch, slices, method="ransac").size == 0

    def test_bw_matches_ransac(self):
        bad_true = [2, 6, 10, 15]
        sch, _, slices = self._corrupted(bad_true, seed=2)
        bw = coding.locate_errors(sch, slices, method="bw")
        rs = coding.locate_errors(sch, slices, method="ransac")
        assert sorted(bw.tolist()) == sorted(rs.tolist()) == bad_true

    def test_bw_falls_back_to_ransac(self, monkeypatch):
        """When the BW least-squares localization is degenerate (here:
        sabotaged to return zeros, so the error-locator polynomial flags the
        wrong rows), the self-consistency verification must reject it and the
        consensus fallback must still recover the true corrupted set."""
        bad_true = [0, 4, 9, 13, 17]
        sch, _, slices = self._corrupted(bad_true)
        calls = {"lstsq": 0}

        def broken_lstsq(a, b, rcond=None):
            calls["lstsq"] += 1
            return np.zeros(a.shape[1]), None, None, None

        monkeypatch.setattr(np.linalg, "lstsq", broken_lstsq)
        bad = coding.locate_errors(sch, slices, method="bw")
        assert calls["lstsq"] > 0            # the BW branch actually ran
        assert sorted(bad.tolist()) == bad_true

    def test_decode_with_errors_at_max_budget(self):
        """Full pipeline at mu*C = (C-S)/2 = 8 corrupted slices of 20 —
        the paper's eq. (11) tolerance boundary."""
        bad_true = [1, 3, 5, 7, 11, 14, 16, 19]
        sch, w, slices = self._corrupted(bad_true, seed=3)
        assert len(bad_true) == sch.max_errors
        out, bad = coding.decode_with_errors(
            sch, jnp.asarray(slices, jnp.float32))
        assert sorted(bad.tolist()) == bad_true
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=5e-3, atol=5e-3)

    def test_decode_with_errors_through_coded_store(self):
        """CodedStore.get_shard(corrupt=...) routes through the
        error-correcting decode and still reconstructs every client tree."""
        from repro.stores.store import CodedStore, RoundPayload

        sch = _scheme(self.C, self.S)
        shard_clients = {s: [2 * s, 2 * s + 1] for s in range(self.S)}
        _, row_spec = coding.tree_to_flat({"w": jnp.zeros((6,), jnp.float32)})
        rng = np.random.default_rng(4)
        flats = {s: jnp.asarray(rng.standard_normal((2, 6)), jnp.float32)
                 for s in range(self.S)}
        store = CodedStore(sch, shard_clients)
        store.put_round(RoundPayload.from_flat(0, shard_clients, flats,
                                               row_spec))
        store.flush()
        corrupt = np.zeros((self.C, 12))
        corrupt[[2, 8, 12]] = rng.standard_normal((3, 12)) * 10.0
        got = store.get_shard(0, 1, corrupt=corrupt)
        assert sorted(got) == shard_clients[1]
        for i, c in enumerate(shard_clients[1]):
            np.testing.assert_allclose(np.asarray(got[c]["w"]),
                                       np.asarray(flats[1][i]),
                                       rtol=5e-3, atol=5e-3)


class TestEncodeRounds:
    def test_matches_per_round_encode(self):
        sch = _scheme(16, 4)
        rng = np.random.default_rng(5)
        hist = jnp.asarray(rng.standard_normal((5, 4, 257)), jnp.float32)
        enc = jnp.asarray(sch.encode_matrix(), jnp.float32)
        out = coding.encode_rounds(enc, hist)
        assert out.shape == (5, 16, 257)
        for g in range(5):
            np.testing.assert_allclose(np.asarray(out[g]),
                                       np.asarray(coding.encode(sch, hist[g])),
                                       rtol=1e-6, atol=1e-6)

    def test_kernel_path_matches(self):
        sch = _scheme(12, 3)
        rng = np.random.default_rng(6)
        hist = jnp.asarray(rng.standard_normal((3, 3, 100)), jnp.float32)
        enc = jnp.asarray(sch.encode_matrix(), jnp.float32)
        ref = coding.encode_rounds(enc, hist)
        krn = coding.encode_rounds(enc, hist, use_kernel=True)
        np.testing.assert_allclose(np.asarray(ref), np.asarray(krn),
                                   rtol=1e-5, atol=1e-5)

    def test_out_dtype(self):
        sch = _scheme(8, 2)
        hist = jnp.asarray(np.random.default_rng(7).standard_normal((2, 2, 32)),
                           jnp.float32)
        enc = jnp.asarray(sch.encode_matrix(), jnp.float32)
        assert coding.encode_rounds(enc, hist,
                                    out_dtype=jnp.bfloat16).dtype == jnp.bfloat16


class TestPytrees:
    def test_pytree_roundtrip(self):
        rng = jax.random.key(0)
        trees = []
        for s in range(3):
            k = jax.random.fold_in(rng, s)
            trees.append({
                "a": jax.random.normal(k, (7, 5), jnp.float32),
                "b": {"c": jax.random.normal(k, (11,), jnp.float32)},
            })
        sch = _scheme(9, 3)
        slices, specs = coding.encode_pytrees(sch, trees)
        out = coding.decode_pytrees(sch, slices[jnp.asarray([1, 4, 8])],
                                    [1, 4, 8], specs)
        for t, o in zip(trees, out):
            for la, lb in zip(jax.tree.leaves(t), jax.tree.leaves(o)):
                np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                           rtol=2e-3, atol=2e-3)


@settings(max_examples=20, deadline=None)
@given(c=st.integers(4, 40), s=st.integers(2, 6), p=st.integers(1, 50),
       seed=st.integers(0, 100))
def test_property_roundtrip(c, s, p, seed):
    """Property: for any C>=S, encode->erasure-decode is identity (f32 tol)."""
    if c < s:
        c = s
    sch = _scheme(c, s)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((s, p)), jnp.float32)
    slices = coding.encode(sch, w)
    ids = sorted(rng.choice(c, size=s, replace=False).tolist())
    out = coding.decode_erasure(sch, slices[jnp.asarray(ids)], ids)
    np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                               rtol=2e-2, atol=2e-2)
