"""Durability tests: the checksummed snapshot format, the write-ahead
journal, checkpoint rotation with torn-write fallback, crash/resume
bit-identity for ``FederatedSession`` (in-process ``InjectedCrash`` and a
real ``os._exit`` subprocess kill), exactly-once unlearning replay through
the service journal, the ``repro.checkpoint`` -> ``repro.stores`` rename
shim, and the ``ScenarioConfig`` checkpoint-knob validation."""
import dataclasses
import json
import os
import subprocess
import sys
import tempfile

import jax
import numpy as np
import pytest

from _hypothesis_fallback import given, settings, st
from _durability_crash_child import session_signature

from repro.core.coding import CodingScheme, StackedRowSpec
from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import client_datasets_images, make_image_data
from repro.durability import (CheckpointManager, Journal, SnapshotCorruption,
                              load_snapshot, replay, save_snapshot)
from repro.faults import FaultPlan, InjectedCrash
from repro.fl import FLSimulator
from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                 ScenarioConfig, UnlearnRequest)
from repro.service import (LedgerEntry, ServiceRequest, UnlearningService,
                           sequenced_trace, service_request_id,
                           single_device_placement)
from repro.stores.store import StoreStats

FL_TINY = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=2, retrain_ratio=2.0)
NUM_STAGES = 2


def _tiny_sim(seed=0):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _schedule():
    return RequestSchedule([
        UnlearnRequest(lambda p: [p.shard_clients[0][0]], framework="SE",
                       after_stage=0, rounds=1),
        UnlearnRequest(lambda p: [p.shard_clients[1][0]], framework="SE",
                       after_stage=1, rounds=1),
    ])


# -------------------------------------------------------------- snapshot fmt
class TestSnapshotFormat:
    def _graph(self):
        bf16 = np.dtype("bfloat16")
        rng = np.random.default_rng(0)
        tree = {"w": rng.standard_normal((3, 2)).astype(np.float32),
                "b": rng.standard_normal(2).astype(np.float32)}
        leaves, treedef = jax.tree.flatten(tree)
        return {
            "slices": {(0, 1): rng.standard_normal(7).astype(np.float32)
                       .astype(bf16)},
            "spec": StackedRowSpec((0, 1, 2), 8,
                                   (treedef, [(l.shape, l.dtype)
                                              for l in leaves])),
            "scheme": CodingScheme(num_shards=2, num_clients=5),
            "stats": StoreStats(server_bytes=12, reads=3),
            "served": {"req-s0-0", "req-s1-0"},
            "rng": {"state": 12345678901234567890, "pos": 17},
            "scalars": [None, True, 2.5, -0.0, "text"],
            "jaxarr": jax.numpy.arange(6, dtype=jax.numpy.int32),
        }

    def test_roundtrip_exact(self, tmp_path):
        path = str(tmp_path / "s.ckpt")
        obj = self._graph()
        n = save_snapshot(path, obj)
        assert n == os.path.getsize(path)
        back = load_snapshot(path)
        sl, sl2 = obj["slices"][(0, 1)], back["slices"][(0, 1)]
        assert sl2.dtype == np.dtype("bfloat16")          # never promoted
        assert sl2.tobytes() == sl.tobytes()
        td, shapes = back["spec"].row_spec
        td0, shapes0 = obj["spec"].row_spec
        assert td == td0 and shapes == shapes0
        assert back["spec"].client_ids == (0, 1, 2)
        assert back["scheme"].num_shards == 2
        assert back["scheme"].alpha.tobytes() == obj["scheme"].alpha.tobytes()
        assert back["stats"] == obj["stats"]
        assert back["served"] == obj["served"]
        assert back["rng"] == obj["rng"]                  # bigint exact
        assert back["scalars"] == obj["scalars"]
        got = np.asarray(back["jaxarr"])
        assert isinstance(back["jaxarr"], jax.Array)
        assert got.dtype == np.int32
        np.testing.assert_array_equal(got, np.arange(6, dtype=np.int32))

    def test_atomic_commit_leaves_no_tmp(self, tmp_path):
        path = str(tmp_path / "s.ckpt")
        save_snapshot(path, {"a": 1})
        assert os.listdir(tmp_path) == ["s.ckpt"]

    def test_truncation_detected(self, tmp_path):
        path = str(tmp_path / "s.ckpt")
        save_snapshot(path, self._graph())
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.truncate(size // 2)
        with pytest.raises(SnapshotCorruption, match="torn write"):
            load_snapshot(path)

    def test_bitflip_detected(self, tmp_path):
        path = str(tmp_path / "s.ckpt")
        save_snapshot(path, self._graph())
        size = os.path.getsize(path)
        with open(path, "r+b") as f:
            f.seek(size - 8)
            chunk = f.read(8)
            f.seek(size - 8)
            f.write(bytes(b ^ 0xFF for b in chunk))
        with pytest.raises(SnapshotCorruption, match="checksum mismatch"):
            load_snapshot(path)

    def test_bad_magic_detected(self, tmp_path):
        path = str(tmp_path / "s.ckpt")
        with open(path, "wb") as f:
            f.write(b"NOTASNAP" + b"\0" * 64)
        with pytest.raises(SnapshotCorruption, match="bad magic"):
            load_snapshot(path)

    def test_missing_file_is_corruption(self, tmp_path):
        with pytest.raises(SnapshotCorruption, match="unreadable"):
            load_snapshot(str(tmp_path / "nope.ckpt"))


# ------------------------------------------------------------------- journal
class TestJournal:
    def test_append_replay_roundtrip(self, tmp_path):
        path = str(tmp_path / "j.wal")
        j = Journal(path)
        events = [{"ev": "stage_begin", "stage": 0},
                  {"ev": "req_commit", "rids": ["req-s0-0"]},
                  {"ev": "snapshot", "step": 0, "path": "snap-000000.ckpt"}]
        assert [j.append(e) for e in events] == [0, 1, 2]
        j.close()
        assert Journal(path).events() == events

    def test_seq_continues_across_reopen(self, tmp_path):
        path = str(tmp_path / "j.wal")
        j1 = Journal(path)
        j1.append({"ev": "a"})
        j1.close()
        j2 = Journal(path)
        assert j2.append({"ev": "b"}) == 1
        assert [r["seq"] for r in j2.records()] == [0, 1]

    def test_torn_tail_tolerated(self, tmp_path):
        path = str(tmp_path / "j.wal")
        j = Journal(path)
        j.append({"ev": "a"})
        j.append({"ev": "b"})
        j.close()
        with open(path, "a") as f:
            f.write('deadbeef {"seq": 2, "ev"')     # crash mid-append
        assert [r["ev"]["ev"] for r in replay(path)] == ["a", "b"]
        # a reopened journal resumes numbering after the good prefix
        assert Journal(path).append({"ev": "c"}) == 2

    def test_corrupt_middle_stops_replay(self, tmp_path):
        path = str(tmp_path / "j.wal")
        j = Journal(path)
        j.append({"ev": "a"})
        j.append({"ev": "b"})
        j.close()
        lines = open(path).read().splitlines()
        lines[0] = "00000000 " + lines[0].split(" ", 1)[1]
        with open(path, "w") as f:
            f.write("\n".join(lines) + "\n")
        assert replay(path) == []                   # nothing after bad crc


# -------------------------------------------------------- checkpoint manager
class TestCheckpointManager:
    def test_save_load_and_rotation(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=2)
        for step in range(4):
            mgr.save({"step": step}, step)
        assert mgr.steps() == [2, 3]                # pruned to keep=2
        state, step, path = mgr.load_latest()
        assert state == {"step": 3} and step == 3
        assert path.endswith("snap-000003.ckpt")

    def test_corrupt_newest_falls_back_to_previous(self, tmp_path):
        mgr = CheckpointManager(str(tmp_path), keep=3)
        for step in range(3):
            mgr.save({"step": step}, step)
        bad = mgr.snapshot_path(2)
        with open(bad, "r+b") as f:
            f.truncate(os.path.getsize(bad) // 3)
        state, step, _path = mgr.load_latest()
        assert state == {"step": 1} and step == 1
        assert mgr.skipped == [bad]

    def test_empty_dir_loads_none(self, tmp_path):
        assert CheckpointManager(str(tmp_path)).load_latest() is None


# ------------------------------------------------- stores rename (satellite)
class TestStoresRenameShim:
    def test_legacy_import_warns_and_is_identical(self):
        sys.modules.pop("repro.checkpoint", None)
        with pytest.warns(DeprecationWarning,
                          match="repro.checkpoint is deprecated"):
            import repro.checkpoint as legacy
        import repro.stores as stores
        for name in ("CodedStore", "FullStore", "UncodedShardStore",
                     "ParameterStore", "RoundPayload", "StoreStats",
                     "make_store", "register_store", "tree_bytes"):
            assert getattr(legacy, name) is getattr(stores, name), name
        assert legacy.STORES is stores.STORES

    def test_legacy_store_module_resolves_same_classes(self):
        from repro.checkpoint import store as legacy_store
        from repro.stores import store as new_store
        assert legacy_store.CodedStore is new_store.CodedStore
        assert legacy_store._StackedRow is new_store._StackedRow


# ---------------------------------------------- scenario knobs (satellite)
class TestScenarioCheckpointValidation:
    def test_negative_interval_fails_at_construction(self):
        with pytest.raises(ValueError, match="checkpoint_every=-1"):
            ScenarioConfig(checkpoint_every=-1)

    def test_interval_without_dir_fails(self):
        with pytest.raises(ValueError, match="needs a checkpoint_dir"):
            ScenarioConfig(checkpoint_every=2)

    def test_unwritable_dir_fails_at_construction(self):
        with pytest.raises(ValueError, match="not writable"):
            ScenarioConfig(checkpoint_dir="/proc/definitely/not/writable")

    def test_writable_dir_accepted(self, tmp_path):
        cfg = ScenarioConfig(checkpoint_every=2,
                             checkpoint_dir=str(tmp_path / "ck"))
        assert cfg.checkpoint_every == 2

    def test_session_rejects_interval_without_dir(self):
        with pytest.raises(ValueError, match="needs a"):
            FederatedSession(_tiny_sim(), checkpoint_every=1)


# --------------------------------------------- crash/resume (in-process)
@pytest.fixture(scope="module")
def baseline_sig():
    """Signature of the uninterrupted, checkpoint-free run — the oracle
    every crashed+resumed variant must match bit-for-bit."""
    session = FederatedSession(_tiny_sim(), store_kind="coded")
    session.run(NUM_STAGES, schedule=_schedule())
    return session_signature(session)


class TestSessionCrashResume:
    def test_crash_after_requests_resumes_bit_identical(self, tmp_path,
                                                        baseline_sig):
        ck = str(tmp_path / "ck")
        plan = FaultPlan(seed=7).add("process_kill", stage=1,
                                     phase="after_requests", mode="raise")
        crashed = FederatedSession(_tiny_sim(), store_kind="coded",
                                   faults=plan, checkpoint_every=1,
                                   checkpoint_dir=ck)
        with pytest.raises(InjectedCrash):
            crashed.run(NUM_STAGES, schedule=_schedule())
        assert plan.ledger.count("process_kill") == 1
        assert crashed.checkpointer.steps() == [0]   # died before snap-1

        resumed = FederatedSession(_tiny_sim(), store_kind="coded",
                                   checkpoint_every=1, checkpoint_dir=ck)
        resumed.run(NUM_STAGES, schedule=_schedule(), resume_from=ck)
        info = resumed.last_resume_info
        assert info["step"] == 0 and info["start_stage"] == 1
        assert session_signature(resumed) == baseline_sig
        # exactly-once: a request lands at most once per impacted stage
        # (a multi-stage victim legitimately yields one result per stage)
        pairs = [(i, u.request_id)
                 for i, st_ in enumerate(resumed.report.stages)
                 for u in st_.unlearn]
        assert len(pairs) == len(set(pairs))
        assert {rid for _, rid in pairs} == {"req-s0-0", "req-s1-0"}

    def test_torn_snapshot_falls_back_to_previous_good(self, tmp_path,
                                                       baseline_sig):
        ck = str(tmp_path / "ck")
        plan = (FaultPlan(seed=7)
                .add("torn_write", step=1, frac=0.4)
                .add("process_kill", stage=1, phase="after_snapshot",
                     mode="raise"))
        crashed = FederatedSession(_tiny_sim(), store_kind="coded",
                                   faults=plan, checkpoint_every=1,
                                   checkpoint_dir=ck)
        with pytest.raises(InjectedCrash):
            crashed.run(NUM_STAGES, schedule=_schedule())
        assert plan.ledger.count("torn_write") == 1

        resumed = FederatedSession(_tiny_sim(), store_kind="coded")
        resumed.run(NUM_STAGES, schedule=_schedule(), resume_from=ck)
        info = resumed.last_resume_info
        assert len(info["skipped_snapshots"]) == 1   # snap-1: checksum fail
        assert info["step"] == 0 and info["start_stage"] == 1
        assert session_signature(resumed) == baseline_sig

    def test_resume_from_empty_dir_raises(self, tmp_path):
        session = FederatedSession(_tiny_sim(), store_kind="coded")
        with pytest.raises(FileNotFoundError, match="no usable snapshot"):
            session.run(NUM_STAGES, resume_from=str(tmp_path / "empty"))

    def test_resume_rejects_mismatched_config(self, tmp_path):
        ck = str(tmp_path / "ck")
        session = FederatedSession(_tiny_sim(), store_kind="coded",
                                   checkpoint_every=1, checkpoint_dir=ck)
        session.run(1, schedule=None)
        other = FederatedSession(_tiny_sim(), store_kind="full")
        with pytest.raises(ValueError, match="store_kind"):
            other.run(NUM_STAGES, resume_from=ck)


# ------------------------------------------ service exactly-once (satellite)
@pytest.fixture(scope="module")
def trained_for_service():
    session = FederatedSession(_tiny_sim(), store_kind="coded")
    record = session.run_stage()
    victims = [record.plan.shard_clients[0][0],
               record.plan.shard_clients[1][0]]
    return session, victims


class TestServiceExactlyOnce:
    def test_journal_replay_commits_exactly_once(self, tmp_path,
                                                 trained_for_service):
        session, victims = trained_for_service
        trace = sequenced_trace(victims, spacing=0.1, rounds=1)
        jpath = str(tmp_path / "svc.wal")
        j1 = Journal(jpath)
        svc1 = UnlearningService(session,
                                 placement=single_device_placement(),
                                 journal=j1)
        rep1 = svc1.serve(trace[:1])        # "crash" after first request
        j1.close()
        # the WAL interleaves the hash-chained audit records with the
        # dispatch/commit markers; exactly-once cares about the latter
        assert [e["ev"] for e in Journal(jpath).events()
                if e["ev"] != "audit"] == ["svc_dispatch", "svc_commit"]
        from repro.telemetry import verify_journal
        assert verify_journal(Journal(jpath)) == svc1.audit.head

        j2 = Journal(jpath)
        svc2 = UnlearningService(session,
                                 placement=single_device_placement(),
                                 journal=j2)
        rep2 = svc2.serve(trace, resume=True)
        j2.close()
        assert [e.request_id for e in rep2.entries] == ["svc-0", "svc-1"]
        # committed entry replayed bit-identically, never re-dispatched
        assert rep2.entries[0].to_dict() == rep1.entries[0].to_dict()
        events = Journal(jpath).events()
        commits = [e["request_id"] for e in events if e["ev"] == "svc_commit"]
        dispatches = [e["request_id"] for e in events
                      if e["ev"] == "svc_dispatch"]
        assert commits.count("svc-0") == 1          # exactly once, ever
        assert dispatches.count("svc-0") == 1
        assert commits.count("svc-1") == 1
        assert dispatches.count("svc-1") == 1

    def test_dispatched_uncommitted_redispatches_exactly_once(
            self, tmp_path, trained_for_service):
        session, victims = trained_for_service
        trace = sequenced_trace(victims[:1], rounds=1)
        jpath = str(tmp_path / "svc.wal")
        j = Journal(jpath)
        # crash between retrain and ledger-commit: dispatch journaled,
        # commit never was
        j.append({"ev": "svc_dispatch", "request_id": "svc-0",
                  "batch_id": 0})
        svc = UnlearningService(session,
                                placement=single_device_placement(),
                                journal=j)
        rep = svc.serve(trace, resume=True)
        j.close()
        assert [e.request_id for e in rep.entries] == ["svc-0"]
        events = Journal(jpath).events()
        assert sum(1 for e in events if e["ev"] == "svc_commit") == 1

    def test_report_keys_requests_on_ids(self, tmp_path,
                                         trained_for_service):
        session, victims = trained_for_service
        trace = sequenced_trace(victims, spacing=0.1, rounds=1)
        trace[0] = dataclasses.replace(trace[0], request_id="user-abc")
        svc = UnlearningService(session,
                                placement=single_device_placement())
        rep = svc.serve(trace)
        d = json.loads(rep.to_json())
        assert set(d["requests"]) == {"user-abc", "svc-1"}
        assert d["requests"]["user-abc"]["clients"] == [victims[0]]

    def test_ledger_entry_dict_roundtrip(self):
        entry = LedgerEntry(rid=4, arrival=0.25, clients=(7, 9),
                            framework="SE", batch_id=1, queue_wait=0.5,
                            batch_wait=0.01, retrain_wall=1.5, latency=2.01,
                            n_jobs=2, devices=[0, 1],
                            impacted=[(0, 0), (0, 1)], cost_units=3.5,
                            deadline=5.0, sla_met=True, job_attempts=3,
                            job_retries=1, request_id="user-x")
        assert LedgerEntry.from_dict(entry.to_dict()) == entry

    def test_service_request_id_fallback(self):
        assert service_request_id(ServiceRequest(t=0.0, clients=(1,),
                                                 rid=3)) == "svc-3"
        assert service_request_id(ServiceRequest(
            t=0.0, clients=(1,), rid=3, request_id="user-z")) == "user-z"


# ------------------------------------------- subprocess kill (acceptance)
class TestKillResumeSubprocess:
    def test_killed_session_resumes_bit_identical(self, tmp_path):
        """The durability acceptance anchor: a session killed mid-run with
        ``os._exit(137)`` (no atexit, no flushes) resumes from its snapshots
        and journal to a state bit-identical to the uninterrupted run.
        Subprocess because a real process kill cannot be simulated
        in-process."""
        env = dict(os.environ)
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ["src", env.get("PYTHONPATH", "")] if p)
        child = os.path.join(os.path.dirname(__file__),
                             "_durability_crash_child.py")
        cwd = os.path.dirname(os.path.dirname(os.path.abspath(child)))

        def run(mode, ckpt):
            return subprocess.run([sys.executable, child, mode, ckpt],
                                  env=env, cwd=cwd, capture_output=True,
                                  text=True, timeout=560)

        ck = str(tmp_path / "ck")
        crash = run("crash", ck)
        assert crash.returncode == 137, crash.stderr[-2000:]
        assert os.path.exists(os.path.join(ck, "journal.wal"))
        assert os.path.exists(os.path.join(ck, "snap-000000.ckpt"))

        resume = run("resume", ck)
        assert resume.returncode == 0, resume.stderr[-2000:]
        got = json.loads(resume.stdout.strip().splitlines()[-1])
        assert got["start_stage"] == 1 and got["resumed_step"] == 0
        assert got["request_ids"] == ["req-s0-0", "req-s1-0", "req-s2-0"]
        assert got["once_per_stage"]

        base = run("baseline", str(tmp_path / "unused"))
        assert base.returncode == 0, base.stderr[-2000:]
        ref = json.loads(base.stdout.strip().splitlines()[-1])
        assert got["sig"] == ref["sig"]              # bit-identical


# ------------------------------------------------ property tests (satellite)
_DTYPES = ["float32", "float16", "bfloat16", "int32", "int8", "uint8"]


@settings(max_examples=12)
@given(dtype=st.sampled_from(_DTYPES), n=st.integers(1, 64),
       seed=st.integers(0, 2 ** 20), as_jax=st.booleans())
def test_snapshot_array_roundtrip_never_promotes(dtype, n, seed, as_jax):
    rng = np.random.default_rng(seed)
    dt = np.dtype(dtype)
    if dt.kind in "iu":
        a = rng.integers(np.iinfo(dt).min, np.iinfo(dt).max,
                         size=n).astype(dt)
    else:
        a = rng.standard_normal(n).astype(np.float32).astype(dt)
    obj = {("coded", 0): [jax.numpy.asarray(a) if as_jax else a, None],
           "dtype": dt}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.ckpt")
        save_snapshot(path, obj)
        back = load_snapshot(path)
    got = back[("coded", 0)][0]
    assert isinstance(got, jax.Array) == as_jax
    arr = np.asarray(got)
    assert arr.dtype == dt                           # never silently promoted
    assert arr.tobytes() == a.tobytes()              # bit-for-bit
    assert back["dtype"] == dt


@settings(max_examples=12)
@given(seed=st.integers(0, 2 ** 20))
def test_snapshot_store_stats_roundtrip(seed):
    rng = np.random.default_rng(seed)
    stats = StoreStats(**{f.name: int(rng.integers(0, 2 ** 40))
                          for f in dataclasses.fields(StoreStats)})
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.ckpt")
        save_snapshot(path, {"stats": stats})
        back = load_snapshot(path)["stats"]
    assert isinstance(back, StoreStats) and back == stats


@settings(max_examples=10)
@given(shards=st.integers(1, 4), extra=st.integers(0, 5))
def test_snapshot_coding_scheme_roundtrip(shards, extra):
    scheme = CodingScheme(num_shards=shards, num_clients=shards + extra)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "s.ckpt")
        save_snapshot(path, {"scheme": scheme})
        back = load_snapshot(path)["scheme"]
    assert back.num_shards == shards
    assert back.num_clients == shards + extra
    assert back.alpha.dtype == scheme.alpha.dtype
    assert back.alpha.tobytes() == scheme.alpha.tobytes()
    assert back.omega.tobytes() == scheme.omega.tobytes()


@settings(max_examples=10)
@given(n=st.integers(1, 12), cut=st.floats(min_value=0.05, max_value=0.95))
def test_journal_torn_tail_property(n, cut):
    events = [{"ev": "e", "i": i} for i in range(n)]
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "j.wal")
        j = Journal(path)
        for e in events:
            j.append(e)
        j.close()
        lines = open(path, "rb").read().splitlines(keepends=True)
        torn = lines[-1][: max(1, int(len(lines[-1]) * cut))]
        with open(path, "wb") as f:
            f.writelines(lines[:-1])
            f.write(torn)
        got = [r["ev"] for r in replay(path)]
    # a journal line is ~40+ bytes, so cut<=0.95 always tears the record
    assert got == events[:-1]
