"""Chaos-harness tests: deterministic fault injection, coded quorum-read
recovery, service retry/backoff/abort, and degraded-mode training.

Property-style anchors (the PR's acceptance criteria):

* faults within the code's budget (<= ``max_errors`` corruptions, erasures
  leaving >= S slices) recover — *bit-identically* when they spare the
  canonical ``CodingScheme.quorum()`` read subset;
* faults beyond the budget fail loudly with the typed
  ``CodingBudgetExceeded`` (never a silent mis-decode);
* a chaotic serve completes with models bit-identical to the fault-free
  serve while ``ServiceReport``/``StoreStats`` record nonzero
  recoveries/retries, and replaying the same plan seed reproduces the
  identical fault ledger.

The fault seed is env-overridable (``REPRO_FAULT_SEED``) so the CI chaos
job can pin it explicitly.
"""
import dataclasses
import json
import os
import subprocess
import sys
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.stores.store import CodedStore, RoundPayload, StoreStats
from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.core import coding
from repro.core.coding import CodingBudgetExceeded, CodingScheme
from repro.data import client_datasets_images, make_image_data
from repro.faults import (INJECTORS, DegradedModeEvent, FaultInjector,
                          FaultLedger, FaultPlan, RecoveryEvent,
                          TransientJobError, chaos_plan, make_injector,
                          register_injector)
from repro.fl import FLSimulator
from repro.fl.experiment import FederatedSession
from repro.service import (DevicePlacement, LedgerEntry, RetryPolicy,
                           ServiceReport, UnlearningService, sequenced_trace,
                           single_device_placement)

FAULT_SEED = int(os.environ.get("REPRO_FAULT_SEED", "7"))

FL_TINY = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim(seed=0):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _scheme(c=12, s=4):
    return CodingScheme(num_shards=s, num_clients=c)


def _coded(c=12, s=4, p=33, seed=0):
    sch = _scheme(c, s)
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.standard_normal((s, p)), jnp.float32)
    return sch, w, coding.encode(sch, w)


# ---------------------------------------------------------------- coding
class TestQuorumRecovery:
    def test_quorum_is_the_decode_subset(self):
        sch = _scheme()
        q = sch.quorum()
        assert len(q) == sch.num_shards
        assert set(int(i) for i in q) <= set(range(sch.num_clients))
        _, ids = sch.decode_matrix(list(range(sch.num_clients)))
        assert list(q) == [int(i) for i in ids]

    def test_erasure_sparing_quorum_is_bit_identical(self):
        sch, w, slices = _coded()
        w0 = coding.decode_erasure(sch, slices, list(range(12)))
        spare = [i for i in range(12) if i not in set(sch.quorum())][:3]
        avail = [i for i in range(12) if i not in spare]
        w1, lost, bad = coding.decode_robust(sch, slices, available=avail)
        assert lost == spare and bad == []
        np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))

    def test_corruption_sparing_quorum_is_bit_identical(self):
        sch, w, slices = _coded()
        w0 = coding.decode_erasure(sch, slices, list(range(12)))
        hit = [i for i in range(12) if i not in set(sch.quorum())][:2]
        sl = slices.at[jnp.asarray(hit)].add(10.0)
        w1, lost, bad = coding.decode_robust(sch, sl)
        assert lost == [] and bad == hit
        np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))

    def test_corruption_hitting_quorum_still_recovers(self):
        sch, w, slices = _coded()
        hit = int(sch.quorum()[0])
        sl = slices.at[hit].add(10.0)
        w1, lost, bad = coding.decode_robust(sch, sl)
        assert bad == [hit]
        np.testing.assert_allclose(np.asarray(w1), np.asarray(w),
                                   atol=1e-3, rtol=1e-3)

    def test_combined_erasure_and_corruption_reduced_scheme(self):
        # 2 erased + 2 corrupted on C=12, S=4: the reduced (S=4, C=10) code
        # still has budget (10-4)//2 = 3 >= 2
        sch, w, slices = _coded()
        others = [i for i in range(12) if i not in set(sch.quorum())]
        lost_t, bad_t = others[:2], others[2:4]
        sl = slices.at[jnp.asarray(bad_t)].add(10.0)
        avail = [i for i in range(12) if i not in lost_t]
        w1, lost, bad = coding.decode_robust(sch, sl, available=avail)
        assert lost == lost_t and bad == bad_t
        w0 = coding.decode_erasure(sch, slices, list(range(12)))
        np.testing.assert_array_equal(np.asarray(w0), np.asarray(w1))

    def test_reduced_scheme_budget_tightens(self):
        sch = _scheme(12, 4)
        assert sch.max_errors == 4
        assert sch.reduced(range(8)).max_errors == 2
        assert sch.reduced(range(4)).max_errors == 0


class TestCodingBudgetExceeded:
    def test_locate_errors_names_budget_and_observed(self):
        sch, w, slices = _coded(10, 4)        # max_errors = 3
        sl = np.asarray(slices, np.float64)
        sl[:4] += 10.0
        with pytest.raises(CodingBudgetExceeded,
                           match=r"count 4 exceeds the correctable budget "
                                 r"max_errors=3") as ei:
            coding.locate_errors(sch, sl)
        assert ei.value.observed == 4 and ei.value.max_errors == 3

    def test_decode_with_errors_budget_raise(self):
        sch, w, slices = _coded(10, 4)
        sl = slices + jnp.where(jnp.arange(10)[:, None] < 4, 10.0, 0.0)
        with pytest.raises(CodingBudgetExceeded, match="max_errors=3"):
            coding.decode_with_errors(sch, sl)

    def test_too_few_available_raises_erasure_kind(self):
        sch, w, slices = _coded(12, 4)
        with pytest.raises(CodingBudgetExceeded,
                           match="erased slices count 9"):
            coding.decode_robust(sch, slices, available=[0, 1, 2])

    def test_zero_budget_scheme_detects_corruption(self):
        # C = S + 1: corruption is detectable (one redundant point) but
        # max_errors = 0 — the read must fail loudly, never mis-decode.
        # (At C == S every vector is a codeword; corruption is invisible.)
        sch, w, slices = _coded(5, 4)
        sl = slices.at[2].add(10.0)
        with pytest.raises(CodingBudgetExceeded, match="max_errors=0"):
            coding.decode_robust(sch, sl)

    def test_within_budget_does_not_raise(self):
        sch, w, slices = _coded(10, 4)
        sl = slices.at[jnp.asarray([1, 5, 8])].add(10.0)
        w1, bad = coding.decode_with_errors(sch, sl)
        assert list(bad) == [1, 5, 8]


# ------------------------------------------------------------- fault plans
class TestFaultPlanRegistry:
    def test_builtin_injectors_registered(self):
        for name in ("client_dropout", "straggler", "slice_erasure",
                     "slice_corruption", "device_failure", "device_hang",
                     "job_exception"):
            assert name in INJECTORS

    def test_unknown_injector_raises(self):
        with pytest.raises(ValueError, match="unknown fault injector"):
            make_injector("nope")

    def test_custom_injector_registers(self):
        @register_injector("_test_noop")
        class _Noop(FaultInjector):
            pass
        assert isinstance(make_injector("_test_noop"), _Noop)

    def test_chaos_plan_builder(self):
        plan = chaos_plan(seed=3, corrupt=1, erase=1, job_rate=0.5,
                          dead_device=0, dropout=0.1)
        names = [i.name for i in plan.injectors]
        assert names == ["slice_corruption", "slice_erasure",
                         "job_exception", "device_failure", "client_dropout"]
        assert plan.describe()["seed"] == 3


class TestFaultPlanDeterminism:
    def test_site_rng_is_pure_function_of_seed_and_site(self):
        a = FaultPlan(seed=FAULT_SEED).rng("x", 1, (2, 3)).random(4)
        b = FaultPlan(seed=FAULT_SEED).rng("x", 1, (2, 3)).random(4)
        c = FaultPlan(seed=FAULT_SEED).rng("x", 2, (2, 3)).random(4)
        np.testing.assert_array_equal(a, b)
        assert not np.array_equal(a, c)

    def test_slice_faults_replay_identically(self):
        sch = _scheme()
        p1 = FaultPlan(seed=FAULT_SEED).add("slice_corruption", count=2)
        p2 = FaultPlan(seed=FAULT_SEED).add("slice_corruption", count=2)
        l1, n1 = p1.slice_faults(3, sch, width=7)
        l2, n2 = p2.slice_faults(3, sch, width=7)
        assert l1 == l2 and sorted(n1) == sorted(n2)
        for r in n1:
            np.testing.assert_array_equal(n1[r], n2[r])
        # and a second read of the SAME round sees the SAME fault
        l3, n3 = p1.slice_faults(3, sch, width=7)
        assert l3 == l1 and sorted(n3) == sorted(n1)

    def test_spare_quorum_never_hits_the_read_set(self):
        sch = _scheme()
        q = set(int(i) for i in sch.quorum())
        plan = FaultPlan(seed=FAULT_SEED).add("slice_erasure", count=3)
        for rnd in range(20):
            lost, _ = plan.slice_faults(rnd, sch, width=5)
            assert not (set(lost) & q)

    def test_job_exception_is_job_keyed_not_attempt_keyed(self):
        plan = FaultPlan(seed=FAULT_SEED).add("job_exception", rate=1.0,
                                              fail_attempts=2)
        key = ("shard", 0, 1, (5,))
        _, e1 = plan.job_action(key, 1, device=0)
        _, e2 = plan.job_action(key, 2, device=3)   # other device, same job
        _, e3 = plan.job_action(key, 3, device=0)   # beyond fail_attempts
        assert isinstance(e1, TransientJobError)
        assert isinstance(e2, TransientJobError)
        assert e3 is None

    def test_straggler_delays_first_attempt_only(self):
        plan = FaultPlan(seed=FAULT_SEED).add("straggler", rate=1.0,
                                              delay_s=0.5)
        d1, e1 = plan.job_action(("j",), 1, device=0)
        d2, e2 = plan.job_action(("j",), 2, device=0)
        assert d1 == 0.5 and e1 is None
        assert d2 == 0.0 and e2 is None

    def test_ledger_signature_is_thread_order_independent(self):
        ev = [RecoveryEvent("retry", site=("j", i)) for i in range(5)]
        a, b = FaultLedger(), FaultLedger()
        for e in ev:
            a.record(e)
        for e in reversed(ev):
            b.record(e)
        assert a.signature() == b.signature()
        assert a.count("retry") == 5 and a.kinds() == {"retry": 5}

    def test_client_dropout_keeps_min_keep(self):
        plan = FaultPlan(seed=FAULT_SEED).add("client_dropout", rate=1.0,
                                              min_keep=1)
        shard_clients = {0: [1, 2, 3], 1: [4, 5]}
        dropped = plan.dropped_clients(0, shard_clients)
        assert len(dropped[0]) == 2 and len(dropped[1]) == 1


# ------------------------------------------------------------ coded store
class TestCodedStoreQuorumReads:
    def _store(self, plan=None, c=12, s=4):
        sch = _scheme(c, s)
        per = c // s
        shard_clients = {i: list(range(i * per, (i + 1) * per))
                         for i in range(s)}
        store = CodedStore(sch, shard_clients)
        rng = np.random.default_rng(1)
        params = {cl: {"w": jnp.asarray(rng.standard_normal(5), jnp.float32)}
                  for cl in range(c)}
        store.put_round(RoundPayload.from_clients(0, shard_clients, params))
        if plan is not None:
            store.attach_faults(plan)
        return store

    def test_faulted_read_is_bit_identical_and_accounted(self):
        base = self._store().get_shard(0, 1)
        plan = FaultPlan(seed=FAULT_SEED).add("slice_corruption", count=2)
        store = self._store(plan)
        got = store.get_shard(0, 1)
        for cl in base:
            _trees_equal(base[cl], got[cl])
        assert store.stats.reads == 1
        assert store.stats.recovered_reads == 1
        assert store.stats.corrupted_slices == 2
        assert plan.ledger.count("quorum_read") == 1

    def test_erasure_plan_recovers(self):
        base = self._store().get_shard(0, 0)
        plan = FaultPlan(seed=FAULT_SEED).add("slice_erasure", count=3)
        store = self._store(plan)
        got = store.get_shard(0, 0)
        for cl in base:
            _trees_equal(base[cl], got[cl])
        assert store.stats.erased_slices == 3

    def test_budget_exceeded_read_fails_typed_and_counted(self):
        # C=8, S=4: max_errors = 2 but 3 slices corrupted
        plan = FaultPlan(seed=FAULT_SEED).add("slice_corruption", count=3,
                                              spare_quorum=False)
        store = self._store(plan, c=8, s=4)
        with pytest.raises(CodingBudgetExceeded):
            store.get_shard(0, 0)
        assert store.stats.failed_reads == 1

    def test_legacy_available_and_corrupt_args_still_work(self):
        store = self._store()
        base = store.get_shard(0, 1)
        q = set(int(i) for i in store.scheme.quorum())
        avail = [i for i in range(12) if i in q or i % 2 == 0]
        got = store.get_shard(0, 1, available=avail)
        for cl in base:
            _trees_equal(base[cl], got[cl])
        noise = np.zeros((12, store._slices[0].shape[1]), np.float32)
        noise[1] = 25.0
        got2 = store.get_shard(0, 1, corrupt=noise)
        for cl in base:
            np.testing.assert_allclose(
                np.asarray(got2[cl]["w"]), np.asarray(base[cl]["w"]),
                atol=1e-3)

    def test_concurrent_reads_decode_identically(self):
        """Satellite: corrupt one slice while two threads read the same
        shard through the RLock path — both must decode identically."""
        plan = FaultPlan(seed=FAULT_SEED).add("slice_corruption", count=1)
        store = self._store(plan)
        base = self._store().get_shard(0, 2)
        barrier = threading.Barrier(2)
        results, errors = [None, None], []

        def read(i):
            try:
                barrier.wait(timeout=10)
                results[i] = store.get_shard(0, 2)
            except Exception as exc:          # pragma: no cover
                errors.append(exc)

        threads = [threading.Thread(target=read, args=(i,)) for i in (0, 1)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=60)
        assert not errors
        for cl in base:
            _trees_equal(results[0][cl], results[1][cl])
            _trees_equal(base[cl], results[0][cl])
        assert store.stats.reads == 2
        assert store.stats.recovered_reads == 2   # same injected fault twice

    def test_stats_merge_includes_recovery_counters(self):
        a = StoreStats(reads=2, recovered_reads=1, erased_slices=3,
                       corrupted_slices=1, failed_reads=0)
        b = StoreStats(reads=1, failed_reads=2)
        c = a + b
        assert (c.reads, c.recovered_reads, c.failed_reads) == (3, 1, 2)


# -------------------------------------------------------------- placement
class TestPlacementSatellites:
    def test_context_manager_shuts_down_pool(self):
        with DevicePlacement(max_workers=1) as p:
            assert p.submit(lambda: 41 + 1).result() == 42
            assert p._pool is not None
        assert p._pool is None

    def test_shutdown_is_idempotent(self):
        p = DevicePlacement(max_workers=1)
        p.submit(lambda: None).result()
        p.shutdown()
        p.shutdown()                      # second call is a clean no-op
        assert p._pool is None

    def test_exit_shuts_down_even_when_body_raises(self):
        p = DevicePlacement(max_workers=1)
        with pytest.raises(RuntimeError, match="boom"):
            with p:
                p.submit(lambda: None).result()
                raise RuntimeError("boom")
        assert p._pool is None

    def test_reassign_skips_unhealthy_deterministically(self):
        p = DevicePlacement(devices=[object(), object(), object()])
        p.mark_unhealthy(1)
        assert p.reassign(0) == 2         # 1 is skipped
        assert p.reassign(1) == 2
        assert p.describe()["unhealthy"] == [1]
        # every device down: never raises, returns the avoided index
        p.mark_unhealthy(0)
        p.mark_unhealthy(2)
        assert p.reassign(0) == 0
        p.reset_health()
        assert p.reassign(0) == 1
        assert p.describe()["unhealthy"] == []

    def test_assign_stays_round_robin_under_faults(self):
        p = DevicePlacement(devices=[object(), object()])
        p.mark_unhealthy(0)
        assert [p.assign() for _ in range(4)] == [0, 1, 0, 1]


# ---------------------------------------------------------- report guards
class TestServiceReportGuards:
    def test_empty_report_never_raises(self):
        rep = ServiceReport()
        assert np.isnan(rep.percentile(50))
        assert np.isnan(rep.p95)
        assert np.isnan(rep.throughput)
        assert rep.sla_hit_rate is None
        assert rep.num_aborted == 0
        json.dumps(rep.to_dict())         # serializable end to end

    def test_all_aborted_ledger_guards(self):
        rep = ServiceReport(serve_wall=1.0)
        rep.entries = [LedgerEntry(rid=i, arrival=0.0, clients=(i,),
                                   framework="SE", batch_id=0, latency=1.0,
                                   aborted=True) for i in range(3)]
        assert rep.completed == []
        assert np.isnan(rep.p50)
        assert np.isnan(rep.throughput)
        assert rep.sla_hit_rate is None
        assert rep.num_aborted == 3
        assert rep.to_dict()["num_aborted"] == 3

    def test_completed_entries_keep_finite_aggregates(self):
        rep = ServiceReport(serve_wall=2.0)
        rep.entries = [
            LedgerEntry(rid=0, arrival=0.0, clients=(0,), framework="SE",
                        batch_id=0, latency=1.0, sla_met=True),
            LedgerEntry(rid=1, arrival=0.0, clients=(1,), framework="SE",
                        batch_id=0, latency=3.0, aborted=True),
        ]
        assert rep.percentile(50) == 1.0  # aborted entry excluded
        assert rep.throughput == 0.5
        assert rep.sla_hit_rate == 1.0

    def test_retry_policy_backoff_is_bounded(self):
        rp = RetryPolicy(backoff=0.1, backoff_factor=2.0, max_backoff=0.35)
        assert rp.backoff_for(1) == pytest.approx(0.1)
        assert rp.backoff_for(2) == pytest.approx(0.2)
        assert rp.backoff_for(3) == pytest.approx(0.35)
        assert rp.backoff_for(9) == pytest.approx(0.35)


# ----------------------------------------------------- degraded training
class TestDegradedTraining:
    def test_dropout_degrades_stage_engine_with_event(self):
        @register_injector("_test_drop_first_of_shard0")
        class _DropOne(FaultInjector):
            def stage_dropout(self, plan, stage, shard_clients):
                s = sorted(shard_clients)[0]
                return {s: [shard_clients[s][0]]}

        plan = FaultPlan(seed=FAULT_SEED).add("_test_drop_first_of_shard0")
        sess = FederatedSession(_tiny_sim(), store_kind="coded",
                                engine="stage", faults=plan)
        record = sess.run_stage()
        sizes = sorted(len(cs) for cs in record.plan.shard_clients.values())
        assert sizes == [3, 4]            # one client gone -> ragged stage
        degraded = [e for e in plan.ledger.events
                    if isinstance(e, DegradedModeEvent)]
        assert len(degraded) == 1
        assert degraded[0].fallback == "fused"
        assert degraded[0].reason == "ragged_stage"
        assert len(degraded[0].dropped_clients) == 1
        assert plan.ledger.count("client_dropout") == 1
        # training still lands a full record: every shard has a model
        assert set(record.shard_models) == set(record.plan.shard_clients)

    def test_seeded_dropout_replays_identically(self):
        shard_clients = {0: [1, 2, 3, 4], 1: [5, 6, 7, 8]}
        d1 = FaultPlan(seed=FAULT_SEED).add(
            "client_dropout", rate=0.5).dropped_clients(2, shard_clients)
        d2 = FaultPlan(seed=FAULT_SEED).add(
            "client_dropout", rate=0.5).dropped_clients(2, shard_clients)
        assert d1 == d2


# --------------------------------------------------------- chaotic serves
@pytest.fixture(scope="module")
def trained_session():
    sess = FederatedSession(_tiny_sim(), store_kind="coded", engine="fused")
    sess.run_stage()
    return sess


def _serve(session, plan, trace=None, retry=None):
    svc = UnlearningService(session, policy="fifo",
                            placement=single_device_placement(),
                            faults=plan,
                            retry=retry or RetryPolicy(backoff=0.001))
    trace = trace or sequenced_trace([session.records[0].plan.clients[0]],
                                     spacing=0.1)
    try:
        report = svc.serve(trace)
    finally:
        svc.placement.shutdown()
        for rec in session.records:       # detach for the next scenario
            if hasattr(rec.store, "attach_faults"):
                rec.store.attach_faults(None)
    models = {s: jax.device_get(w) for s, w in
              session.report.stages[0].unlearn[-1].models.items()}
    return report, models


def _chaotic_plan():
    return (FaultPlan(seed=FAULT_SEED)
            .add("slice_corruption", count=2, scale=10.0)
            .add("job_exception", rate=1.0, fail_attempts=1))


class TestChaoticServe:
    def test_chaotic_serve_bit_identical_with_nonzero_recoveries(
            self, trained_session):
        """Acceptance anchor: <= max_errors corruptions + transient job
        failures -> the served trace completes with models bit-identical to
        the fault-free serve, and the report records the recovery work."""
        rep0, m0 = _serve(trained_session, None)
        rep1, m1 = _serve(trained_session, _chaotic_plan())
        assert set(m0) == set(m1)
        for s in m0:
            _trees_equal(m0[s], m1[s])
        assert rep1.faults["retries"] > 0
        assert rep1.faults["recoveries"] > 0
        assert rep1.faults["aborts"] == 0
        assert all(e.job_retries > 0 and not e.aborted for e in rep1.entries)
        assert rep0.faults["retries"] == 0 and rep0.faults["recoveries"] == 0

    def test_same_seed_replays_identical_ledger(self, trained_session):
        p1, p2 = _chaotic_plan(), _chaotic_plan()
        _serve(trained_session, p1)
        _serve(trained_session, p2)
        sig1, sig2 = p1.ledger.signature(), p2.ledger.signature()
        assert sig1 and sig1 == sig2
        other = (FaultPlan(seed=FAULT_SEED + 1)
                 .add("slice_corruption", count=2, scale=10.0)
                 .add("job_exception", rate=1.0, fail_attempts=1))
        _serve(trained_session, other)
        assert other.ledger.signature() != sig1

    def test_retry_budget_exhaustion_aborts_cleanly(self, trained_session):
        plan = FaultPlan(seed=FAULT_SEED).add("job_exception", rate=1.0,
                                              fail_attempts=99)
        rep, _m = _serve(trained_session, plan,
                         retry=RetryPolicy(max_retries=1, backoff=0.001))
        assert rep.faults["aborts"] > 0
        assert all(e.aborted for e in rep.entries)
        assert rep.num_aborted == len(rep.entries)
        assert np.isnan(rep.p50) and np.isnan(rep.throughput)
        assert plan.ledger.count("abort") > 0
        assert plan.ledger.count("retry") > 0

    def test_report_json_roundtrips_with_fault_summary(self, trained_session):
        rep, _m = _serve(trained_session, _chaotic_plan())
        d = json.loads(rep.to_json())
        assert d["faults"]["retries"] >= 1
        assert d["faults"]["recoveries"] >= 1
        assert d["requests"]["svc-0"]["job_attempts"] >= 2
        assert d["num_aborted"] == 0


# ------------------------------------------------- device-kill (4 devices)
class TestDeviceFailureMultiDevice:
    def test_device_kill_mid_serve_all_requests_complete(self):
        """Kill one of 4 virtual devices: every request still completes
        with models matching the healthy serve, the dead device is marked
        unhealthy, and retries re-dispatch deterministically.  Subprocess
        because XLA_FLAGS must be set before jax initializes."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ["src", env.get("PYTHONPATH", "")] if p)
        env.setdefault("REPRO_FAULT_SEED", str(FAULT_SEED))
        child = os.path.join(os.path.dirname(__file__),
                             "_faults_chaos_child.py")
        proc = subprocess.run(
            [sys.executable, child], env=env,
            cwd=os.path.dirname(os.path.dirname(os.path.abspath(child))),
            capture_output=True, text=True, timeout=560)
        assert proc.returncode == 0, proc.stderr[-2000:]
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["num_devices"] == 4
        assert out["models_bit_identical"]
        assert out["aborts"] == 0
        assert out["retries"] > 0
        assert out["unhealthy"] == [0]
        assert out["ledger_replay_identical"]
