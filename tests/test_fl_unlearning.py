"""End-to-end federated learning + unlearning tests (paper Sec 5 protocol at
reduced scale): SE/FE/FR/RR all produce finite working models, SE touches only
the impacted shard, the coded store round-trips through training, and the
theory formulas match Monte-Carlo."""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import FLConfig, OptimizerConfig, get_config, reduce_for_smoke
from repro.core import theory, unlearning
from repro.core.sharding import ShardManager, adaptive_requests, even_requests
from repro.data import client_datasets_images, make_image_data
from repro.fl import FLSimulator
from repro.fl.mia import mia_f1

FL_SMALL = FLConfig(num_clients=12, clients_per_round=8, num_shards=2,
                    local_epochs=4, global_rounds=4, retrain_ratio=2.0)


@pytest.fixture(scope="module")
def sim():
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=12,
                              d_model=32, cnn_channels=(4, 8))
    data = make_image_data(12 * 40, image_size=12, seed=0)
    clients = client_datasets_images(data, FL_SMALL.num_clients, iid=True)
    # lr=0.1: the smoke config cuts the paper's training budget (L=10, G=30
    # -> 4, 4), and sgdm at the paper's lr=0.05 is still far from converged
    # at that budget (acc 0.27 vs the 0.3 learning bar). Doubling the lr
    # compensates for the reduced epoch count and trains stably (loss 1.98
    # -> 1.79, acc 0.37); 0.15+ starts to diverge on this config.
    s = FLSimulator(cfg, FL_SMALL, clients, task="image",
                    opt_cfg=OptimizerConfig(name="sgdm", lr=0.1, grad_clip=0.0),
                    local_batch=10)
    return s


@pytest.fixture(scope="module")
def record(sim):
    return sim.train_stage(store_kind="coded")


def test_training_learns(sim, record):
    test = make_image_data(400, image_size=12, seed=99)
    m = sim.evaluate(record.shard_models, test.images, test.labels)
    assert m["acc"] > 0.3, f"shard-ensemble failed to learn: {m}"


@pytest.mark.parametrize("fw", ["SE", "FE", "FR", "RR"])
def test_unlearning_frameworks_run(sim, record, fw):
    victim = record.plan.shard_clients[0][0]
    res = sim.unlearn(fw, record, [victim], rounds=2)
    leaves = jax.tree.leaves(list(res.models.values())[0])
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)
    assert res.cost_units > 0
    if fw == "SE":
        assert res.impacted_shards == [0]
        # untouched shard model must be bit-identical (isolation!)
        for a, b in zip(jax.tree.leaves(record.shard_models[1]),
                        jax.tree.leaves(res.models[1])):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_se_cost_below_fr(sim, record):
    victim = record.plan.shard_clients[0][0]
    se = sim.unlearn("SE", record, [victim], rounds=2)
    fr = sim.unlearn("FR", record, [victim], rounds=2)
    assert se.cost_units < fr.cost_units, (se.cost_units, fr.cost_units)


def test_coded_store_erasure_during_unlearning(sim, record):
    """Unlearning still works when only a subset of slices is reachable."""
    victim = record.plan.shard_clients[0][0]
    avail = list(range(FL_SMALL.clients_per_round))[:FL_SMALL.num_shards + 1]
    res = sim.unlearn("SE", record, [victim], rounds=1, available=avail)
    leaves = jax.tree.leaves(res.models[0])
    assert all(np.all(np.isfinite(np.asarray(l, np.float32))) for l in leaves)


def test_mia_f1_in_range(sim, record):
    test = make_image_data(300, image_size=12, seed=123)
    victim = record.plan.shard_clients[0][0]
    res = sim.unlearn("SE", record, [victim], rounds=2)
    member_ids = [c for c in record.plan.clients if c != victim][:4]
    mx = np.concatenate([sim.client_data[c][0][:40] for c in member_ids])
    my = np.concatenate([sim.client_data[c][1][:40] for c in member_ids])
    f1 = mia_f1(sim._pf, res.models, sim._make_batch, "image",
                (mx, my), (test.images, test.labels),
                sim.client_data[victim])
    assert 0.0 <= f1 <= 1.0


def test_request_patterns():
    mgr = ShardManager(100, 4, 20, seed=0)
    plan = mgr.new_stage()
    ev = even_requests(plan, 4)
    assert len({plan.shard_of(c) for c in ev}) == 4   # spread over all shards
    ad = adaptive_requests(plan, 3)
    assert len({plan.shard_of(c) for c in ad}) == 1   # concentrated
    assert mgr.impacted_shards(plan, ad) == {plan.shard_of(ad[0])}


def test_theory_matches_montecarlo():
    s, k, ct = 4, 6, 2.5
    assert abs(theory.sequential_time(s, k, ct)
               - theory.mc_sequential_time(s, k, ct)) < 1e-6
    analytic = theory.concurrent_time(s, k, ct)
    mc = theory.mc_concurrent_time(s, k, ct)
    assert abs(analytic - mc) / analytic < 0.02
    lo, hi = theory.storage_efficiency_bounds(100, 4, 0.1)
    assert lo == 4 and abs(hi - 80.0) < 1e-9
    assert theory.coded_throughput(100, 8) > theory.coded_throughput(100, 4)


def test_calibration_eq3_algebra():
    """eq (3): the calibrated update has the historical norm, new direction."""
    w = {"a": np.zeros(4, np.float32)}
    new_delta = {"a": np.asarray([0.0, 3.0, 0.0, 4.0], np.float32)}  # norm 5
    old_delta = {"a": np.asarray([10.0, 0.0, 0.0, 0.0], np.float32)}  # norm 10
    out = unlearning.calibrate(w, [new_delta], [old_delta])
    got = np.asarray(out["a"])
    np.testing.assert_allclose(np.linalg.norm(got), 10.0, rtol=1e-5)
    np.testing.assert_allclose(got / np.linalg.norm(got),
                               np.asarray(new_delta["a"]) / 5.0, rtol=1e-5)
