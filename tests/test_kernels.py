"""Pallas kernel validation (interpret mode on CPU) against pure-jnp oracles,
with hypothesis shape/dtype sweeps as required for every kernel."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.kernels.calibrate.ops import calibrate_update
from repro.kernels.calibrate.ref import calibrate_update_ref
from repro.kernels.coded_matmul.ops import coded_matmul
from repro.kernels.coded_matmul.ref import coded_matmul_ref
from repro.kernels.window_attn.ops import window_attention
from repro.kernels.window_attn.ref import window_attention_ref


# ---------------------------------------------------------------- coded_matmul
class TestCodedMatmul:
    @pytest.mark.parametrize("c,s,p", [(20, 4, 1000), (100, 4, 4096),
                                       (7, 3, 17), (128, 8, 8192)])
    def test_matches_ref(self, c, s, p):
        rng = np.random.default_rng(c + p)
        b = jnp.asarray(rng.standard_normal((c, s)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((s, p)), jnp.float32)
        np.testing.assert_allclose(np.asarray(coded_matmul(b, w)),
                                   np.asarray(coded_matmul_ref(b, w)),
                                   rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_dtypes(self, dtype):
        rng = np.random.default_rng(0)
        b = jnp.asarray(rng.standard_normal((16, 4)), dtype)
        w = jnp.asarray(rng.standard_normal((4, 300)), dtype)
        out = coded_matmul(b, w)
        ref = coded_matmul_ref(b.astype(jnp.float32), w.astype(jnp.float32))
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-2, atol=2e-2)

    @settings(max_examples=15, deadline=None)
    @given(c=st.integers(1, 64), s=st.integers(1, 12), p=st.integers(1, 600),
           seed=st.integers(0, 99))
    def test_property_shapes(self, c, s, p, seed):
        rng = np.random.default_rng(seed)
        b = jnp.asarray(rng.standard_normal((c, s)), jnp.float32)
        w = jnp.asarray(rng.standard_normal((s, p)), jnp.float32)
        np.testing.assert_allclose(np.asarray(coded_matmul(b, w)),
                                   np.asarray(coded_matmul_ref(b, w)),
                                   rtol=1e-4, atol=1e-4)

    def test_encode_decode_through_kernel(self):
        """The coding layer's use_kernel path reconstructs exactly."""
        from repro.core import coding
        sch = coding.CodingScheme(num_shards=4, num_clients=20)
        rng = np.random.default_rng(5)
        w = jnp.asarray(rng.standard_normal((4, 513)), jnp.float32)
        slices = coding.encode(sch, w, use_kernel=True)
        out = coding.decode_erasure(sch, slices, list(range(20)),
                                    use_kernel=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)


# ------------------------------------------------------------------ calibrate
class TestCalibrate:
    @pytest.mark.parametrize("m,p", [(4, 1000), (5, 8192), (1, 33), (16, 100000)])
    def test_matches_ref(self, m, p):
        rng = np.random.default_rng(m * p)
        w = jnp.asarray(rng.standard_normal(p), jnp.float32)
        d = jnp.asarray(rng.standard_normal((m, p)), jnp.float32)
        c = jnp.asarray(rng.standard_normal(m), jnp.float32)
        np.testing.assert_allclose(np.asarray(calibrate_update(w, d, c)),
                                   np.asarray(calibrate_update_ref(w, d, c)),
                                   rtol=1e-4, atol=1e-4)

    @settings(max_examples=15, deadline=None)
    @given(m=st.integers(1, 10), p=st.integers(1, 3000), seed=st.integers(0, 99))
    def test_property_shapes(self, m, p, seed):
        rng = np.random.default_rng(seed)
        w = jnp.asarray(rng.standard_normal(p), jnp.float32)
        d = jnp.asarray(rng.standard_normal((m, p)), jnp.float32)
        c = jnp.asarray(rng.standard_normal(m), jnp.float32)
        np.testing.assert_allclose(np.asarray(calibrate_update(w, d, c)),
                                   np.asarray(calibrate_update_ref(w, d, c)),
                                   rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------- window_attn
class TestWindowAttention:
    @pytest.mark.parametrize("b,s,h,kv,hd,window", [
        (1, 256, 2, 2, 64, 128),
        (2, 512, 4, 2, 64, 100),     # GQA + non-multiple window
        (1, 384, 2, 1, 128, 256),    # padding path (384 % 256 != 0)
    ])
    def test_matches_ref(self, b, s, h, kv, hd, window):
        rng = np.random.default_rng(s + window)
        q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
        out = window_attention(q, k, v, window, blk=128)
        g = h // kv
        k_e = jnp.repeat(k, g, axis=2)
        v_e = jnp.repeat(v, g, axis=2)
        qt = q.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        kt = k_e.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        vt = v_e.transpose(0, 2, 1, 3).reshape(b * h, s, hd)
        ref = window_attention_ref(qt, kt, vt, window)
        ref = ref.reshape(b, h, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)

    def test_matches_model_local_attention(self):
        """Kernel agrees with the model's lax sliding-window path."""
        from repro.models.attention import local_blockwise_attention
        rng = np.random.default_rng(7)
        b, s, h, kv, hd, window = 1, 512, 4, 2, 64, 128
        q = jnp.asarray(rng.standard_normal((b, s, h, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((b, s, kv, hd)), jnp.float32)
        a = window_attention(q, k, v, window, blk=128)
        b_ = local_blockwise_attention(q, k, v, window=window, block_q=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                                   rtol=2e-3, atol=2e-3)

    @settings(max_examples=8, deadline=None)
    @given(s=st.sampled_from([128, 256, 384]),
           window=st.integers(16, 300),
           hd=st.sampled_from([64, 128]),
           seed=st.integers(0, 50))
    def test_property(self, s, window, hd, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((1, s, 2, hd)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, s, 2, hd)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, s, 2, hd)), jnp.float32)
        out = window_attention(q, k, v, window, blk=128)
        qt = q.transpose(0, 2, 1, 3).reshape(2, s, hd)
        kt = k.transpose(0, 2, 1, 3).reshape(2, s, hd)
        vt = v.transpose(0, 2, 1, 3).reshape(2, s, hd)
        ref = window_attention_ref(qt, kt, vt, window) \
            .reshape(1, 2, s, hd).transpose(0, 2, 1, 3)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   rtol=2e-3, atol=2e-3)


# ------------------------------------------------------------------- ssm_scan
class TestSsmScan:
    def _inputs(self, bsz, s, d, n, seed=0):
        rng = np.random.default_rng(seed)
        dt = jnp.asarray(np.abs(rng.standard_normal((bsz, s, d))) * 0.1 + 0.01,
                         jnp.float32)
        b = jnp.asarray(rng.standard_normal((bsz, s, n)), jnp.float32)
        c = jnp.asarray(rng.standard_normal((bsz, s, n)), jnp.float32)
        x = jnp.asarray(rng.standard_normal((bsz, s, d)), jnp.float32)
        a = jnp.asarray(-np.abs(rng.standard_normal((d, n))) - 0.1, jnp.float32)
        h0 = jnp.asarray(rng.standard_normal((bsz, d, n)), jnp.float32) * 0.1
        return dt, b, c, x, a, h0

    @pytest.mark.parametrize("bsz,s,d,n", [(1, 32, 128, 16), (2, 64, 256, 16),
                                           (1, 48, 200, 8)])
    def test_matches_ref(self, bsz, s, d, n):
        from repro.kernels.ssm_scan.ops import ssm_scan
        from repro.kernels.ssm_scan.ref import ssm_scan_ref
        args = self._inputs(bsz, s, d, n)
        y, h = ssm_scan(*args, chunk=16, blk_d=128)
        yr, hr = ssm_scan_ref(*args)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h), np.asarray(hr),
                                   rtol=2e-4, atol=2e-4)

    def test_matches_chunked_model_path(self):
        """The production chunked scan (models/mamba) agrees with the same
        oracle — closing the loop kernel <-> model."""
        from repro.kernels.ssm_scan.ref import ssm_scan_ref
        from repro.models.mamba import _chunk_scan
        import jax
        rng = np.random.default_rng(3)
        bsz, s, d, n = 1, 32, 64, 8
        dt, b, c, x, a, h0 = self._inputs(bsz, s, d, n, seed=3)
        abar = jnp.exp(dt[..., None] * a)
        bu = dt[..., None] * b[:, :, None, :] * x[..., None]
        h_all, h_last = _chunk_scan(abar, bu, h0)
        y = jnp.einsum("bsn,bsdn->bsd", c, h_all)
        yr, hr = ssm_scan_ref(dt, b, c, x, a, h0)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-4, atol=2e-4)

    @settings(max_examples=6, deadline=None)
    @given(s=st.sampled_from([16, 40, 64]), d=st.sampled_from([64, 192]),
           seed=st.integers(0, 30))
    def test_property(self, s, d, seed):
        from repro.kernels.ssm_scan.ops import ssm_scan
        from repro.kernels.ssm_scan.ref import ssm_scan_ref
        args = self._inputs(1, s, d, 16, seed=seed)
        y, h = ssm_scan(*args, chunk=8, blk_d=64)
        yr, hr = ssm_scan_ref(*args)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=5e-4, atol=5e-4)


# ------------------------------------------------------------------------ wkv
class TestWkv:
    def _inputs(self, b, s, h, n, seed=0):
        rng = np.random.default_rng(seed)
        r = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32) * 0.3
        v = jnp.asarray(rng.standard_normal((b, s, h, n)), jnp.float32)
        lw = jnp.asarray(-np.abs(rng.standard_normal((b, s, h, n))) - 0.05,
                         jnp.float32)
        u = jnp.asarray(rng.standard_normal((h, n)), jnp.float32) * 0.5
        h0 = jnp.asarray(rng.standard_normal((b, h, n, n)), jnp.float32) * 0.1
        return r, k, v, lw, u, h0

    @pytest.mark.parametrize("b,s,h,n", [(1, 32, 2, 16), (2, 48, 1, 64)])
    def test_matches_ref(self, b, s, h, n):
        from repro.kernels.wkv.ops import wkv
        from repro.kernels.wkv.ref import wkv_ref
        r, k, v, lw, u, h0 = self._inputs(b, s, h, n)
        y, hl = wkv(r, k, v, lw, u, h0, chunk=16)
        for hi in range(h):
            yr, hr = wkv_ref(r[:, :, hi], k[:, :, hi], v[:, :, hi],
                             lw[:, :, hi], u[hi], h0[:, hi])
            np.testing.assert_allclose(np.asarray(y[:, :, hi]), np.asarray(yr),
                                       rtol=5e-4, atol=5e-4)
            np.testing.assert_allclose(np.asarray(hl[:, hi]), np.asarray(hr),
                                       rtol=5e-4, atol=5e-4)

    def test_matches_model_wkv_scan(self):
        """Kernel agrees with the chunk-parallel production path."""
        from repro.kernels.wkv.ops import wkv
        from repro.models.rwkv6 import wkv_scan
        r, k, v, lw, u, h0 = self._inputs(1, 64, 2, 16, seed=5)
        y1, h1 = wkv(r, k, v, lw, u, h0, chunk=16)
        y2, h2 = wkv_scan(r, k, v, lw, u, h0, chunk=16)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=1e-3, atol=1e-3)
