"""Unit coverage for the membership-inference attack (repro.fl.mia) —
previously exercised only through the table1 benchmark.

The tests drive ``mia_f1`` with a synthetic 'model' whose logits are embedded
directly in the inputs, so member/non-member separability (and hence the
expected attack outcome) is controlled exactly:

* a model that memorizes the forgotten client -> the attack flags its data as
  member -> high F1 (unlearning failed);
* a model whose forgotten-client outputs look like held-out data -> low F1
  (data actually forgotten).
"""
import jax.numpy as jnp
import numpy as np

from repro.fl import mia

K = 4          # classes
N = 120        # examples per split


def _predict(_model, batch):
    """Logits are carried verbatim in the first K input features."""
    x = batch["images"]
    return jnp.asarray(x[:, :K])


def _make_batch(x, y):
    return {"images": x, "labels": y}


def _member_like(rng, n=N, conf=6.0):
    """Confident, correct logits (low loss, low entropy) — training data."""
    y = rng.integers(0, K, n)
    x = rng.normal(0, 0.1, (n, K)).astype(np.float32)
    x[np.arange(n), y] += conf
    return x, y.astype(np.int64)


def _nonmember_like(rng, n=N):
    """Uninformative logits (high loss, high entropy) — held-out data."""
    y = rng.integers(0, K, n)
    x = rng.normal(0, 0.3, (n, K)).astype(np.float32)
    return x, y.astype(np.int64)


class TestFeatures:
    def test_shapes_and_signal(self):
        rng = np.random.default_rng(0)
        mx, my = _member_like(rng)
        nx, ny = _nonmember_like(rng)
        fm = mia._features(_predict, {0: None}, _make_batch, mx, my, "image")
        fn = mia._features(_predict, {0: None}, _make_batch, nx, ny, "image")
        assert fm.shape == (N, 3) and fn.shape == (N, 3)
        # members: lower nll, higher max-prob, lower entropy
        assert fm[:, 0].mean() < fn[:, 0].mean()
        assert fm[:, 1].mean() > fn[:, 1].mean()
        assert fm[:, 2].mean() < fn[:, 2].mean()

    def test_ensemble_averages_models(self):
        rng = np.random.default_rng(1)
        mx, my = _member_like(rng)
        one = mia._features(_predict, {0: None}, _make_batch, mx, my, "image")
        two = mia._features(_predict, {0: None, 1: None}, _make_batch,
                            mx, my, "image")
        np.testing.assert_allclose(one, two, rtol=1e-5, atol=1e-5)


class TestLogreg:
    def test_separates_separable_data(self):
        rng = np.random.default_rng(2)
        x0 = rng.normal(-2.0, 0.5, (200, 3))
        x1 = rng.normal(+2.0, 0.5, (200, 3))
        x = np.concatenate([x1, x0])
        y = np.concatenate([np.ones(200), np.zeros(200)])
        model = mia._logreg_fit(x, y)
        thr = float(np.median(mia._logreg_score(model, x)))
        pred = mia._logreg_predict(model, x, thr)
        assert (pred == y).mean() > 0.95


class TestMiaF1:
    def test_memorized_forgotten_data_scores_high(self):
        """If the 'unlearned' model still treats the forgotten client's data
        like training data, the attack catches it (F1 near 1)."""
        rng = np.random.default_rng(3)
        member = _member_like(rng)
        nonmember = _nonmember_like(rng)
        forgotten = _member_like(rng)             # still memorized
        f1 = mia.mia_f1(_predict, {0: None}, _make_batch, "image",
                        member, nonmember, forgotten)
        assert 0.6 <= f1 <= 1.0, f1

    def test_forgotten_data_scores_low(self):
        """If the forgotten client's outputs are indistinguishable from
        held-out data, the attack F1 collapses toward/below the
        no-information rate."""
        rng = np.random.default_rng(4)
        member = _member_like(rng)
        nonmember = _nonmember_like(rng)
        forgotten = _nonmember_like(rng)          # actually forgotten
        f1 = mia.mia_f1(_predict, {0: None}, _make_batch, "image",
                        member, nonmember, forgotten)
        assert 0.0 <= f1 <= 0.62, f1

    def test_ordering(self):
        """Memorized forgotten data must score strictly higher than
        genuinely forgotten data under the same attack setup."""
        rng = np.random.default_rng(5)
        member = _member_like(rng)
        nonmember = _nonmember_like(rng)
        hi = mia.mia_f1(_predict, {0: None}, _make_batch, "image",
                        member, nonmember, _member_like(rng))
        lo = mia.mia_f1(_predict, {0: None}, _make_batch, "image",
                        member, nonmember, _nonmember_like(rng))
        assert hi > lo

    def test_lm_task_branch(self):
        """The per-sequence feature path: (n, T) tokens, (n, T, V) logits."""
        rng = np.random.default_rng(6)
        T, V = 8, 5

        def predict_lm(_model, batch):
            y = batch["labels"]
            onehot = jnp.eye(V)[y]                # (n, T, V)
            return 6.0 * onehot

        def make_batch(x, y):
            return {"tokens": x, "labels": y}

        def split(n=60):
            y = rng.integers(0, V, (n, T)).astype(np.int64)
            return y.copy(), y

        member, nonmember, forgotten = split(), split(), split()
        f1 = mia.mia_f1(predict_lm, {0: None}, make_batch, "lm",
                        member, nonmember, forgotten)
        assert 0.0 <= f1 <= 1.0
