"""Partitioner-registry tests: coverage/disjointness invariants for every
registered partitioner, Dirichlet label-skew monotone in alpha, Zipf
quantity skew monotone in the exponent, and bit-for-bit seed
reproducibility."""
import numpy as np
import pytest

from repro.data.federated import (PARTITIONERS, get_partitioner,
                                  partition_dirichlet, partition_zipf)

N, CLIENTS, CLASSES = 2000, 10, 10


def _labels(seed=0):
    return np.random.default_rng(seed).integers(0, CLASSES, N).astype(np.int32)


def _canonical_names():
    return sorted({fn.partitioner_name for fn in PARTITIONERS.values()})


class TestInvariants:
    @pytest.mark.parametrize("name", ["iid", "dirichlet", "zipf"])
    def test_disjoint_cover(self, name):
        parts = PARTITIONERS[name](N, _labels(), CLIENTS, seed=3)
        assert len(parts) == CLIENTS
        assert all(len(p) > 0 for p in parts)
        allidx = np.concatenate(parts)
        assert len(allidx) == len(set(allidx.tolist())), f"{name}: overlap"
        assert set(allidx.tolist()) <= set(range(N))

    @pytest.mark.parametrize("name", ["primary-class", "buckets"])
    def test_legacy_shapes(self, name):
        # the paper's legacy skews keep their seed semantics bit-for-bit
        # (primary-class may duplicate filler samples across clients)
        parts = PARTITIONERS[name](N, _labels(), CLIENTS, seed=3)
        assert len(parts) == CLIENTS
        allidx = np.concatenate(parts)
        assert set(allidx.tolist()) <= set(range(N))

    @pytest.mark.parametrize("name", _canonical_names())
    def test_seed_reproducible_bit_for_bit(self, name):
        a = PARTITIONERS[name](N, _labels(), CLIENTS, seed=7)
        b = PARTITIONERS[name](N, _labels(), CLIENTS, seed=7)
        c = PARTITIONERS[name](N, _labels(), CLIENTS, seed=8)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))
        assert any(not np.array_equal(x, y) for x, y in zip(a, c))

    def test_unknown_partitioner_lists_registered(self):
        with pytest.raises(ValueError, match="registered:.*dirichlet"):
            get_partitioner("dirichletto")


def _label_skew(parts, labels) -> float:
    """Mean L1 distance between client label histograms and the global one."""
    glob = np.bincount(labels, minlength=CLASSES) / len(labels)
    dists = []
    for p in parts:
        h = np.bincount(labels[p], minlength=CLASSES) / len(p)
        dists.append(np.abs(h - glob).sum())
    return float(np.mean(dists))


class TestDirichlet:
    def test_skew_monotone_in_alpha(self):
        labels = _labels()
        skews = [_label_skew(partition_dirichlet(N, labels, CLIENTS, seed=0,
                                                 alpha=a), labels)
                 for a in (0.05, 0.5, 5.0, 50.0)]
        assert skews[0] > skews[1] > skews[2] > skews[3], skews
        # tiny alpha: clients are nearly single-class
        assert skews[0] > 1.0
        # huge alpha approaches the IID histogram
        assert skews[-1] < 0.3

    def test_needs_labels(self):
        with pytest.raises(ValueError, match="zipf.*buckets"):
            partition_dirichlet(N, None, CLIENTS)

    def test_bad_alpha(self):
        with pytest.raises(ValueError, match="alpha"):
            partition_dirichlet(N, _labels(), CLIENTS, alpha=0.0)

    def test_fewer_examples_than_clients_is_actionable(self):
        labels = np.zeros(3, np.int32)
        with pytest.raises(ValueError, match="samples_per_client"):
            partition_dirichlet(3, labels, 5)

    def test_unknown_parameter_lists_accepted(self):
        with pytest.raises(ValueError, match="accepted:.*alpha"):
            get_partitioner("dirichlet", alhpa=0.1)
        with pytest.raises(ValueError, match="accepted:.*exponent"):
            get_partitioner("zipf", seed=3)


def _quantity_skew(parts) -> float:
    sizes = np.asarray(sorted(len(p) for p in parts), np.float64)
    return float(sizes[-1] / sizes[0])


class TestZipf:
    def test_skew_monotone_in_exponent(self):
        ratios = [_quantity_skew(partition_zipf(N, None, CLIENTS, seed=0,
                                                exponent=e))
                  for e in (0.0, 0.5, 1.0, 2.0)]
        assert ratios[0] == pytest.approx(1.0)          # equal split
        assert ratios[0] < ratios[1] < ratios[2] < ratios[3], ratios
        assert ratios[-1] > 50                          # heavy head at e=2

    def test_sizes_sum_to_n(self):
        parts = partition_zipf(N, None, CLIENTS, seed=1, exponent=1.5)
        assert sum(len(p) for p in parts) == N

    def test_labels_ignored(self):
        a = partition_zipf(N, _labels(), CLIENTS, seed=2)
        b = partition_zipf(N, None, CLIENTS, seed=2)
        assert all(np.array_equal(x, y) for x, y in zip(a, b))


class TestLegacyShim:
    def test_iid_flag_maps_to_registry(self):
        from repro.data import client_datasets_images, make_image_data
        data = make_image_data(400, image_size=8, seed=0)
        old = client_datasets_images(data, 4, iid=False, seed=5)
        new = client_datasets_images(data, 4, partitioner="primary-class",
                                     seed=5)
        for k in old:
            assert np.array_equal(old[k][0], new[k][0])
            assert np.array_equal(old[k][1], new[k][1])
