"""§Perf optimization variants must be numerically equivalent to the
paper-faithful baselines they replace."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_fallback import given, settings, st

from repro.configs import get_config, reduce_for_smoke
from repro.models.attention import (blockwise_attention, causal_skip_attention)
from repro.models.moe import apply_moe, init_moe
from repro.models.params import RealInit


class TestCausalSkip:
    @settings(max_examples=8, deadline=None)
    @given(s=st.sampled_from([256, 512, 1024]), window=st.sampled_from([0, 200]),
           seed=st.integers(0, 20))
    def test_matches_masked_full(self, s, window, seed):
        rng = np.random.default_rng(seed)
        q = jnp.asarray(rng.standard_normal((1, s, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, s, 2, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, s, 2, 32)), jnp.float32)
        a = blockwise_attention(q, k, v, causal=True, window=window)
        b = causal_skip_attention(q, k, v, window=window, block_q=256,
                                  block_kv=128)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)

    def test_full_q_block(self):
        """block_q = whole sequence (seq-parallel mode) is still correct."""
        rng = np.random.default_rng(3)
        q = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((2, 256, 4, 32)), jnp.float32)
        a = blockwise_attention(q, k, v, causal=True, block_q=256)
        b = blockwise_attention(q, k, v, causal=True, block_q=64)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=2e-4)


class TestMoeImpls:
    @pytest.mark.parametrize("arch", ["granite-moe-1b-a400m",
                                      "jamba-1.5-large-398b"])
    def test_gather_matches_einsum(self, arch):
        cfg = reduce_for_smoke(get_config(arch))
        p = init_moe(RealInit(jax.random.key(0), jnp.float32), cfg)
        x = jax.random.normal(jax.random.key(1), (2, 64, cfg.d_model),
                              jnp.float32)
        y1, a1 = apply_moe(p, x, dataclasses.replace(cfg, moe_impl="einsum"),
                           group_size=32)
        y2, a2 = apply_moe(p, x, dataclasses.replace(cfg, moe_impl="gather"),
                           group_size=32)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(a1), float(a2), rtol=1e-6)

    def test_gather_grads_match(self):
        cfg = reduce_for_smoke(get_config("granite-moe-1b-a400m"))
        p = init_moe(RealInit(jax.random.key(0), jnp.float32), cfg)
        x = jax.random.normal(jax.random.key(1), (1, 64, cfg.d_model),
                              jnp.float32)

        def loss(p, impl):
            c = dataclasses.replace(cfg, moe_impl=impl)
            return jnp.sum(apply_moe(p, x, c, group_size=32)[0] ** 2)

        g1 = jax.grad(lambda p: loss(p, "einsum"))(p)
        g2 = jax.grad(lambda p: loss(p, "gather"))(p)
        for k in g1:
            np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                       rtol=5e-4, atol=5e-4)


class TestSsmChunkDtype:
    def test_bf16_chunks_close_to_f32(self):
        from repro.models.mamba import init_mamba, mamba_block
        cfg = reduce_for_smoke(get_config("jamba-1.5-large-398b"))
        p = init_mamba(RealInit(jax.random.key(0), jnp.float32), cfg)
        x = jax.random.normal(jax.random.key(2), (2, 128, cfg.d_model),
                              jnp.float32) * 0.5
        y32, _ = mamba_block(p, x, cfg)
        ybf, _ = mamba_block(p, x, dataclasses.replace(
            cfg, ssm_chunk_dtype="bfloat16"))
        err = float(jnp.abs(y32 - ybf).max()) / (float(jnp.abs(y32).max()) + 1e-9)
        assert err < 0.05, f"bf16 chunk relative error {err}"


class TestFedAvgLocalSteps:
    def test_more_local_steps_same_collectives_shape(self):
        """FL property: the round's delta all-reduce count is independent of
        L (the paper's communication saving) — verified structurally via the
        jaxpr: one mean over clients regardless of local steps."""
        from repro.configs import FLConfig, OptimizerConfig
        from repro.launch.train import make_fedavg_step
        from repro.models import init_params
        from repro.optim import init_optimizer
        cfg = reduce_for_smoke(get_config("olmo-1b"))
        opt = OptimizerConfig(name="sgd", lr=1e-2)
        params = init_params(cfg, jax.random.key(0))
        state = (params, init_optimizer(opt, params))
        toks = jnp.zeros((2, 1, 32), jnp.int32)
        batch = {"tokens": toks, "labels": toks}
        for ell in (1, 4):
            fl = FLConfig(fl_clients_per_step=2, fl_local_steps=ell)
            step = make_fedavg_step(cfg, fl, opt)
            (p2, _), mets = jax.jit(step)(state, batch)
            assert np.isfinite(float(mets["loss"]))


class TestMambaPallasImpl:
    def test_pallas_impl_matches_chunked(self):
        from repro.models.mamba import init_mamba, mamba_block
        cfg = reduce_for_smoke(get_config("jamba-1.5-large-398b"))
        p = init_mamba(RealInit(jax.random.key(0), jnp.float32), cfg)
        x = jax.random.normal(jax.random.key(2), (1, 64, cfg.d_model),
                              jnp.float32) * 0.5
        y1, st1 = mamba_block(p, x, cfg)
        y2, st2 = mamba_block(p, x, dataclasses.replace(cfg, mamba_impl="pallas"))
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st1[1]), np.asarray(st2[1]),
                                   rtol=2e-3, atol=2e-3)
