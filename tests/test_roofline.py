"""Loop-aware HLO cost model tests: the walker must multiply while bodies by
trip counts and resolve operand types through the symbol table."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.roofline.hlo_cost import (HloCostModel, analyze_hlo_text,
                                     xla_cost_analysis)


def _compile(f, *specs):
    return jax.jit(f).lower(*specs).compile()


class TestHloCost:
    def test_scan_trip_count_multiplies(self):
        def f_scan(x, w):
            def body(c, wi):
                return c @ wi, None
            c, _ = jax.lax.scan(body, x, w)
            return c

        def f_unroll(x, w):
            c = x
            for i in range(8):
                c = c @ w[i]
            return c

        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
        cs = _compile(f_scan, x, w)
        cu = _compile(f_unroll, x, w)
        fs = analyze_hlo_text(cs.as_text(), 1)["mxu_flops_per_device"]
        fu = analyze_hlo_text(cu.as_text(), 1)["mxu_flops_per_device"]
        expected = 8 * 2 * 128 ** 3
        assert fs == pytest.approx(expected, rel=0.05)
        assert fu == pytest.approx(expected, rel=0.05)
        # XLA's own analysis undercounts the scan 8x — that's the bug we fix
        # (xla_cost_analysis normalizes the list-vs-dict return across jax
        # versions)
        assert xla_cost_analysis(cs)["flops"] * 7 < fs

    def test_dot_flops_exact(self):
        def f(a, b):
            return a @ b
        a = jax.ShapeDtypeStruct((64, 256), jnp.float32)
        b = jax.ShapeDtypeStruct((256, 32), jnp.float32)
        c = _compile(f, a, b)
        out = analyze_hlo_text(c.as_text(), 1)
        assert out["mxu_flops_per_device"] == pytest.approx(2 * 64 * 256 * 32,
                                                            rel=0.01)

    def test_nested_scan(self):
        def f(x, w):
            def outer(c, _):
                def inner(ci, wi):
                    return ci @ wi, None
                c2, _ = jax.lax.scan(inner, c, w)
                return c2, None
            c, _ = jax.lax.scan(outer, x, None, length=3)
            return c

        x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        w = jax.ShapeDtypeStruct((4, 64, 64), jnp.float32)
        c = _compile(f, x, w)
        out = analyze_hlo_text(c.as_text(), 1)
        assert out["mxu_flops_per_device"] == pytest.approx(
            3 * 4 * 2 * 64 ** 3, rel=0.05)

    def test_bytes_nonzero_and_scaled(self):
        def f_scan(x, w):
            def body(c, wi):
                return c @ wi, None
            c, _ = jax.lax.scan(body, x, w)
            return c
        x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        w = jax.ShapeDtypeStruct((8, 128, 128), jnp.float32)
        c = _compile(f_scan, x, w)
        out = analyze_hlo_text(c.as_text(), 1)
        # at minimum the 8 weight matrices are read from HBM
        assert out["bytes_per_device"] >= 8 * 128 * 128 * 4

    def test_entry_found(self):
        c = _compile(lambda x: x + 1, jax.ShapeDtypeStruct((4,), jnp.float32))
        m = HloCostModel(c.as_text(), 1)
        assert m.entry is not None
        assert m.entry_cost() is not None
