"""Fused stacked-parameter round engine: the device-resident fast paths must
be numerically equivalent to the seed per-client paths they replace —
stacked flatten vs per-tree flatten, batched vs per-round encode, fused
shard_round vs the legacy loop (bit-for-bit), stacked vs sequential
calibration, and the bf16 / grouped-encode store options."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.stores.store import CodedStore, FullStore, RoundPayload
from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.core import coding, unlearning
from repro.data import client_datasets_images, make_image_data
from repro.fl import FLSimulator
from repro.fl.experiment import (ScenarioConfig, build_simulator, run_unlearn,
                                 train_stage)


def _stacked_tree(m=5, seed=0):
    k = jax.random.key(seed)
    ks = jax.random.split(k, 3)
    return {
        "conv": {"w": jax.random.normal(ks[0], (m, 3, 3, 4), jnp.float32)},
        "dense": {"w": jax.random.normal(ks[1], (m, 7, 5), jnp.float32),
                  "b": jax.random.normal(ks[2], (m, 5), jnp.float32)},
    }


# ------------------------------------------------------------ flatten paths
class TestStackedFlatten:
    def test_rows_match_per_tree_flatten(self):
        stacked = _stacked_tree(m=5)
        flat, spec = coding.tree_to_flat_stacked(stacked)
        assert flat.shape[0] == 5
        for i in range(5):
            tree_i = jax.tree.map(lambda a, i=i: a[i], stacked)
            fi, spec_i = coding.tree_to_flat(tree_i)
            np.testing.assert_array_equal(np.asarray(flat[i]), np.asarray(fi))
            # per-row spec reassembles exactly like the per-tree spec
            back = coding.flat_to_tree(flat[i], spec)
            for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(tree_i)):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_stacked_roundtrip(self):
        stacked = _stacked_tree(m=4, seed=1)
        flat, spec = coding.tree_to_flat_stacked(stacked)
        back = coding.flat_to_stacked_tree(flat, spec)
        for a, b in zip(jax.tree.leaves(back), jax.tree.leaves(stacked)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_traceable_under_jit(self):
        stacked = _stacked_tree(m=3, seed=2)
        f_eager, _ = coding.tree_to_flat_stacked(stacked)
        f_jit = jax.jit(lambda t: coding.tree_to_flat_stacked(t)[0])(stacked)
        np.testing.assert_array_equal(np.asarray(f_eager), np.asarray(f_jit))


# ------------------------------------------------------------ batched encode
class TestBatchedEncode:
    def test_equals_per_round_encode(self):
        sch = coding.CodingScheme(num_shards=4, num_clients=16)
        rng = np.random.default_rng(0)
        mats = [jnp.asarray(rng.standard_normal((4, 257)), jnp.float32)
                for _ in range(5)]
        batched = coding.encode_batched(sch, mats)
        assert len(batched) == 5
        for m, b in zip(mats, batched):
            np.testing.assert_allclose(np.asarray(b),
                                       np.asarray(coding.encode(sch, m)),
                                       rtol=1e-6, atol=1e-6)

    def test_kernel_path(self):
        sch = coding.CodingScheme(num_shards=3, num_clients=12)
        rng = np.random.default_rng(1)
        mats = [jnp.asarray(rng.standard_normal((3, 100)), jnp.float32)
                for _ in range(3)]
        ref = coding.encode_batched(sch, mats)
        krn = coding.encode_batched(sch, mats, use_kernel=True)
        for a, b in zip(ref, krn):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)

    def test_bf16_storage(self):
        sch = coding.CodingScheme(num_shards=4, num_clients=16)
        rng = np.random.default_rng(2)
        w = jnp.asarray(rng.standard_normal((4, 512)), jnp.float32)
        sl = coding.encode(sch, w, out_dtype=jnp.bfloat16)
        assert sl.dtype == jnp.bfloat16
        out = coding.decode_erasure(sch, sl[jnp.asarray([0, 5, 10, 15])],
                                    [0, 5, 10, 15])
        assert out.dtype == jnp.float32
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=5e-2, atol=5e-2)


# ------------------------------------------------------- fused encode-decode
class TestEncodeDecodeFused:
    def test_matches_two_pass_and_identity(self):
        sch = coding.CodingScheme(num_shards=4, num_clients=20)
        rng = np.random.default_rng(3)
        w = jnp.asarray(rng.standard_normal((4, 321)), jnp.float32)
        out_jnp = coding.encode_decode(sch, w)
        out_krn = coding.encode_decode(sch, w, use_kernel=True)
        np.testing.assert_allclose(np.asarray(out_jnp), np.asarray(out_krn),
                                   rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(out_krn), np.asarray(w),
                                   rtol=1e-3, atol=1e-3)

    def test_subset_ids(self):
        sch = coding.CodingScheme(num_shards=3, num_clients=15)
        rng = np.random.default_rng(4)
        w = jnp.asarray(rng.standard_normal((3, 64)), jnp.float32)
        ids = [2, 6, 9, 14]
        out = coding.encode_decode(sch, w, client_ids=ids, use_kernel=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(w),
                                   rtol=2e-3, atol=2e-3)


# -------------------------------------------------------- stacked calibrate
class TestCalibrateStacked:
    def _setup(self, m=4, seed=0):
        k = jax.random.key(seed)
        ks = jax.random.split(k, 3)
        w = {"a": jax.random.normal(ks[0], (9, 4), jnp.float32),
             "b": jax.random.normal(ks[1], (11,), jnp.float32)}
        stacked = {"a": jax.random.normal(ks[2], (m, 9, 4), jnp.float32),
                   "b": jax.random.normal(jax.random.fold_in(k, 7), (m, 11),
                                          jnp.float32)}
        norms = jnp.asarray(np.random.default_rng(seed).uniform(0.5, 2.0, m),
                            jnp.float32)
        return w, stacked, norms

    def test_matches_sequential_calibrate(self):
        w, stacked, norms = self._setup()
        m = norms.shape[0]
        per_client = [jax.tree.map(lambda a, i=i: a[i], stacked)
                      for i in range(m)]
        # eq (3) reference: sequential per-client accumulation, with stored
        # deltas synthesized to have exactly the stored norms
        stored = [jax.tree.map(lambda a: a * 0, per_client[0]) for _ in range(m)]
        stored = [unlearning.tree_add(s, {"a": jnp.zeros((9, 4)).at[0, 0].set(n),
                                          "b": jnp.zeros(11)})
                  for s, n in zip(stored, np.asarray(norms))]
        ref = unlearning.calibrate(w, per_client, stored)
        out = unlearning.calibrate_stacked(w, stacked, norms)
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)

    def test_kernel_path_matches(self):
        w, stacked, norms = self._setup(seed=1)
        out = unlearning.calibrate_stacked(w, stacked, norms)
        krn = unlearning.calibrate_stacked(w, stacked, norms, use_kernel=True)
        for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(krn)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-4, atol=1e-4)

    def test_matches_simulator_host_loop(self):
        """calibrate_stacked == the seed _calibrate_with_norms host loop."""
        w, stacked, norms = self._setup(seed=2)
        m = norms.shape[0]
        per_client = [jax.tree.map(lambda a, i=i: a[i], stacked)
                      for i in range(m)]
        out = unlearning.calibrate_stacked(w, stacked, norms)
        ref = w
        for nd, sn in zip(per_client, np.asarray(norms)):
            ratio = float(sn) / max(float(unlearning.tree_norm(nd)), 1e-12)
            ref = unlearning.tree_add(ref, unlearning.tree_scale(nd, ratio / m))
        for a, b in zip(jax.tree.leaves(ref), jax.tree.leaves(out)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=1e-5, atol=1e-5)


# --------------------------------------------------- end-to-end round engine
FL_TINY = FLConfig(num_clients=8, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim():
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(8 * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10)


class TestFusedEngineEquivalence:
    @pytest.fixture(scope="class")
    def records(self):
        s_leg, s_fus = _tiny_sim(), _tiny_sim()
        return (train_stage(s_leg, store_kind="coded", engine="legacy"),
                train_stage(s_fus, store_kind="coded", engine="fused"), s_fus)

    def test_shard_models_bit_for_bit(self, records):
        r_leg, r_fus, _ = records
        assert r_leg.plan.shard_clients == r_fus.plan.shard_clients
        for s in r_leg.shard_models:
            for a, b in zip(jax.tree.leaves(r_leg.shard_models[s]),
                            jax.tree.leaves(r_fus.shard_models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_coded_slices_bit_for_bit(self, records):
        r_leg, r_fus, _ = records
        assert set(r_leg.store._slices) == set(r_fus.store._slices)
        for g, sl in r_leg.store._slices.items():
            np.testing.assert_array_equal(np.asarray(sl),
                                          np.asarray(r_fus.store._slices[g]))

    def test_history_norms_match(self, records):
        r_leg, r_fus, _ = records
        assert set(r_leg.history_norms) == set(r_fus.history_norms)
        for k, v in r_leg.history_norms.items():
            # one-array fetch vs per-scalar pulls: identical up to reduce
            # layout (observed <= 1 ulp)
            assert abs(v - r_fus.history_norms[k]) <= 1e-5 * max(abs(v), 1.0)

    def test_stored_round_reconstruction_matches(self, records):
        r_leg, r_fus, _ = records
        for s in r_leg.plan.shard_clients:
            g_leg = r_leg.store.get_shard(0, s)
            g_fus = r_fus.store.get_shard(0, s)
            assert set(g_leg) == set(g_fus)
            for c in g_leg:
                for a, b in zip(jax.tree.leaves(g_leg[c]),
                                jax.tree.leaves(g_fus[c])):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=1e-5, atol=1e-5)

    def test_unlearning_runs_on_fused_record(self, records):
        _, r_fus, sim = records
        victim = r_fus.plan.shard_clients[0][0]
        for fw in ("SE", "FE", "FR", "RR"):
            res = run_unlearn(sim, fw, r_fus, [victim], rounds=2)
            leaves = jax.tree.leaves(list(res.models.values())[0])
            assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
                       for l in leaves), fw


class TestStageEngineEquivalence:
    """The whole-stage superfusion (engine='stage': scan-over-rounds x
    vmap-over-shards + in-program Lagrange encode) must reproduce the fused
    per-shard engine: shard models, stored coded slices, history norms, store
    accounting — and its lazy round-globals view must behave like the
    materialized lists."""

    @pytest.fixture(scope="class")
    def records(self):
        s_fus, s_stg = _tiny_sim(), _tiny_sim()
        return (train_stage(s_fus, store_kind="coded", engine="fused"),
                train_stage(s_stg, store_kind="coded", engine="stage"), s_stg)

    def test_shard_models_bit_for_bit(self, records):
        r_fus, r_stg, _ = records
        assert r_fus.plan.shard_clients == r_stg.plan.shard_clients
        for s in r_fus.shard_models:
            for a, b in zip(jax.tree.leaves(r_fus.shard_models[s]),
                            jax.tree.leaves(r_stg.shard_models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_coded_slices_match(self, records):
        """In-program einsum encode vs the store's batched matmul encode —
        bit-identical on CPU; the acceptance bound for the fused-encode path
        is <=1e-5 rel."""
        r_fus, r_stg, _ = records
        assert set(r_fus.store._slices) == set(r_stg.store._slices)
        for g, sl in r_fus.store._slices.items():
            np.testing.assert_allclose(np.asarray(sl),
                                       np.asarray(r_stg.store._slices[g]),
                                       rtol=1e-5, atol=1e-6)

    def test_history_norms_match(self, records):
        r_fus, r_stg, _ = records
        assert set(r_fus.history_norms) == set(r_stg.history_norms)
        for k, v in r_fus.history_norms.items():
            assert abs(v - r_stg.history_norms[k]) <= 1e-5 * max(abs(v), 1.0)

    def test_store_accounting_matches(self, records):
        r_fus, r_stg, _ = records
        assert r_fus.store.stats == r_stg.store.stats

    def test_round_globals_lazy_view(self, records):
        r_fus, r_stg, _ = records
        for s in r_stg.plan.shard_clients:
            view = r_stg.round_globals[s]
            ref = r_fus.round_globals[s]
            assert len(view) == len(ref) == FL_TINY.global_rounds + 1
            for g in (0, len(ref) - 1, -1):
                for a, b in zip(jax.tree.leaves(ref[g]),
                                jax.tree.leaves(view[g])):
                    np.testing.assert_array_equal(np.asarray(a),
                                                  np.asarray(b))

    def test_stored_round_reconstruction_matches(self, records):
        r_fus, r_stg, _ = records
        for s in r_fus.plan.shard_clients:
            g_fus = r_fus.store.get_shard(0, s)
            g_stg = r_stg.store.get_shard(0, s)
            assert set(g_fus) == set(g_stg)
            for c in g_fus:
                for a, b in zip(jax.tree.leaves(g_fus[c]),
                                jax.tree.leaves(g_stg[c])):
                    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                               rtol=1e-5, atol=1e-5)

    def test_unlearning_runs_on_stage_record(self, records):
        _, r_stg, sim = records
        victim = r_stg.plan.shard_clients[0][0]
        for fw in ("SE", "FE", "FR", "RR"):
            res = run_unlearn(sim, fw, r_stg, [victim], rounds=2)
            leaves = jax.tree.leaves(list(res.models.values())[0])
            assert all(np.all(np.isfinite(np.asarray(l, np.float32)))
                       for l in leaves), fw

    def test_uncoded_store_stage_engine(self):
        s_stg, s_fus = _tiny_sim(), _tiny_sim()
        r_stg = train_stage(s_stg, store_kind="uncoded", engine="stage")
        r_fus = train_stage(s_fus, store_kind="uncoded", engine="fused")
        assert r_stg.store.stats.server_bytes == r_fus.store.stats.server_bytes
        c = r_stg.plan.shard_clients[0][0]
        for a, b in zip(jax.tree.leaves(r_stg.store.get(0, c)),
                        jax.tree.leaves(r_fus.store.get(0, c))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_encode_group_rejected(self):
        with pytest.raises(ValueError, match="fused-engine option"):
            train_stage(_tiny_sim(), engine="stage", encode_group=2)

    def test_ragged_stage_falls_back(self):
        """Unequal per-client sample counts across shards break the (S, M, n)
        stack — the stage engine must warn and degrade to the per-shard fused
        path, producing the same record the fused engine would."""
        cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                                  d_model=16, cnn_channels=(4, 4))
        data = make_image_data(8 * 30, image_size=8, seed=0)
        clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
        # shrink one client's dataset: its shard's n_min now differs
        cid = sorted(clients)[0]
        clients[cid] = (clients[cid][0][:11], clients[cid][1][:11])

        def mk():
            return FLSimulator(cfg, FL_TINY, clients, task="image",
                               opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                                       grad_clip=0.0),
                               local_batch=10)

        s_stg, s_fus = mk(), mk()
        with pytest.warns(UserWarning, match="ragged stage"):
            r_stg = train_stage(s_stg, store_kind="coded", engine="stage")
        r_fus = train_stage(s_fus, store_kind="coded", engine="fused")
        assert r_stg.plan.shard_clients == r_fus.plan.shard_clients
        for s in r_fus.shard_models:
            for a, b in zip(jax.tree.leaves(r_fus.shard_models[s]),
                            jax.tree.leaves(r_stg.shard_models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestBatchedCalibration:
    """SE's multi-shard batched retraining (calib_stage: vmap over impacted
    shards, scan over rounds) must match the per-shard sequential loop."""

    def test_batched_matches_sequential(self):
        from repro.fl.experiment.frameworks import (ShardedEraser,
                                                    UnlearnContext)
        sim = _tiny_sim()
        rec = train_stage(sim, store_kind="coded", engine="stage")
        victims = [rec.plan.shard_clients[0][0], rec.plan.shard_clients[1][0]]
        fw = ShardedEraser()
        ctx = UnlearnContext(sim, rec, victims, FL_TINY.global_rounds)
        jobs = fw.prepare_jobs(ctx)
        assert len(jobs) == 2 and fw._batchable(jobs)
        m_bat, c_bat = fw._run_batched(ctx, jobs)
        m_seq, c_seq = fw._run_sequential(ctx, jobs)
        assert c_bat == c_seq
        assert set(m_bat) == set(m_seq)
        for s in m_seq:
            for a, b in zip(jax.tree.leaves(m_seq[s]),
                            jax.tree.leaves(m_bat[s])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-6)

    def test_ragged_jobs_not_batchable(self):
        from repro.fl.experiment.frameworks import (ShardedEraser,
                                                    UnlearnContext)
        sim = _tiny_sim()
        rec = train_stage(sim, store_kind="coded", engine="fused")
        # two victims in shard 0, one in shard 1: retained counts differ
        victims = rec.plan.shard_clients[0][:2] + [rec.plan.shard_clients[1][0]]
        fw = ShardedEraser()
        ctx = UnlearnContext(sim, rec, list(victims), 2)
        jobs = fw.prepare_jobs(ctx)
        assert len(jobs) == 2 and not fw._batchable(jobs)
        res = run_unlearn(sim, "SE", rec, list(victims), rounds=2)
        assert res.impacted_shards == [0, 1]


class TestVmappedEvaluate:
    def test_matches_host_loop(self):
        sim = _tiny_sim()
        rec = train_stage(sim, store_kind="uncoded", rounds=1)
        data = make_image_data(110, image_size=8, seed=9)
        new = sim.evaluate(rec.shard_models, data.images, data.labels,
                           batch=32)
        ref = sim.evaluate_host(rec.shard_models, data.images, data.labels,
                                batch=32)
        assert new["acc"] == ref["acc"]
        assert abs(new["loss"] - ref["loss"]) < 1e-4

    def test_single_model_ensemble(self):
        sim = _tiny_sim()
        rec = train_stage(sim, store_kind="uncoded", rounds=1)
        data = make_image_data(60, image_size=8, seed=10)
        one = {0: rec.shard_models[0]}
        new = sim.evaluate(one, data.images, data.labels, batch=30)
        ref = sim.evaluate_host(one, data.images, data.labels, batch=30)
        assert new["acc"] == ref["acc"]
        assert abs(new["loss"] - ref["loss"]) < 1e-4


class TestDeprecatedShims:
    """train_stage/unlearn stay callable on the simulator as thin wrappers
    over the experiment API: they warn, and their results are bit-identical
    to the new path on identically-seeded sims."""

    @pytest.fixture(scope="class")
    def pair(self):
        s_new, s_old = _tiny_sim(), _tiny_sim()
        r_new = train_stage(s_new, store_kind="coded")
        with pytest.warns(DeprecationWarning, match="train_stage is deprecated"):
            r_old = s_old.train_stage(store_kind="coded")
        return s_new, r_new, s_old, r_old

    def test_train_stage_shim_equivalent(self, pair):
        _, r_new, _, r_old = pair
        assert r_old.plan.shard_clients == r_new.plan.shard_clients
        for s in r_new.shard_models:
            for a, b in zip(jax.tree.leaves(r_old.shard_models[s]),
                            jax.tree.leaves(r_new.shard_models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert r_old.history_norms == r_new.history_norms
        for g, sl in r_new.store._slices.items():
            np.testing.assert_array_equal(np.asarray(r_old.store._slices[g]),
                                          np.asarray(sl))

    @pytest.mark.parametrize("fw", ["SE", "FE", "FR", "RR"])
    def test_unlearn_shim_equivalent(self, pair, fw):
        s_new, r_new, s_old, r_old = pair
        victim = r_new.plan.shard_clients[0][0]
        res_new = run_unlearn(s_new, fw, r_new, [victim], rounds=2)
        with pytest.warns(DeprecationWarning, match="unlearn is deprecated"):
            res_old = s_old.unlearn(fw, r_old, [victim], rounds=2)
        assert res_old.impacted_shards == res_new.impacted_shards
        assert res_old.cost_units == res_new.cost_units
        for s in res_new.models:
            for a, b in zip(jax.tree.leaves(res_old.models[s]),
                            jax.tree.leaves(res_new.models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


class TestDeprecatedScenarioShims:
    """``ScenarioConfig``'s pre-registry spellings — ``task="image"|"lm"``
    and ``iid=True/False`` — warn, map onto the task/family/partitioner
    registries, and build a bit-identical simulator + trained stage."""

    _TINY = dict(num_clients=6, clients_per_round=4, num_shards=2,
                 local_epochs=1, global_rounds=2, samples_per_client=10,
                 image_size=8, test_n=20)

    def test_image_noniid_spelling_bit_identical(self):
        with pytest.warns(DeprecationWarning, match="deprecated"):
            old = ScenarioConfig(task="image", iid=False, **self._TINY)
        new = ScenarioConfig(task="classification", model="cnn",
                             partitioner="primary-class", **self._TINY)
        assert (old.task, old.model, old.partitioner, old.iid) == \
            ("classification", "cnn", "primary-class", None)
        s_old, t_old = build_simulator(old)
        s_new, t_new = build_simulator(new)
        assert s_old.cfg == s_new.cfg
        assert s_old.opt == s_new.opt and s_old.local_batch == s_new.local_batch
        for c in s_old.client_data:
            np.testing.assert_array_equal(s_old.client_data[c][0],
                                          s_new.client_data[c][0])
            np.testing.assert_array_equal(s_old.client_data[c][1],
                                          s_new.client_data[c][1])
        np.testing.assert_array_equal(t_old[0], t_new[0])
        r_old = train_stage(s_old, store_kind="coded")
        r_new = train_stage(s_new, store_kind="coded")
        assert r_old.plan.shard_clients == r_new.plan.shard_clients
        for s in r_new.shard_models:
            for a, b in zip(jax.tree.leaves(r_old.shard_models[s]),
                            jax.tree.leaves(r_new.shard_models[s])):
                np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    def test_lm_spelling_maps_and_matches_data(self):
        tiny = dict(self._TINY, seq_len=12, samples_per_client=4)
        with pytest.warns(DeprecationWarning, match="task='lm'"):
            old = ScenarioConfig(task="lm", iid=True, **tiny)
        new = ScenarioConfig(task="generation", model="transformer",
                             partitioner="iid", **tiny)
        assert (old.task, old.model, old.partitioner) == \
            ("generation", "transformer", "iid")
        s_old, _ = build_simulator(old)
        s_new, _ = build_simulator(new)
        assert s_old.cfg == s_new.cfg
        for c in s_old.client_data:
            np.testing.assert_array_equal(s_old.client_data[c][0],
                                          s_new.client_data[c][0])

    def test_iid_false_lm_maps_to_buckets(self):
        with pytest.warns(DeprecationWarning, match="iid=.*deprecated"):
            cfg = ScenarioConfig(task="generation", iid=False, **self._TINY)
        assert cfg.partitioner == "buckets"


class TestStoreFastPaths:
    def test_grouped_encode_defers_then_matches(self):
        """group_rounds > 1 batches encodes; auto-flush on first read."""
        sch = coding.CodingScheme(num_shards=2, num_clients=6)
        shard_clients = {0: [0, 1], 1: [2, 3]}
        rng = np.random.default_rng(0)
        tmpl = {"w": np.zeros((3, 2), np.float32)}
        _, row_spec = coding.tree_to_flat(
            {"w": jnp.zeros((3, 2), jnp.float32)})

        def flats(seed):
            r = np.random.default_rng(seed)
            return {s: jnp.asarray(r.standard_normal((2, 6)), jnp.float32)
                    for s in (0, 1)}

        grouped = CodedStore(sch, shard_clients, group_rounds=4)
        eager = CodedStore(sch, shard_clients, group_rounds=1)
        per_round = [flats(i) for i in range(3)]
        for g, f in enumerate(per_round):
            grouped.put_round(RoundPayload.from_flat(g, shard_clients, f,
                                                     row_spec))
            eager.put_round(RoundPayload.from_flat(g, shard_clients, f,
                                                   row_spec))
        assert not grouped._slices          # group not full: still pending
        assert len(eager._slices) == 3      # eager store encodes per round
        got = grouped.get_shard(1, 0)       # triggers auto-flush
        want = eager.get_shard(1, 0)
        assert set(got) == set(want)
        for c in got:
            for a, b in zip(jax.tree.leaves(got[c]), jax.tree.leaves(want[c])):
                np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                           rtol=1e-5, atol=1e-5)

    def test_bf16_slices_halve_storage(self):
        sch = coding.CodingScheme(num_shards=2, num_clients=6)
        shard_clients = {0: [0, 1], 1: [2, 3]}
        _, row_spec = coding.tree_to_flat({"w": jnp.zeros((8,), jnp.float32)})
        rng = np.random.default_rng(1)
        f = {s: jnp.asarray(rng.standard_normal((2, 8)), jnp.float32)
             for s in (0, 1)}
        st32 = CodedStore(sch, shard_clients)
        st16 = CodedStore(sch, shard_clients, slice_dtype=jnp.bfloat16)
        st32.put_round(RoundPayload.from_flat(0, shard_clients, f, row_spec))
        st16.put_round(RoundPayload.from_flat(0, shard_clients, f, row_spec))
        st32.flush(), st16.flush()
        assert st16.stats.client_bytes * 2 == st32.stats.client_bytes
        a = st32.get_shard(0, 0)
        b = st16.get_shard(0, 0)
        for c in a:
            np.testing.assert_allclose(np.asarray(a[c]["w"]),
                                       np.asarray(b[c]["w"]),
                                       rtol=5e-2, atol=5e-2)

    def test_full_store_stacked_rows_lazy(self):
        store = FullStore()
        stacked = _stacked_tree(m=3, seed=5)
        store.put_round(RoundPayload.from_stacked(0, {0: [10, 11, 12]},
                                                  {0: stacked}))
        got = store.get(0, 11)
        want = jax.tree.map(lambda a: a[1], stacked)
        for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert store.clients_at(0) == [10, 11, 12]
        # the unified read path serves whole shards on uncoded stores too
        shard = store.get_shard(0, 0)
        assert sorted(shard) == [10, 11, 12]
