"""Scenario-zoo acceptance tests: every registered model family runs
END-TO-END through ``run_scenario`` -> ``FederatedSession`` -> coded store ->
SE unlearning at smoke scale, with the mamba/rwkv6 paths asserted to route
through their ``ssm_scan``/``wkv`` Pallas kernel ops (interpret mode on
CPU).  Plus ``ScenarioConfig.__post_init__`` validation (typo'd registry
keys fail with actionable errors, not deep KeyErrors) and a guard that the
CI matrix smoke job covers every registered family so a registry entry can
never silently rot."""
import importlib
import re
from pathlib import Path

import numpy as np
import pytest

from repro.fl.experiment import (RequestSchedule, ScenarioConfig,
                                 UnlearnRequest, build_session,
                                 register_model_family)
from repro.fl.families import ModelFamily, canonical_families, get_model_family
from repro.fl.tasks import get_task

# kernel op -> module that owns it (what the model files import lazily)
_OP_MODULES = {"ssm_scan": "repro.kernels.ssm_scan.ops",
               "wkv": "repro.kernels.wkv.ops"}


def _family_cfg(family: str) -> ScenarioConfig:
    fam = get_model_family(family)
    schedule = RequestSchedule([UnlearnRequest(
        lambda plan: [plan.shard_clients[0][0]], framework="SE", rounds=1)])
    common = dict(model=family, store="coded", num_clients=8,
                  clients_per_round=4, num_shards=2, local_epochs=1,
                  global_rounds=2, num_stages=1, schedule=schedule)
    if fam.task == "classification":
        return ScenarioConfig(task="classification", partitioner="dirichlet",
                              partitioner_kwargs={"alpha": 1.0},
                              samples_per_client=12, image_size=8, test_n=40,
                              local_batch=2, **common)
    return ScenarioConfig(task="generation", partitioner="zipf",
                          partitioner_kwargs={"exponent": 0.5},
                          samples_per_client=6, seq_len=16, test_n=20,
                          local_batch=2, **common)


@pytest.mark.parametrize("family", canonical_families())
def test_family_end_to_end(family, monkeypatch):
    """One tiny stage + one SE request per family — the CI matrix smoke."""
    fam = get_model_family(family)
    counts = {}
    for op in fam.kernel_ops:
        mod = importlib.import_module(_OP_MODULES[op])
        real = getattr(mod, op)

        def spy(*a, _real=real, _op=op, **kw):
            counts[_op] = counts.get(_op, 0) + 1
            return _real(*a, **kw)

        monkeypatch.setattr(mod, op, spy)

    cfg = _family_cfg(family)
    session, (tx, ty) = build_session(cfg)
    report = session.run(cfg.num_stages, schedule=cfg.schedule)

    # trained + served: one stage, one SE result on the impacted shard only
    assert len(report.stages) == 1
    (res,) = report.stages[0].unlearn
    assert res.framework == "SE"
    assert list(res.impacted_shards) == [0]
    assert res.cost_units > 0
    assert report.store_stats.client_bytes > 0      # coded slices landed

    # the family's declared kernel ops were actually exercised
    for op in fam.kernel_ops:
        assert counts.get(op, 0) > 0, f"{family} never routed through {op!r}"

    # task-appropriate eval metrics, finite
    metrics = session.sim.evaluate(res.models, tx, ty)
    assert all(np.isfinite(v) for v in metrics.values()), metrics
    if fam.task == "generation":
        assert "ppl" in metrics and "bpc" in metrics
        assert metrics["ppl"] == pytest.approx(np.exp(metrics["loss"]),
                                               rel=1e-6)


class TestFamilyRegistry:
    def test_kernel_declarations(self):
        assert get_model_family("mamba").kernel_ops == ("ssm_scan",)
        assert get_model_family("rwkv6").kernel_ops == ("wkv",)
        assert get_model_family("mamba").build(
            _family_cfg("mamba")).mamba_impl == "pallas"
        assert get_model_family("rwkv6").build(
            _family_cfg("rwkv6")).rwkv_impl == "pallas"

    def test_aliases_resolve_to_same_class(self):
        assert type(get_model_family("rwkv")) is type(get_model_family("rwkv6"))
        assert type(get_model_family("nanogpt")) is type(
            get_model_family("transformer"))

    def test_third_party_family_is_one_class(self):
        from repro.fl.experiment import build_simulator

        @register_model_family("cnn-wide-test")
        class WideCNN(ModelFamily):
            task = "classification"

            def build(self, cfg):
                import dataclasses
                from repro.configs import get_config
                return dataclasses.replace(get_config("cnn-paper"),
                                           image_size=cfg.image_size,
                                           d_model=64, cnn_channels=(4, 8))

        try:
            cfg = ScenarioConfig(model="cnn-wide-test", num_clients=4,
                                 clients_per_round=4, num_shards=2,
                                 samples_per_client=8, image_size=8)
            sim, _test = build_simulator(cfg)
            assert sim.cfg.d_model == 64
        finally:
            from repro.fl.families import FAMILIES
            FAMILIES.pop("cnn-wide-test", None)

    def test_default_families_per_task(self):
        assert get_task("classification").default_family == "cnn"
        assert get_task("generation").default_family == "transformer"


class TestScenarioValidation:
    def test_unknown_task_lists_registered(self):
        with pytest.raises(ValueError, match="registered:.*classification"):
            ScenarioConfig(task="vision")

    def test_unknown_model_lists_registered(self):
        with pytest.raises(ValueError, match="registered:.*mamba"):
            ScenarioConfig(model="mambo")

    def test_unknown_partitioner_lists_registered(self):
        with pytest.raises(ValueError, match="registered:.*zipf"):
            ScenarioConfig(partitioner="zpif")

    def test_typod_partitioner_kwarg_fails_at_config_time(self):
        with pytest.raises(ValueError, match="accepted:.*alpha"):
            ScenarioConfig(partitioner="dirichlet",
                           partitioner_kwargs={"alhpa": 0.1})

    def test_unknown_store_lists_registered(self):
        with pytest.raises(ValueError, match="registered:.*coded"):
            ScenarioConfig(store="codedx")

    def test_unknown_engine(self):
        with pytest.raises(ValueError, match="stage.*fused.*legacy"):
            ScenarioConfig(engine="turbo")

    def test_unknown_scheduled_framework(self):
        sched = RequestSchedule([UnlearnRequest([0], framework="SEE")])
        with pytest.raises(ValueError, match="registered:.*SE"):
            ScenarioConfig(schedule=sched)

    def test_model_task_mismatch(self):
        with pytest.raises(ValueError, match="plays task"):
            ScenarioConfig(task="classification", model="rwkv6")

    def test_shards_must_divide_sampled_clients(self):
        with pytest.raises(ValueError, match="must divide"):
            ScenarioConfig(clients_per_round=10, num_shards=4)

    def test_clients_per_round_bounded(self):
        with pytest.raises(ValueError, match="exceeds num_clients"):
            ScenarioConfig(num_clients=4, clients_per_round=8)

    def test_bad_slice_dtype(self):
        with pytest.raises(ValueError, match="bfloat16"):
            ScenarioConfig(slice_dtype="floatiest")
        ScenarioConfig(slice_dtype="bfloat16")       # jnp extension dtype OK
        ScenarioConfig(slice_dtype=np.float16)

    def test_iid_and_partitioner_conflict(self):
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="not both"):
                ScenarioConfig(iid=True, partitioner="zipf")


def test_ci_matrix_covers_all_registered_families():
    """The CI scenario-zoo matrix must name every registered family — adding
    a family without smoke coverage fails here, not in production."""
    ci = (Path(__file__).resolve().parents[1] / ".github" / "workflows"
          / "ci.yml").read_text()
    m = re.search(r"family:\s*\[([^\]]*)\]", ci)
    assert m, "ci.yml has no scenario-zoo family matrix"
    listed = {s.strip() for s in m.group(1).split(",") if s.strip()}
    assert listed == set(canonical_families()), (
        f"CI matrix {sorted(listed)} != registered families "
        f"{sorted(canonical_families())}")
