"""Service-layer tests: seeded workload generators (reproducible
run-to-run), scheduling-policy semantics (pure virtual-time logic), the
serving engine's ledger, and the acceptance anchors — FIFO on a 1-device
placement is bit-identical to the sequential ``FederatedSession.run`` on the
same request trace, and a 4-virtual-device subprocess run spreads one
batch's shard programs across all devices with per-shard models matching
the sequential serves."""
import dataclasses
import json
import os
import subprocess
import sys
import time

import jax
import numpy as np
import pytest

from repro.configs import FLConfig, OptimizerConfig, get_config
from repro.data import client_datasets_images, make_image_data
from repro.fl import FLSimulator
from repro.fl.experiment import (FederatedSession, RequestSchedule,
                                 UnlearnRequest)
from repro.service import (POLICIES, BatchWindowPolicy, FIFOPolicy, Pending,
                           SLAPolicy, ServiceRequest, UnlearningService,
                           VirtualClock, bursty_trace, client_sampler,
                           iter_poisson_trace, iter_trace, load_trace,
                           make_policy, poisson_trace, save_trace,
                           save_trace_jsonl, sequenced_trace,
                           single_device_placement)

FL_TINY = FLConfig(num_clients=10, clients_per_round=8, num_shards=2,
                   local_epochs=2, global_rounds=3, retrain_ratio=2.0)


def _tiny_sim(seed=0):
    cfg = dataclasses.replace(get_config("cnn-paper"), image_size=8,
                              d_model=16, cnn_channels=(4, 4))
    data = make_image_data(FL_TINY.num_clients * 30, image_size=8, seed=0)
    clients = client_datasets_images(data, FL_TINY.num_clients, iid=True)
    return FLSimulator(cfg, FL_TINY, clients, task="image",
                       opt_cfg=OptimizerConfig(name="sgdm", lr=0.05,
                                               grad_clip=0.0),
                       local_batch=10, seed=seed)


def _trees_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def _req(rid, t, clients=(0,), deadline=None, framework="SE"):
    return ServiceRequest(t=t, clients=tuple(clients), framework=framework,
                          deadline=deadline, rid=rid)


def _pend(rid, t, impacted):
    return Pending(_req(rid, t), impacted=frozenset(impacted))


# ------------------------------------------------------------------ workload
class TestWorkload:
    def test_poisson_reproducible(self):
        a = poisson_trace(range(10), n=8, rate=4.0, seed=3, skew=1.0)
        b = poisson_trace(range(10), n=8, rate=4.0, seed=3, skew=1.0)
        assert a == b
        c = poisson_trace(range(10), n=8, rate=4.0, seed=4, skew=1.0)
        assert a != c
        ts = [r.t for r in a]
        assert ts == sorted(ts) and ts[0] > 0
        assert [r.rid for r in a] == list(range(8))

    def test_bursty_reproducible_and_bursty(self):
        a = bursty_trace(range(10), n=12, burst_rate=2.0, mean_burst=4.0,
                         seed=7)
        b = bursty_trace(range(10), n=12, burst_rate=2.0, mean_burst=4.0,
                         seed=7)
        assert a == b
        times = [r.t for r in a]
        assert len(set(times)) < len(times)        # some burst shares a t

    def test_hot_client_skew_concentrates(self):
        flat = poisson_trace(range(20), n=60, rate=1.0, seed=0, skew=0.0)
        hot = poisson_trace(range(20), n=60, rate=1.0, seed=0, skew=3.0)

        def top_share(trace):
            counts = {}
            for r in trace:
                counts[r.clients[0]] = counts.get(r.clients[0], 0) + 1
            return max(counts.values()) / len(trace)
        assert top_share(hot) > top_share(flat)

    def test_sampler_without_replacement_exhausts(self):
        sample = client_sampler([1, 2, 3], seed=0, replace=False)
        got = {sample(1)[0] for _ in range(3)}
        assert got == {1, 2, 3}
        with pytest.raises(ValueError, match="exhausted"):
            sample(1)

    def test_sequenced_trace_scalars_and_groups(self):
        tr = sequenced_trace([3, (4, 5)], spacing=0.5, rounds=2)
        assert tr[0].clients == (3,) and tr[1].clients == (4, 5)
        assert (tr[0].t, tr[1].t) == (0.0, 0.5)
        assert all(r.rounds == 2 for r in tr)

    def test_trace_file_roundtrip(self, tmp_path):
        trace = poisson_trace(range(6), n=5, rate=2.0, seed=1, deadline=3.0)
        path = str(tmp_path / "trace.json")
        save_trace(path, trace)
        assert load_trace(path) == trace

    def test_virtual_clock_is_monotone(self):
        clk = VirtualClock()
        assert clk.advance_to(2.0) == 2.0
        assert clk.advance_to(1.0) == 2.0          # no time travel
        assert clk.advance(0.5) == 2.5
        assert clk.advance(-1.0) == 2.5

    def test_sampler_large_pool_without_replacement_is_linear(self):
        """Regression: the without-replacement filter used an O(n·k)
        membership scan against the drawn-index *array*; on a 300k-client
        pool it took minutes.  The hoisted-set form stays well under a
        second per call."""
        sample = client_sampler(range(300_000), seed=0, skew=1.0,
                                replace=False)
        t0 = time.perf_counter()
        drawn = sample(500) + sample(500)
        wall = time.perf_counter() - t0
        assert len(set(drawn)) == 1000             # no duplicates across calls
        assert wall < 5.0, f"sampler took {wall:.1f}s on a 300k pool"


# ----------------------------------------------------------------- streaming
class TestStreamingWorkload:
    def test_iter_poisson_matches_materialized(self):
        kw = dict(n=16, rate=4.0, seed=3, skew=1.0, victims_per_request=2)
        gen = iter_poisson_trace(range(10), **kw)
        assert next(gen).rid == 0                  # lazy: yields one at a time
        assert [next(gen).rid for _ in range(15)] == list(range(1, 16))
        assert list(iter_poisson_trace(range(10), **kw)) == \
            poisson_trace(range(10), **kw)

    def test_jsonl_roundtrip_streams(self, tmp_path):
        trace = poisson_trace(range(6), n=5, rate=2.0, seed=1, deadline=3.0)
        path = str(tmp_path / "trace.jsonl")
        # writer consumes a generator without materializing it
        assert save_trace_jsonl(path, iter(trace)) == 5
        assert list(iter_trace(path)) == trace

    def test_iter_trace_reads_legacy_json(self, tmp_path):
        trace = poisson_trace(range(6), n=4, rate=2.0, seed=1)
        path = str(tmp_path / "trace.json")
        save_trace(path, trace)
        assert list(iter_trace(path)) == trace


class TestStreamingServe:
    @pytest.fixture(scope="class")
    def sessions(self):
        """Two identically-seeded trained sessions: one serves the
        materialized trace, one the generator form of the same trace."""
        sess_a = FederatedSession(_tiny_sim(), store_kind="coded")
        sess_b = FederatedSession(_tiny_sim(), store_kind="coded")
        rec = sess_a.run_stage()
        sess_b.run_stage()
        victims = [rec.plan.shard_clients[0][0], rec.plan.shard_clients[1][0]]
        return sess_a, sess_b, victims

    def test_generator_serve_bit_identical_to_list(self, sessions):
        sess_a, sess_b, victims = sessions
        trace = sequenced_trace(victims, spacing=0.1, rounds=1)
        svc = dict(policy="fifo", placement=single_device_placement())
        rep_a = UnlearningService(sess_a, **svc).serve(list(trace))
        rep_b = UnlearningService(sess_b, **svc).serve(iter(trace))
        assert [e.rid for e in rep_a.entries] == [e.rid for e in rep_b.entries]
        assert rep_a.num_batches == rep_b.num_batches
        got_a = [u for st in sess_a.report.stages for u in st.unlearn]
        got_b = [u for st in sess_b.report.stages for u in st.unlearn]
        assert len(got_a) == len(got_b) == len(victims)
        for ra, rb in zip(got_a, got_b):
            assert ra.impacted_shards == rb.impacted_shards
            assert ra.cost_units == rb.cost_units
            for s in ra.models:
                _trees_equal(ra.models[s], rb.models[s])

    def test_non_monotone_stream_raises(self, sessions):
        sess_a, _, victims = sessions
        bad = iter([_req(0, 1.0, victims[:1]), _req(1, 0.5, victims[:1])])
        with pytest.raises(ValueError, match="time-ordered"):
            UnlearningService(
                sess_a, placement=single_device_placement()).serve(bad)


# ------------------------------------------------------------------ policies
class TestPolicies:
    def test_registry(self):
        assert {"fifo", "window", "sla"} <= set(POLICIES)
        assert isinstance(make_policy("fifo"), FIFOPolicy)
        with pytest.raises(ValueError, match="unknown scheduling policy"):
            make_policy("nope")

    def test_fifo_releases_singletons_in_order(self):
        q = [_pend(1, 0.2, {(0, 1)}), _pend(0, 0.1, {(0, 0)})]
        batches = FIFOPolicy().release(q, now=0.3)
        assert [[p.req.rid for p in b] for b in batches] == [[0], [1]]
        assert q == []

    def test_window_coalesces_per_window(self):
        pol = BatchWindowPolicy(width=1.0)
        q = [_pend(0, 0.1, set()), _pend(1, 0.9, set()), _pend(2, 1.2, set())]
        assert pol.next_event(q, now=0.0) == 1.0
        batches = pol.release(q, now=1.0)
        assert [[p.req.rid for p in b] for b in batches] == [[0, 1]]
        assert [p.req.rid for p in q] == [2]       # window 1 still open
        drained = pol.release(q, now=1.5, final=True)
        assert [[p.req.rid for p in b] for b in drained] == [[2]]
        assert q == []                             # final drains

    def test_window_rejects_bad_width(self):
        with pytest.raises(ValueError, match="positive"):
            BatchWindowPolicy(width=0.0)

    def test_sla_merges_due_with_shard_overlap(self):
        pol = SLAPolicy(default_deadline=1.0, max_hold=float("inf"))
        q = [_pend(0, 0.0, {(0, 0)}),              # due at t=1.0
             _pend(1, 0.8, {(0, 0), (0, 1)}),      # overlaps shard 0
             _pend(2, 0.9, {(0, 2)})]              # disjoint — stays queued
        assert pol.next_event(q, now=0.0) == 1.0
        batches = pol.release(q, now=1.0)
        assert [[p.req.rid for p in b] for b in batches] == [[0, 1]]
        assert [p.req.rid for p in q] == [2]

    def test_sla_transitive_overlap_closure(self):
        pol = SLAPolicy(default_deadline=1.0)
        q = [_pend(0, 0.0, {(0, 0)}),
             _pend(1, 0.5, {(0, 0), (0, 1)}),
             _pend(2, 0.6, {(0, 1), (0, 2)})]      # joins via request 1
        (batch,) = pol.release(q, now=1.0)
        assert [p.req.rid for p in batch] == [0, 1, 2]

    def test_sla_respects_request_deadline(self):
        pol = SLAPolicy(default_deadline=100.0, est_serve=0.5)
        q = [Pending(_req(0, 0.0, deadline=2.0), frozenset({(0, 0)}))]
        assert pol.next_event(q, now=0.0) == pytest.approx(1.5)

    def test_sla_default_hold_is_capped_below_deadline(self):
        """With no serving-time estimate the default max_hold (half the
        deadline budget) keeps the policy from holding a request right up
        to its own deadline — which would guarantee an SLA miss."""
        pol = SLAPolicy(default_deadline=10.0)
        q = [_pend(0, 2.0, {(0, 0)})]
        assert pol.next_event(q, now=2.0) == pytest.approx(7.0)


# ------------------------------------------------------------------- serving
class TestServiceServing:
    @pytest.fixture(scope="class")
    def sessions(self):
        """Two identically-seeded trained sessions + their shared victims:
        one serves through ``FederatedSession.run`` (the reference), one
        through the service."""
        sim_a, sim_b = _tiny_sim(), _tiny_sim()
        sess_a = FederatedSession(sim_a, store_kind="coded")
        sess_b = FederatedSession(sim_b, store_kind="coded")
        rec = sess_b.run_stage()
        victims = [rec.plan.shard_clients[0][0], rec.plan.shard_clients[1][0]]
        schedule = RequestSchedule([
            UnlearnRequest([v], framework="SE", after_stage=0, rounds=2)
            for v in victims])
        sess_a.run(1, schedule=schedule)
        return sess_a, sess_b, victims

    def test_fifo_one_device_bit_identical_to_session_run(self, sessions):
        sess_a, sess_b, victims = sessions
        trace = sequenced_trace(victims, spacing=0.1, rounds=2)
        service = UnlearningService(sess_b, policy="fifo",
                                    placement=single_device_placement())
        report = service.serve(trace)
        assert len(report.entries) == len(trace)
        ref = [u for st in sess_a.report.stages for u in st.unlearn]
        got = [u for st in sess_b.report.stages for u in st.unlearn]
        assert len(ref) == len(got) == len(victims)
        for ra, rb in zip(ref, got):
            assert ra.impacted_shards == rb.impacted_shards
            assert ra.cost_units == rb.cost_units
            for s in ra.models:
                _trees_equal(ra.models[s], rb.models[s])

    def test_ledger_fields_and_json(self, sessions):
        _, sess_b, victims = sessions
        trace = sequenced_trace(victims, spacing=0.05, rounds=1,
                                deadline=120.0)
        report = UnlearningService(
            sess_b, policy="window", policy_opts={"width": 1.0},
            placement=single_device_placement()).serve(trace)
        assert report.num_batches == 1             # coalesced in one window
        d = json.loads(report.to_json())
        assert d["num_requests"] == len(victims)
        assert d["throughput_rps"] > 0
        assert d["latency_p50_s"] <= d["latency_p95_s"] <= d["latency_p99_s"]
        for e in report.entries:
            assert e.queue_wait >= 0 and e.batch_wait >= 0
            assert e.retrain_wall > 0
            assert e.latency == pytest.approx(
                e.queue_wait + e.batch_wait + e.retrain_wall)
            assert e.sla_met is True
        assert report.sla_hit_rate == 1.0

    def test_sla_deadline_missed_is_marked(self, sessions):
        _, sess_b, victims = sessions
        trace = sequenced_trace(victims[:1], rounds=1, deadline=1e-9)
        report = UnlearningService(
            sess_b, placement=single_device_placement()).serve(trace)
        assert report.entries[0].sla_met is False
        assert report.sla_hit_rate == 0.0

    def test_requests_outside_stage_serve_empty(self, sessions):
        _, sess_b, _ = sessions
        absent = [c for c in range(FL_TINY.num_clients)
                  if c not in set(sess_b.records[0].plan.clients)]
        trace = sequenced_trace(absent[:1], rounds=1)
        report = UnlearningService(
            sess_b, placement=single_device_placement()).serve(trace)
        (entry,) = report.entries
        assert entry.n_jobs == 0 and entry.retrain_wall == 0.0

    def test_unknown_framework_raises(self, sessions):
        _, sess_b, victims = sessions
        trace = sequenced_trace(victims[:1], framework="NOPE")
        with pytest.raises(ValueError, match="unknown unlearning framework"):
            UnlearningService(sess_b).serve(trace)

    def test_serve_requires_trained_stage(self):
        session = FederatedSession(_tiny_sim())
        with pytest.raises(RuntimeError, match="train at least one stage"):
            UnlearningService(session).serve(sequenced_trace([0]))


# --------------------------------------------------- async multi-device run
class TestAsyncMultiDevice:
    def test_four_virtual_devices_serve_concurrently(self):
        """Acceptance anchor: on 4 virtual CPU devices, one async batch of 4
        single-shard requests lands one shard program per device, and every
        per-shard model matches the sequential FIFO serves.  Subprocess
        because XLA_FLAGS must be set before jax initializes."""
        env = dict(os.environ)
        env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                            + " --xla_force_host_platform_device_count=4")
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in ["src", env.get("PYTHONPATH", "")] if p)
        child = os.path.join(os.path.dirname(__file__),
                             "_service_async_child.py")
        proc = subprocess.run([sys.executable, child], env=env, cwd=os.path.dirname(os.path.dirname(os.path.abspath(child))),
                              capture_output=True, text=True, timeout=560)
        assert proc.returncode == 0, proc.stderr[-2000:]
        out = json.loads(proc.stdout.strip().splitlines()[-1])
        assert out["num_devices"] == 4
        assert out["devices_used"] == [0, 1, 2, 3]
        assert out["async_batches"] == 1           # one merged window batch
        assert out["async_jobs"] == 4              # one program per shard
        assert out["impacted"] == [0, 1, 2, 3]
        assert out["max_abs_err"] < 1e-5
